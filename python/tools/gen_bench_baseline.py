#!/usr/bin/env python3
"""Generate BENCH_baseline.json: the deterministic accounting baseline
the CI gate (`cdlm bench --check-baseline`) compares against.

The rust reference backend is a pure function of (backend seed, model
seed, decode history), so per-request `steps` and `model_calls` are
exact integers reproducible on any machine. This script is a
line-for-line port of that accounting — the SplitMix64/avalanche hash
chain (rust/src/runtime/reference.rs), the six closed-batch decode
engines (rust/src/coordinator/methods/*.rs), the bucket chunk planner
(scheduler.rs), and the `cdlm bench` grid loop (main.rs), including
the cancelled-lane cells (a machine batch stepped `cancel_block` block
cycles then cancelled at the boundary — the block-step machine is
trace-pinned to the closed engines per block, so truncating the closed
loops reproduces its partial accounting) — reusing the existing python
mirrors of the workload generators and vocab (python/compile/tasks.py).

Regenerate after an intentional accounting change:

    python3 python/tools/gen_bench_baseline.py

and commit the refreshed BENCH_baseline.json in the same PR. The CI
bench itself runs the rust implementation; this generator exists so the
baseline can be produced without a decode run, and any disagreement
between the two is itself a cross-language parity failure worth
investigating.
"""

from __future__ import annotations

import importlib.util
import json
import struct
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]

# ---------------------------------------------------------------------------
# import python/compile/{vocab,tasks}.py as a package (no __init__.py)
# ---------------------------------------------------------------------------

def _load(name: str, path: Path, package: str | None = None):
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod
    spec.loader.exec_module(mod)
    return mod

import types

_pkg = types.ModuleType("compile")
_pkg.__path__ = [str(REPO / "python" / "compile")]
sys.modules["compile"] = _pkg
vocab = _load("compile.vocab", REPO / "python" / "compile" / "vocab.py")
tasks = _load("compile.tasks", REPO / "python" / "compile" / "tasks.py")

MASK64 = (1 << 64) - 1

# ---------------------------------------------------------------------------
# reference backend hash chain (rust/src/runtime/reference.rs)
# ---------------------------------------------------------------------------

DEFAULT_SEED = 0xCD1A_2026
CTX_MASK = 0x00FF_FFFF
TOK_BASE = 4
TOK_RANGE = 53

PAD, MASK, BOS, EOS = 0, 1, 2, 3

# geometry (rust/src/runtime/manifest.rs::Manifest::reference)
PROMPT_LEN, GEN_LEN, BLOCK, SEQ_LEN = 64, 32, 8, 96
BUCKETS = [1, 2, 4]
TAU = None  # f32(0.9), set below
REFRESH_EVERY = 4


def f32(x: float) -> float:
    """Round a double to the nearest f32 (exact f64 representation)."""
    return struct.unpack("f", struct.pack("f", x))[0]


TAU = f32(0.9)


def mix(a: int, b: int) -> int:
    z = (a ^ (b * 0x9E37_79B9_7F4A_7C15)) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
    return z ^ (z >> 31)


def unit(h: int) -> float:
    return (h >> 11) / float(1 << 53)


def token_hash(ids) -> int:
    h = 0x6A09_E667_F3BC_C908
    for t in ids:
        h = mix(h, t & 0xFFFF_FFFF)
    return h


def ctx_step(prev: int, tok: int) -> int:
    return mix(prev, tok & 0xFFFF_FFFF) & CTX_MASK


def fnv1a(name: str) -> int:
    h = 0xCBF2_9CE4_8422_2325
    for b in name.encode():
        h = ((h ^ b) * 0x0000_0100_0000_01B3) & MASK64
    return h


def model_seed(model: str) -> int:
    return mix(DEFAULT_SEED, fnv1a(model))


def ctx_root(ms: int) -> int:
    return mix(ms, 0xB10C_CACE) & CTX_MASK


def chain(ms: int, ids) -> int:
    """Context hash after folding `ids` from the chain root."""
    ctx = ctx_root(ms)
    for t in ids:
        ctx = ctx_step(ctx, t)
    return ctx


def dlm_propose(ms: int, h_pos: int, student: bool):
    r = mix(ms, h_pos)
    tok = EOS if r % 16 == 0 else TOK_BASE + (r % TOK_RANGE)
    u = unit(mix(r, 0x5EED_C0DE))
    conf = 1.0 - 0.25 * u if student else 1.0 - 0.6 * u
    return tok, f32(conf)


def ar_next(ms: int, ctx: int) -> int:
    r = mix(mix(ms, 0xA12_57E9), ctx)
    return EOS if r % 12 == 0 else TOK_BASE + (r % TOK_RANGE)


# ---------------------------------------------------------------------------
# SequenceState accounting subset (rust/src/coordinator/sequence.rs)
# ---------------------------------------------------------------------------

class Seq:
    def __init__(self, prompt_ids):
        assert len(prompt_ids) == PROMPT_LEN
        self.prompt = list(prompt_ids)
        self.gen = [MASK] * GEN_LEN
        self.steps = 0
        self.model_calls = 0
        self.done = False

    def full_ids(self):
        return self.prompt + self.gen

    def masked_in(self, lo, ln):
        return [i for i in range(lo, lo + ln) if self.gen[i] == MASK]

    def finalize_threshold(self, lo, toks, confs, tau):
        masked = self.masked_in(lo, len(toks))
        if not masked:
            return 0
        n = 0
        for pos in masked:
            if confs[pos - lo] >= tau:
                self.gen[pos] = toks[pos - lo]
                n += 1
        if n == 0:
            best, best_c = masked[0], confs[masked[0] - lo]
            for pos in masked[1:]:
                if confs[pos - lo] > best_c:
                    best_c = confs[pos - lo]
                    best = pos
            self.gen[best] = toks[best - lo]
            n = 1
        return n

    def finalize_top_m(self, lo, toks, confs, m):
        masked = self.masked_in(lo, len(toks))
        if not masked:
            return 0
        # stable descending by confidence (rust sort_by is stable)
        masked = sorted(masked, key=lambda pos: -confs[pos - lo])
        take = min(len(masked), max(m, 1))
        for pos in masked[:take]:
            self.gen[pos] = toks[pos - lo]
        return take

    def eos_in(self, lo, ln):
        return any(t == EOS for t in self.gen[lo:lo + ln])

    def gen_length(self):
        try:
            end = self.gen.index(EOS)
        except ValueError:
            end = len(self.gen)
        return sum(1 for t in self.gen[:end] if t != MASK)


# ---------------------------------------------------------------------------
# closed-batch decode engines (accounting-faithful ports)
# ---------------------------------------------------------------------------

def denoise_proposals(ms: int, seqs):
    """teacher_denoise / teacher_full_cache: per-lane full-seq proposals."""
    out = []
    for s in seqs:
        row = s.full_ids()
        lh = token_hash(row)
        out.append([dlm_propose(ms, mix(lh, p), False) for p in range(SEQ_LEN)])
    return out


def block_proposals(ms: int, rows, ctxs, pos0: int, student: bool):
    """student_block_step / teacher_block_approx over one block."""
    out = []
    for row, ctx_prev in zip(rows, ctxs):
        bh = mix(token_hash(row), ctx_prev)
        out.append(
            [dlm_propose(ms, mix(bh, pos0 + i), student)
             for i in range(len(row))]
        )
    return out


def decode_bidirectional(ms, prompts, threshold: bool, max_cycles=None):
    """vanilla (TopM m=1) and fast-dllm-par (Threshold).

    `max_cycles` mirrors the rust block-step machine's cancellation
    point: the lanes are cancelled at that block-cycle boundary, so the
    outer loop simply stops after that many blocks (the machine's
    cycle N processes block N for a together-admitted batch, and all
    per-block accounting is charged inside the cycle).
    """
    seqs = [Seq(p) for p in prompts]
    blk = BLOCK
    for b in range(GEN_LEN // blk):
        if max_cycles is not None and b >= max_cycles:
            break
        lo = b * blk
        while True:
            if not any(s.masked_in(lo, blk) for s in seqs):
                break
            props = denoise_proposals(ms, seqs)
            for r, s in enumerate(seqs):
                base = PROMPT_LEN + lo
                toks = [props[r][base + i][0] for i in range(blk)]
                confs = [props[r][base + i][1] for i in range(blk)]
                if s.masked_in(lo, blk):
                    if threshold:
                        s.finalize_threshold(lo, toks, confs, TAU)
                    else:
                        s.finalize_top_m(lo, toks, confs, 1)
                s.steps += 1
                s.model_calls += 1
    return seqs


def decode_cached_teacher(ms, prompts, dual: bool, max_cycles=None):
    """dllm-cache (top-1, periodic refresh) / fast-dllm-dc (threshold,
    refresh at block boundaries)."""
    seqs = [Seq(p) for p in prompts]
    blk = BLOCK
    refresh_ids = [None] * len(seqs)  # full ids at last write_full
    ssr = 1 << 62  # usize::MAX stand-in: force refresh first
    for b in range(GEN_LEN // blk):
        if max_cycles is not None and b >= max_cycles:
            break
        lo = b * blk
        if dual:
            ssr = 1 << 62
        while True:
            active = [r for r, s in enumerate(seqs) if s.masked_in(lo, blk)]
            if not active:
                break
            if ssr >= REFRESH_EVERY:
                props = denoise_proposals(ms, seqs)
                for r, s in enumerate(seqs):
                    refresh_ids[r] = s.full_ids()
                for r in active:
                    base = PROMPT_LEN + lo
                    toks = [props[r][base + i][0] for i in range(blk)]
                    confs = [props[r][base + i][1] for i in range(blk)]
                    if dual:
                        seqs[r].finalize_threshold(lo, toks, confs, TAU)
                    else:
                        seqs[r].finalize_top_m(lo, toks, confs, 1)
                    seqs[r].steps += 1
                    seqs[r].model_calls += 1
                ssr = 1
            else:
                pos0 = PROMPT_LEN + lo
                rows = [s.gen[lo:lo + blk] for s in seqs]
                ctxs = [chain(ms, refresh_ids[r][:pos0])
                        for r in range(len(seqs))]
                props = block_proposals(ms, rows, ctxs, pos0, False)
                for r in active:
                    toks = [t for t, _ in props[r]]
                    confs = [c for _, c in props[r]]
                    if dual:
                        seqs[r].finalize_threshold(lo, toks, confs, TAU)
                    else:
                        seqs[r].finalize_top_m(lo, toks, confs, 1)
                    seqs[r].steps += 1
                    seqs[r].model_calls += 1
                ssr += 1
    return seqs


def decode_cdlm(ms, prompts, max_cycles=None):
    seqs = [Seq(p) for p in prompts]
    blk = BLOCK
    num_blocks = GEN_LEN // blk
    # prefill: exact prompt chain, one model call per lane
    ctx = [chain(ms, s.prompt) for s in seqs]
    for s in seqs:
        s.model_calls += 1
    for b in range(num_blocks):
        if max_cycles is not None and b >= max_cycles:
            break
        lo = b * blk
        if all(s.done for s in seqs):
            break
        while True:
            need = [r for r, s in enumerate(seqs)
                    if not s.done and s.masked_in(lo, blk)]
            if not need:
                break
            pos0 = PROMPT_LEN + lo
            rows = [s.gen[lo:lo + blk] for s in seqs]
            props = block_proposals(ms, rows, ctx, pos0, True)
            for r, s in enumerate(seqs):
                if s.done:
                    continue
                if s.masked_in(lo, blk):
                    toks = [t for t, _ in props[r]]
                    confs = [c for _, c in props[r]]
                    s.finalize_threshold(lo, toks, confs, TAU)
                s.steps += 1
                s.model_calls += 1
        for s in seqs:
            if not s.done and s.eos_in(lo, blk):
                s.done = True
        still_running = any(not s.done for s in seqs)
        if not still_running or b + 1 == num_blocks:
            break
        # commit: one extra model call per continuing lane; the chain
        # extends over the final block tokens
        for r, s in enumerate(seqs):
            if not s.done:
                s.model_calls += 1
                new_ctx = ctx[r]
                for t in s.gen[lo:lo + blk]:
                    new_ctx = ctx_step(new_ctx, t)
                ctx[r] = new_ctx
            else:
                # done lanes' slots are not committed; their chain is
                # never read again
                pass
    return seqs


def decode_ar(ms, prompts, max_cycles=None):
    """AR: one machine cycle covers BLOCK token positions, so
    cancellation after k cycles truncates the token loop at k*BLOCK
    (the charge for the step that proposed token k*BLOCK was paid at
    position k*BLOCK - 1 and is included, same as the machine)."""
    seqs = [Seq(p) for p in prompts]
    ctx = [chain(ms, s.prompt) for s in seqs]
    cur = [ar_next(ms, c) for c in ctx]
    for s in seqs:
        s.model_calls += 1
    done = [False] * len(seqs)
    for i in range(GEN_LEN):
        if max_cycles is not None and i >= max_cycles * BLOCK:
            break
        for r, s in enumerate(seqs):
            if not done[r]:
                s.gen[i] = cur[r]
                s.steps += 1
                if cur[r] == EOS:
                    done[r] = True
                    s.done = True
        if all(done) or i == GEN_LEN - 1:
            break
        # ar_step: every lane's chain extends over its pending token
        # (done lanes included — exact caching), but only live lanes
        # are charged the model call
        for r, s in enumerate(seqs):
            ctx[r] = ctx_step(ctx[r], cur[r])
            if not done[r]:
                s.model_calls += 1
        cur = [ar_next(ms, c) for c in ctx]
    return seqs


METHODS = [
    ("vanilla", "teacher_dream"),
    ("dllm-cache", "teacher_dream"),
    ("fast-dllm-par", "teacher_dream"),
    ("fast-dllm-dc", "teacher_dream"),
    ("cdlm", "cdlm_dream"),
    ("ar", "ar_dream"),
]


def decode_batch(method: str, ms: int, prompts, max_cycles=None):
    if method == "vanilla":
        return decode_bidirectional(
            ms, prompts, threshold=False, max_cycles=max_cycles)
    if method == "fast-dllm-par":
        return decode_bidirectional(
            ms, prompts, threshold=True, max_cycles=max_cycles)
    if method == "dllm-cache":
        return decode_cached_teacher(
            ms, prompts, dual=False, max_cycles=max_cycles)
    if method == "fast-dllm-dc":
        return decode_cached_teacher(
            ms, prompts, dual=True, max_cycles=max_cycles)
    if method == "cdlm":
        return decode_cdlm(ms, prompts, max_cycles=max_cycles)
    if method == "ar":
        return decode_ar(ms, prompts, max_cycles=max_cycles)
    raise ValueError(method)


def cancelled_count(method: str, outs, k: int) -> int:
    """Lanes still decoding at the cancellation boundary — the count the
    rust harness cancels (the teacher baselines never early-stop, so
    every lane survives to the boundary; CDLM/AR lanes that finalized
    <eos> before cycle k retired naturally)."""
    if k >= GEN_LEN // BLOCK:
        return 0
    if method in ("cdlm", "ar"):
        return sum(1 for s in outs if not s.done)
    return len(outs)


# ---------------------------------------------------------------------------
# scheduler chunk plan (rust/src/coordinator/scheduler.rs::plan_chunks)
# ---------------------------------------------------------------------------

def plan_chunks(n: int):
    buckets = sorted(BUCKETS)
    mx = buckets[-1]
    out = []
    left = n
    while left >= mx:
        out.append((mx, mx))
        left -= mx
    if left > 0:
        bucket = next((b for b in buckets if b >= left), mx)
        out.append((bucket, left))
    return out


def engine_decode(method: str, ms: int, prompts):
    """Engine::decode: chunk to buckets, pad by aliasing the last lane,
    truncate padded outcomes."""
    out = []
    start = 0
    for bucket, real in plan_chunks(len(prompts)):
        group = list(prompts[start:start + real])
        start += real
        while len(group) < bucket:
            group.append(group[-1])
        out.extend(decode_batch(method, ms, group)[:real])
    return out


# ---------------------------------------------------------------------------
# the bench grid (rust/src/main.rs::cmd_bench)
# ---------------------------------------------------------------------------

def main():
    if len(sys.argv) > 1:
        sys.exit(
            "gen_bench_baseline.py takes no arguments: it always runs the "
            "CI grid (methods all, batches 1/4/8, n 8) and writes "
            f"{REPO / 'BENCH_baseline.json'}"
        )
    n = 8
    batches = [1, 4, 8]
    samples = tasks.generate("chain-arith", n, 0xE7A1)
    prompts = [
        tasks.encode_example("chain-arith", s, PROMPT_LEN, GEN_LEN)[0]
        for s in samples
    ]
    cells = []
    print(f"{'method':<14} {'batch':>6} {'requests':>9} {'tokens':>7} "
          f"{'steps':>7} {'calls':>7}")
    for method, model in METHODS:
        ms = model_seed(model)
        for requested_bs in batches:
            bs = min(requested_bs, len(prompts))
            outs = []
            for i in range(0, len(prompts), bs):
                outs.extend(engine_decode(method, ms, prompts[i:i + bs]))
            tokens = sum(s.gen_length() for s in outs)
            total_steps = sum(s.steps for s in outs)
            total_calls = sum(s.model_calls for s in outs)
            print(f"{method:<14} {bs:>6} {len(outs):>9} {tokens:>7} "
                  f"{total_steps:>7} {total_calls:>7}")
            cells.append({
                "method": method,
                "batch": bs,
                "requests": len(outs),
                "tokens": tokens,
                "total_steps": total_steps,
                "total_model_calls": total_calls,
            })
    # cancelled-lane accounting cells (rust: `cdlm bench` machine-path
    # harness — admit min(4, n) lanes together, step `cancel_block`
    # block cycles, cancel every surviving lane at the boundary). The
    # machine is trace-pinned to the closed-batch engines per block, so
    # the truncated closed loops above reproduce its partial accounting
    # exactly.
    cancel_block = 2
    for method, model in METHODS:
        ms = model_seed(model)
        bs = min(4, len(prompts))
        outs = decode_batch(
            method, ms, prompts[:bs], max_cycles=cancel_block)
        tokens = sum(s.gen_length() for s in outs)
        total_steps = sum(s.steps for s in outs)
        total_calls = sum(s.model_calls for s in outs)
        cancelled = cancelled_count(method, outs, cancel_block)
        print(f"{method:<14} {bs:>6} cancel@{cancel_block}: "
              f"cancelled {cancelled}, tokens {tokens}, "
              f"steps {total_steps}, calls {total_calls}")
        cells.append({
            "method": method,
            "batch": bs,
            "cancel_at_block": cancel_block,
            "cancelled_lanes": cancelled,
            "requests": len(outs),
            "tokens": tokens,
            "total_steps": total_steps,
            "total_model_calls": total_calls,
        })
    # routed shard-invariance cells (rust: `cdlm bench --replicas N`):
    # every prompt decoded closed-loop through the sharded router, i.e.
    # in a solo cohort on whichever replica the dispatcher picked.
    # Per-lane accounting in a lockstep cohort depends on the slowest
    # cohort mate, so solo cohorts are the composition every replica
    # count reproduces exactly — the rust cell must match this one
    # whether it ran on 1 shard or 4.
    for method, model in METHODS:
        ms = model_seed(model)
        outs = [engine_decode(method, ms, [p])[0] for p in prompts]
        tokens = sum(s.gen_length() for s in outs)
        total_steps = sum(s.steps for s in outs)
        total_calls = sum(s.model_calls for s in outs)
        print(f"{method:<14} routed: requests {len(outs)}, "
              f"tokens {tokens}, steps {total_steps}, calls {total_calls}")
        cells.append({
            "method": method,
            "batch": 1,
            "routed": 1,
            "requests": len(outs),
            "tokens": tokens,
            "total_steps": total_steps,
            "total_model_calls": total_calls,
        })
    # preempted-lane accounting cells (rust: `cdlm bench` machine-path
    # harness — the same min(4, n)-lane machine batch, but every live
    # lane is suspended to the KV pool's cold tier and immediately
    # resumed at the first block boundary). Preemption is REQUIRED to
    # be invisible in the accounting: the rust harness checks each run
    # byte-identical to its uninterrupted twin in-bench, so the
    # baseline integers are simply those of the uninterrupted batch,
    # keyed separately with "preempt": 1 — any drift the spill/reseat
    # round trip ever introduces fails the CI gate.
    for method, model in METHODS:
        ms = model_seed(model)
        bs = min(4, len(prompts))
        outs = decode_batch(method, ms, prompts[:bs])
        tokens = sum(s.gen_length() for s in outs)
        total_steps = sum(s.steps for s in outs)
        total_calls = sum(s.model_calls for s in outs)
        print(f"{method:<14} {bs:>6} preempt: tokens {tokens}, "
              f"steps {total_steps}, calls {total_calls}")
        cells.append({
            "method": method,
            "batch": bs,
            "preempt": 1,
            "requests": len(outs),
            "tokens": tokens,
            "total_steps": total_steps,
            "total_model_calls": total_calls,
        })
    doc = {
        "schema": "cdlm.bench.decode/v1",
        "backend": "reference",
        "backbone": "dream",
        "note": (
            "Deterministic accounting baseline for the CI gate "
            "(cdlm bench --check-baseline). Only requests/tokens/"
            "total_steps/total_model_calls are compared; regenerate "
            "with python3 python/tools/gen_bench_baseline.py after an "
            "intentional accounting change."
        ),
        "n": n,
        "gen_len": GEN_LEN,
        "block_size": BLOCK,
        "results": cells,
    }
    out = REPO / "BENCH_baseline.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"baseline -> {out}")


if __name__ == "__main__":
    main()
