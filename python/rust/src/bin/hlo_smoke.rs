// throwaway smoke: load student_block_step HLO + weights npz, execute, compare
use xla::FromRawBytes;
fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file("/tmp/sbs_test.hlo.txt")?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let mut weights = xla::Literal::read_npz("/tmp/sbs_weights.npz", &())?;
    weights.sort_by(|a, b| a.0.cmp(&b.0));
    let (l, bs, h, s, dh, b) = (3usize, 2usize, 4usize, 96usize, 24usize, 8usize);
    let kc = xla::Literal::vec1(&vec![0f32; l*bs*h*s*dh]).reshape(&[l as i64, bs as i64, h as i64, s as i64, dh as i64])?;
    let vc = kc.clone()?; // hmm Literal clone?
    let cl = xla::Literal::scalar(64i32);
    let vf = xla::Literal::vec1(&[10i32, 0i32]);
    let blk = xla::Literal::vec1(&vec![1i32; bs*b]).reshape(&[bs as i64, b as i64])?;
    let pos0 = xla::Literal::scalar(64i32);
    let mut args: Vec<&xla::Literal> = weights.iter().map(|(_, l)| l).collect();
    args.push(&kc); args.push(&vc); args.push(&cl); args.push(&vf); args.push(&blk); args.push(&pos0);
    let t0 = std::time::Instant::now();
    let res = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
    println!("exec time {:?}", t0.elapsed());
    let outs = res.to_tuple()?;
    println!("n outs {}", outs.len());
    let logits = outs[0].to_vec::<f32>()?;
    let expected = xla::Literal::read_npy("/tmp/sbs_expected_logits.npy", &())?.to_vec::<f32>()?;
    let max_err = logits.iter().zip(&expected).map(|(a, e)| (a - e).abs()).fold(0f32, f32::max);
    println!("logits sum {} max_err {}", logits.iter().sum::<f32>(), max_err);
    assert!(max_err < 1e-4);
    // time a few executions
    let t0 = std::time::Instant::now();
    for _ in 0..10 { exe.execute::<&xla::Literal>(&args)?; }
    println!("per-exec {:?}", t0.elapsed() / 10);
    println!("SMOKE OK");
    Ok(())
}
