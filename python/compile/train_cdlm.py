"""Algorithm 2: CDLM consistency distillation of the block-causal student.

The student is the teacher plus LoRA adapters (paper: LoRA on attention +
MLP), trained under the block-wise causal mask with the three-objective
loss (Eq. 7):

  L = w_distill * L_Distillation + w_cons * L_Consistency + w_dlm * L_DLM

  * Distillation (Eq. 4): forward KL from the teacher's distribution
    (reconstructed as lm_head(h) from the stored hidden-state buffer) to
    the student's prediction at state y, on positions newly unmasked
    between y and its block-completion y*. This is the multi-token
    finalization supervision.
  * Consistency (Eq. 5): forward KL from the stop-gradient student at the
    more-informed state y* to the student at the less-informed y, on
    positions still masked at y* — the discrete analogue of consistency
    models' trajectory self-alignment.
  * DLM (Eq. 6): the standard masked-denoising loss on ground-truth text,
    preserving mask-prediction ability (small weight; Table 3 row 4/6
    shows dropping it trades math for coding accuracy).

Default weights (1.0, 0.5, w_dlm) follow paper Tables 5/6.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import train_common as TC
from . import vocab
from .trajectory import TrajectoryDataset


def _states_from_batch(cfg: M.ModelConfig, order, toks, t_start, t_end):
    """Vectorized reconstruction of (y, y*) generation spans + index sets.

    order/toks [bs, Lg]; t_start/t_end [bs]. Returns gen_y, gen_ystar
    [bs, Lg] token arrays and boolean U (newly unmasked in (t_start,
    t_end]) and Sm (still masked at y*) over positions.
    """
    bs, Lg = order.shape
    step_of_pos = np.zeros((bs, Lg), np.int32)  # step at which pos finalizes
    rows = np.arange(bs)[:, None]
    step_of_pos[rows, order] = np.arange(Lg)[None, :]
    finalized_y = step_of_pos < t_start[:, None]
    finalized_ystar = step_of_pos < t_end[:, None]
    tok_at_pos = np.zeros((bs, Lg), np.int32)
    tok_at_pos[rows, order] = toks
    gen_y = np.where(finalized_y, tok_at_pos, vocab.MASK).astype(np.int32)
    gen_ystar = np.where(finalized_ystar, tok_at_pos,
                         vocab.MASK).astype(np.int32)
    U = finalized_ystar & ~finalized_y
    Sm = ~finalized_ystar
    return gen_y, gen_ystar, U, Sm, tok_at_pos


def cdlm_losses(cfg: M.ModelConfig, teacher_params, params_merged,
                prompts, gen_y, gen_ystar, U, Sm, hbuf, answers, key,
                w):
    """The three objectives for one batch. All inputs are jnp arrays;
    ``params_merged`` is teacher+LoRA (gradients flow to LoRA only).

    Returns (total, dict of parts)."""
    bs = prompts.shape[0]
    P, S = cfg.prompt_len, cfg.seq_len
    vf = jnp.argmin(prompts == vocab.PAD, axis=1).astype(jnp.int32)
    mask = jax.vmap(lambda v: M.block_causal_mask(cfg, v))(vf)

    ids_y = jnp.concatenate([prompts, gen_y], axis=1)
    logits_y = M.forward_full(cfg, params_merged, ids_y, mask)[:, P:, :]
    logq_y = jax.nn.log_softmax(logits_y.astype(jnp.float32), axis=-1)

    # ---- Distillation (Eq. 4): teacher probs from the hidden buffer
    t_logits = hbuf @ teacher_params["head"]
    logp_t = jax.nn.log_softmax(t_logits.astype(jnp.float32), axis=-1)
    p_t = jnp.exp(logp_t)
    kl_distill = jnp.sum(p_t * (logp_t - logq_y), axis=-1)  # [bs, Lg]
    Uf = U.astype(jnp.float32)
    l_distill = jnp.sum(kl_distill * Uf) / (jnp.sum(Uf) + 1e-6)

    # ---- Consistency (Eq. 5): stop-gradient student at y*
    ids_ystar = jnp.concatenate([prompts, gen_ystar], axis=1)
    logits_ystar = M.forward_full(
        cfg, jax.lax.stop_gradient(params_merged), ids_ystar, mask)[:, P:, :]
    logq_ystar = jax.nn.log_softmax(logits_ystar.astype(jnp.float32), -1)
    q_ystar = jnp.exp(logq_ystar)
    kl_cons = jnp.sum(q_ystar * (logq_ystar - logq_y), axis=-1)
    Sf = Sm.astype(jnp.float32)
    l_cons = jnp.sum(kl_cons * Sf) / (jnp.sum(Sf) + 1e-6)

    # ---- DLM (Eq. 6) on ground truth, under the student mask
    l_dlm = TC.dlm_loss(cfg, params_merged, prompts, answers, key,
                        mask_fn=M.block_causal_mask)

    total = w["distill"] * l_distill + w["cons"] * l_cons + w["dlm"] * l_dlm
    return total, {"distill": l_distill, "cons": l_cons, "dlm": l_dlm}


def train_cdlm(cfg: M.ModelConfig, teacher_params, traj: TrajectoryDataset,
               steps: int, weights=(1.0, 0.5, 0.01), batch_size: int = 16,
               lr: float = 1e-3, seed: int = 0, log_every: int = 50,
               eval_hook=None, eval_every: int | None = None):
    """Train LoRA adapters; returns (merged_student_params, history).

    ``eval_hook(merged_params) -> dict`` is called every ``eval_every``
    steps (drives Fig. 7 validation trends and Table 3 convergence)."""
    w = {"distill": weights[0], "cons": weights[1], "dlm": weights[2]}
    lora = M.init_lora(cfg, jax.random.PRNGKey(seed + 3))
    opt = TC.AdamW(lr, total_steps=steps, weight_decay=0.0)
    ost = opt.init(lora)
    N, B = cfg.gen_len, cfg.block_size

    @jax.jit
    def step_fn(lora, ost, prompts, gen_y, gen_ystar, U, Sm, hbuf, answers,
                key):
        def loss_fn(lo):
            merged = M.apply_lora(cfg, teacher_params, lo)
            return cdlm_losses(cfg, teacher_params, merged, prompts, gen_y,
                               gen_ystar, U, Sm, hbuf, answers, key, w)
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(lora)
        lora, ost = opt.update(lora, grads, ost)
        return lora, ost, loss, parts

    rng = np.random.RandomState(seed + 17)
    key = jax.random.PRNGKey(seed + 23)
    history = []
    t0 = time.time()
    for it in range(steps):
        sel = rng.randint(0, len(traj), batch_size)
        order, toks = traj.order[sel], traj.toks[sel]
        # t_start uniform over steps; t_end = completion of its block
        # (Alg. 2 line 5). Block-boundary t_start would make y == y*
        # (degenerate), so t_end uses floor(t/B)+1 blocks.
        t_start = rng.randint(0, N, batch_size)
        t_end = np.minimum(N, (t_start // B + 1) * B)
        gen_y, gen_ystar, U, Sm, _ = _states_from_batch(
            cfg, order, toks, t_start, t_end)
        key, sub = jax.random.split(key)
        lora, ost, loss, parts = step_fn(
            lora, ost, jnp.asarray(traj.prompts[sel]), jnp.asarray(gen_y),
            jnp.asarray(gen_ystar), jnp.asarray(U), jnp.asarray(Sm),
            jnp.asarray(traj.hbuf[sel]), jnp.asarray(traj.answers[sel]), sub)
        if (it + 1) % log_every == 0:
            print(f"[cdlm] step {it+1}/{steps} loss {float(loss):.4f} "
                  f"(distill {float(parts['distill']):.3f} "
                  f"cons {float(parts['cons']):.3f} "
                  f"dlm {float(parts['dlm']):.3f}) "
                  f"({time.time()-t0:.0f}s)", flush=True)
        if eval_hook and eval_every and (it + 1) % eval_every == 0:
            merged = M.merge_lora(cfg, teacher_params, lora)
            metrics = eval_hook(merged)
            metrics["step"] = it + 1
            history.append(metrics)
            print(f"[cdlm] eval @{it+1}: {metrics}", flush=True)
    return M.merge_lora(cfg, teacher_params, lora), history
