"""Algorithm 1: offline trajectory collection from the bidirectional teacher.

For each prompt we run the teacher at its most performant operating point
(block-wise decoding, N = Lg steps, exactly one top-confidence token
finalized per step) and record

  * the finalization order+tokens (which fully determine every
    intermediate state x_{t_k} of the decoding trajectory, Eq. 3), and
  * the hidden-state buffer H [Lg, d]: the teacher's last hidden state at
    each position, captured at the moment that position was finalized
    (paper Fig. 6 — storing d-dim hiddens instead of |V|-dim logits is
    the paper's ~30x storage saving; we reconstruct teacher logits at
    training time by applying the teacher's lm_head).

Temperature augmentation: each prompt is decoded at tau in {0.0, 0.5}
(Appendix A.1 — tau = 1.0 destabilizes the reasoning chain, Fig. 5).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import decoding
from . import model as M
from . import train_common as TC

TEMPERATURES = (0.0, 0.5)


@dataclasses.dataclass
class TrajectoryDataset:
    """Columnar trajectory store (one row per (prompt, temperature)).

    order [n, Lg]  absolute position finalized at each step, minus P
    toks  [n, Lg]  token finalized at each step
    hbuf  [n, Lg, d]  hidden-state buffer indexed BY POSITION (not step)
    prompts [n, P]; answers [n, Lg] ground truth; final [n, Lg] the
    teacher's final sequence (for inspection/tests).
    """
    order: np.ndarray
    toks: np.ndarray
    hbuf: np.ndarray
    prompts: np.ndarray
    answers: np.ndarray
    final: np.ndarray

    def __len__(self):
        return len(self.order)

    def save(self, path: str):
        np.savez_compressed(path, order=self.order, toks=self.toks,
                            hbuf=self.hbuf, prompts=self.prompts,
                            answers=self.answers, final=self.final)

    @staticmethod
    def load(path: str) -> "TrajectoryDataset":
        with np.load(path) as z:
            return TrajectoryDataset(*(z[k] for k in (
                "order", "toks", "hbuf", "prompts", "answers", "final")))

    def state_at(self, row: int, t: int, cfg: M.ModelConfig) -> np.ndarray:
        """Reconstruct x_{t_k}: prompt + tokens finalized in steps < t."""
        from . import vocab
        gen = np.full(cfg.gen_len, vocab.MASK, np.int32)
        for s in range(t):
            gen[self.order[row, s]] = self.toks[row, s]
        return np.concatenate([self.prompts[row], gen])


def collect(cfg: M.ModelConfig, teacher_params, mixture: dict[str, float],
            n_prompts: int, seed: int, batch_size: int = 16,
            temperatures=TEMPERATURES, log=print) -> TrajectoryDataset:
    prompts, answers, _ = TC.make_corpus(cfg, mixture, n_prompts, seed)
    Lg, d = cfg.gen_len, cfg.d_model
    rows_o, rows_t, rows_h, rows_p, rows_a, rows_f = [], [], [], [], [], []
    for tau in temperatures:
        for lo in range(0, n_prompts, batch_size):
            p = prompts[lo:lo + batch_size]
            a = answers[lo:lo + batch_size]
            res = decoding.teacher_block_decode(
                cfg, teacher_params, p, temperature=tau,
                seed=seed + lo, collect=True)
            for r in range(len(p)):
                tr = res.trace[r]
                assert len(tr) == Lg, f"trajectory length {len(tr)} != {Lg}"
                order = np.array([pos - cfg.prompt_len for pos, _, _ in tr],
                                 np.int32)
                toks = np.array([tok for _, tok, _ in tr], np.int32)
                h = np.zeros((Lg, d), np.float32)
                for pos, _, hv in tr:
                    h[pos - cfg.prompt_len] = hv
                rows_o.append(order)
                rows_t.append(toks)
                rows_h.append(h)
                rows_p.append(p[r])
                rows_a.append(a[r])
                rows_f.append(np.asarray(res.ids[r, cfg.prompt_len:]))
            log(f"[trajectory] tau={tau} {min(lo + batch_size, n_prompts)}"
                f"/{n_prompts}")
    return TrajectoryDataset(
        np.stack(rows_o), np.stack(rows_t), np.stack(rows_h),
        np.stack(rows_p), np.stack(rows_a), np.stack(rows_f))
