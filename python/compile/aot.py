"""AOT pipeline: train → collect → distill → lower → export.

``python -m compile.aot`` (driven by ``make artifacts``) produces
everything the rust request path needs, then python is never imported
again:

  artifacts/
    manifest.json        program table, shapes, weight arg order, geometry
    vocab.json           tokenizer table (rust mirror golden-checks this)
    hlo/<prog>_b<bs>[_B<blk>].hlo.txt
    weights_{teacher,cdlm,ar}_{dream,llada}.npz
    traj_{dream,llada}.npz          teacher trajectories (Alg. 1)
    eval/<family>.json              eval prompt sets + references
    golden/*.json                   cross-language parity fixtures
    fig7.json                       validation-trend series (Fig. 7)

HLO **text** is the interchange format (not serialized protos): jax>=0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Every step is skipped if its output already exists, so ``make artifacts``
is incremental; ``CDLM_FAST=1`` shrinks training for development.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import decoding
from . import model as M
from . import tasks
from . import train_common as TC
from . import vocab
from .train_ar import greedy_decode, train_ar
from .train_cdlm import train_cdlm
from .train_teacher import MIXTURES, SEEDS, train_teacher
from .trajectory import TrajectoryDataset, collect

BACKBONES = ("dream", "llada")
BUCKETS = (1, 2, 4)
SWEEP_BLOCKS = (2, 4, 16)  # Fig. 8 block-size sweep (default B=8 is in BUCKETS)
EVAL_N = 64


def art(path: str, *parts) -> str:
    return os.path.join(path, *parts)


# --------------------------------------------------------------------------
# HLO lowering
# --------------------------------------------------------------------------

def to_hlo_text(fn, specs) -> str:
    # keep_unused: every program takes the full weight set in the same
    # order, even weights its computation does not touch (e.g. prefill
    # never reads lm_head) — the rust runtime relies on that convention.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def program_table(cfg: M.ModelConfig, names: list[str]):
    """(name, bs, blk, input specs, builder) for every AOT program.

    Weight args always come first, in sorted-name order; the manifest and
    the rust runtime share this convention.
    """
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    P, S, V = cfg.prompt_len, cfg.seq_len, cfg.vocab_size

    def wspecs():
        shapes = M.param_shapes(cfg)
        return [f32(*shapes[n]) for n in names]

    def wrap(body, n_extra):
        def fn(*args):
            p = dict(zip(names, args[:len(names)]))
            return body(p, *args[len(names):])
        return fn

    table = []
    for bs in BUCKETS:
        cache = [f32(L, bs, H, S, dh)] * 2
        B = cfg.block_size
        table += [
            ("teacher_denoise", bs, None,
             wspecs() + [i32(bs, S), i32(bs)],
             lambda p, ids, vf: M.teacher_denoise(cfg, p, ids, vf)),
            ("teacher_full_cache", bs, None,
             wspecs() + [i32(bs, S), i32(bs)],
             lambda p, ids, vf: M.teacher_full_cache(cfg, p, ids, vf)),
            ("teacher_block_approx", bs, B,
             wspecs() + cache + [i32(bs), i32(bs, B), i32()],
             lambda p, kc, vc, vf, blk, pos0: M.teacher_block_approx(
                 cfg, p, kc, vc, vf, blk, pos0)),
            ("student_prefill", bs, None,
             wspecs() + [i32(bs, P), i32(bs)],
             lambda p, ids, vf: M.student_prefill(cfg, p, ids, vf)),
            ("student_block_step", bs, B,
             wspecs() + cache + [i32(), i32(bs), i32(bs, B), i32()],
             lambda p, kc, vc, cl, vf, blk, pos0: M.student_block_step(
                 cfg, p, kc, vc, cl, vf, blk, pos0)),
            ("ar_prefill", bs, None,
             wspecs() + [i32(bs, P), i32(bs)],
             lambda p, ids, vf: M.ar_prefill(cfg, p, ids, vf)),
            ("ar_step", bs, None,
             wspecs() + cache + [i32(), i32(bs), i32(bs)],
             lambda p, kc, vc, cl, vf, tok: M.ar_step(
                 cfg, p, kc, vc, cl, vf, tok)),
            # Appendix C extension: parallel AR verification of a
            # CDLM-drafted block (speculative decoding)
            ("ar_verify", bs, B,
             wspecs() + cache + [i32(), i32(bs), i32(bs, B), i32()],
             lambda p, kc, vc, cl, vf, blk, pos0: M.ar_verify(
                 cfg, p, kc, vc, cl, vf, blk, pos0)),
        ]
    # Fig. 8: block-size sweep variants (bs=1 only)
    for B in SWEEP_BLOCKS:
        cache = [f32(L, 1, H, S, dh)] * 2
        table.append(
            ("student_block_step", 1, B,
             wspecs() + cache + [i32(), i32(1), i32(1, B), i32()],
             lambda p, kc, vc, cl, vf, blk, pos0: M.student_block_step(
                 cfg, p, kc, vc, cl, vf, blk, pos0)))
    return table


def prog_filename(name: str, bs: int, blk) -> str:
    base = f"{name}_b{bs}"
    if blk is not None:
        base += f"_B{blk}"
    return base + ".hlo.txt"


def export_hlo(cfg: M.ModelConfig, out_dir: str, force: bool = False):
    names = sorted(M.param_shapes(cfg))
    os.makedirs(art(out_dir, "hlo"), exist_ok=True)
    entries = []
    for name, bs, blk, specs, body in program_table(cfg, names):
        fname = prog_filename(name, bs, blk)
        path = art(out_dir, "hlo", fname)
        entry = {
            "name": name, "bs": bs, "block": blk, "file": f"hlo/{fname}",
            "inputs": [{"shape": list(s.shape),
                        "dtype": str(s.dtype)} for s in specs],
        }
        entries.append(entry)
        if os.path.exists(path) and not force:
            continue
        t0 = time.time()

        def fn(*args, _body=body):
            p = dict(zip(names, args[:len(names)]))
            return _body(p, *args[len(names):])

        text = to_hlo_text(fn, specs)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] lowered {fname} ({len(text)} chars, "
              f"{time.time()-t0:.1f}s)", flush=True)
    return entries


# --------------------------------------------------------------------------
# Eval sets + goldens
# --------------------------------------------------------------------------

def export_eval_sets(cfg: M.ModelConfig, out_dir: str):
    os.makedirs(art(out_dir, "eval"), exist_ok=True)
    for fam in tasks.FAMILIES:
        path = art(out_dir, "eval", f"{fam}.json")
        if os.path.exists(path):
            continue
        prompts, answers, samples = TC.encode_family_batch(
            cfg, fam, EVAL_N, seed=0xE7A1)
        data = {
            "family": fam,
            "paper_analogue": tasks.PAPER_ANALOGUE[fam],
            "num_shots": tasks.NUM_SHOTS[fam],
            "prompt_len": cfg.prompt_len,
            "gen_len": cfg.gen_len,
            "prompts": prompts.tolist(),
            "ref_answers": answers.tolist(),
            "finals": [s.final for s in samples],
        }
        with open(path, "w") as f:
            json.dump(data, f)
        print(f"[aot] wrote eval/{fam}.json")


def export_goldens(cfg: M.ModelConfig, out_dir: str, weights: dict):
    """Cross-language parity fixtures for the rust test suite."""
    os.makedirs(art(out_dir, "golden"), exist_ok=True)

    # tokenizer golden
    path = art(out_dir, "golden", "tokenizer.json")
    if not os.path.exists(path):
        texts = ["q:3*4+5=?a:", "#17;", "q:rev(catx)=?a:",
                 "0123456789abcxyz+-*=;#:?(),.><[] "]
        with open(path, "w") as f:
            json.dump({"cases": [{"text": t, "ids": vocab.encode(t)}
                                 for t in texts]}, f)

    # task-generation golden (SplitMix64 parity)
    path = art(out_dir, "golden", "tasks.json")
    if not os.path.exists(path):
        out = {}
        for fam in tasks.FAMILIES:
            ss = tasks.generate(fam, 8, seed=0xBEEF)
            out[fam] = [{"prompt": s.prompt, "answer": s.answer,
                         "final": s.final} for s in ss]
        with open(path, "w") as f:
            json.dump(out, f)

    # decode-parity goldens: python reference decoders on trained weights
    path = art(out_dir, "golden", "decode_parity.json")
    if not os.path.exists(path):
        t_params = TC.load_params(weights["teacher_dream"])
        s_params = TC.load_params(weights["cdlm_dream"])
        a_params = TC.load_params(weights["ar_dream"])
        prompts, _, samples = TC.encode_family_batch(
            cfg, "chain-arith", 4, seed=0x60D)
        fix = {"prompts": prompts.tolist()}
        r = decoding.teacher_block_decode(cfg, t_params, prompts)
        fix["vanilla_ids"] = r.ids[:, cfg.prompt_len:].tolist()
        fix["vanilla_steps"] = r.steps.tolist()
        r = decoding.student_cdlm_decode(cfg, s_params, prompts,
                                         tau_conf=0.9)
        fix["cdlm_ids"] = r.ids[:, cfg.prompt_len:].tolist()
        fix["cdlm_steps"] = r.steps.tolist()
        gen, steps = greedy_decode(cfg, a_params, prompts)
        fix["ar_ids"] = gen.tolist()
        fix["ar_steps"] = steps.tolist()
        with open(path, "w") as f:
            json.dump(fix, f)
        print("[aot] wrote golden/decode_parity.json")


# --------------------------------------------------------------------------
# Training orchestration
# --------------------------------------------------------------------------

def eval_suite(cfg: M.ModelConfig, params, n: int = 16, seed: int = 0xF17):
    """Small validation suite: score + mean steps on chain-arith via the
    python CDLM reference decoder (drives Fig. 7 and Table 3 metrics)."""
    p, _, samples = TC.encode_family_batch(cfg, "chain-arith", n, seed)
    res = decoding.student_cdlm_decode(cfg, params, p, tau_conf=0.9)
    return {"score": decoding.score_batch(cfg, res, samples),
            "steps": float(np.mean(res.steps))}


def ensure_weights(cfg: M.ModelConfig, out_dir: str) -> dict:
    fast = TC.fast_mode()
    teacher_steps = 200 if fast else 3000
    ar_steps = 150 if fast else 1000
    cdlm_steps = 120 if fast else 300
    traj_n = 32 if fast else 96
    paths = {}
    for b in BACKBONES:
        tp = art(out_dir, f"weights_teacher_{b}.npz")
        paths[f"teacher_{b}"] = tp
        if not os.path.exists(tp):
            print(f"[aot] training teacher-{b} ({teacher_steps} steps)…",
                  flush=True)
            params, _ = train_teacher(cfg, b, teacher_steps)
            TC.save_params(tp, params)
        ap = art(out_dir, f"weights_ar_{b}.npz")
        paths[f"ar_{b}"] = ap
        if not os.path.exists(ap):
            print(f"[aot] training ar-{b} ({ar_steps} steps)…", flush=True)
            TC.save_params(ap, train_ar(cfg, b, ar_steps))
        jp = art(out_dir, f"traj_{b}.npz")
        paths[f"traj_{b}"] = jp
        if not os.path.exists(jp):
            print(f"[aot] collecting trajectories for {b} "
                  f"({traj_n} prompts x {len('xx')} temps)…", flush=True)
            t_params = TC.load_params(tp)
            traj = collect(cfg, t_params, MIXTURES[b], traj_n,
                           seed=SEEDS[b] + 300)
            traj.save(jp)
        cp = art(out_dir, f"weights_cdlm_{b}.npz")
        paths[f"cdlm_{b}"] = cp
        if not os.path.exists(cp):
            print(f"[aot] CDLM distillation for {b} "
                  f"({cdlm_steps} steps)…", flush=True)
            t_params = TC.load_params(tp)
            traj = TrajectoryDataset.load(jp)
            w_dlm = 0.01 if b == "dream" else 0.1  # paper Tables 5/6
            hook = (lambda mp: eval_suite(cfg, mp)) if b == "dream" else None
            student, hist = train_cdlm(
                cfg, t_params, traj, cdlm_steps,
                weights=(1.0, 0.5, w_dlm), seed=SEEDS[b],
                eval_hook=hook,
                eval_every=max(1, cdlm_steps // 6) if hook else None)
            TC.save_params(cp, student)
            if hist:
                with open(art(out_dir, "fig7.json"), "w") as f:
                    json.dump({"backbone": b, "history": hist}, f)
                print("[aot] wrote fig7.json")
    return paths


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force-hlo", action="store_true")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    cfg = M.ModelConfig()

    with open(art(out, "vocab.json"), "w") as f:
        f.write(vocab.to_json())

    weights = ensure_weights(cfg, out)
    entries = export_hlo(cfg, out, force=args.force_hlo)
    export_eval_sets(cfg, out)
    export_goldens(cfg, out, weights)

    manifest = {
        "geometry": {
            "vocab_size": cfg.vocab_size, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head, "d_ff": cfg.d_ff,
            "prompt_len": cfg.prompt_len, "gen_len": cfg.gen_len,
            "block_size": cfg.block_size, "seq_len": cfg.seq_len,
            "pad": vocab.PAD, "mask": vocab.MASK, "bos": vocab.BOS,
            "eos": vocab.EOS,
        },
        "weight_names": sorted(M.param_shapes(cfg)),
        "buckets": list(BUCKETS),
        "sweep_blocks": list(SWEEP_BLOCKS),
        "programs": entries,
        "models": {
            k: os.path.basename(v) for k, v in weights.items()
            if not k.startswith("traj")
        },
        "fast_mode": TC.fast_mode(),
    }
    with open(art(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written; {len(entries)} programs")


if __name__ == "__main__":
    main()
