"""Reference (python-side) decoders.

These run only at build time, for three purposes:
  1. teacher trajectory collection (Algorithm 1),
  2. validation metrics during training (Fig. 7, Table 3 convergence),
  3. golden parity with the rust decode engines (rust integration tests
     replay the same inputs and must produce identical token streams).

The rust coordinator re-implements the same policies on top of the AOT
executables; any drift is a test failure, not a judgement call.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import vocab


@dataclasses.dataclass
class DecodeResult:
    ids: np.ndarray          # [bs, S] final sequences
    steps: np.ndarray        # [bs] refinement steps executed (per sample)
    gen_len: np.ndarray      # [bs] valid generated tokens (pre-<eos>)
    trace: list | None = None  # optional per-step trace (trajectories)


def _prep(cfg: M.ModelConfig, prompts: np.ndarray) -> np.ndarray:
    """[bs, P] prompts -> [bs, S] with the generation span masked."""
    bs = prompts.shape[0]
    gen = np.full((bs, cfg.gen_len), vocab.MASK, np.int32)
    return np.concatenate([prompts, gen], axis=1)


def _valid_from(prompts: np.ndarray) -> np.ndarray:
    """First non-pad index per row (prompts are left-padded)."""
    is_pad = prompts == vocab.PAD
    # index of first non-pad; all-pad rows are invalid inputs
    return is_pad.argmin(axis=1).astype(np.int32)


def _gen_length(row: np.ndarray) -> int:
    """Valid tokens before the first <eos> (paper §A.3 accounting)."""
    eos = np.nonzero(row == vocab.EOS)[0]
    end = int(eos[0]) if len(eos) else len(row)
    return int(np.sum(row[:end] != vocab.MASK))


def sample_tokens(logits: jnp.ndarray, temperature: float, key):
    """Greedy (tau=0) or temperature sampling; returns (tok, conf) where
    conf is the softmax probability of the chosen token."""
    lg = logits.astype(jnp.float32)
    probs = jax.nn.softmax(lg, axis=-1)
    if temperature <= 0.0:
        tok = jnp.argmax(lg, axis=-1)
    else:
        tok = jax.random.categorical(key, lg / temperature, axis=-1)
    conf = jnp.take_along_axis(probs, tok[..., None], axis=-1)[..., 0]
    return tok.astype(jnp.int32), conf


def teacher_block_decode(cfg: M.ModelConfig, params, prompts: np.ndarray,
                         temperature: float = 0.0, seed: int = 0,
                         collect: bool = False,
                         steps_per_block: int | None = None) -> DecodeResult:
    """Block-wise decoding with the bidirectional teacher.

    The paper's most-performant teacher operating point (§4.1): N = Lg
    total steps, exactly one (top-confidence) token finalized per step,
    restricted to the active block. ``steps_per_block`` < B gives the
    naive step-truncation baseline of Table 4 (finalize top-m per step).

    When ``collect``, returns per-step (position, token, hidden) tuples —
    the raw material of the trajectory dataset (Algorithm 1).
    """
    bs = prompts.shape[0]
    P, B, S = cfg.prompt_len, cfg.block_size, cfg.seq_len
    spb = B if steps_per_block is None else steps_per_block
    ids = _prep(cfg, prompts)
    vf = jnp.asarray(_valid_from(prompts))
    key = jax.random.PRNGKey(seed)
    steps = np.zeros(bs, np.int64)
    trace: list[list[tuple[int, int, np.ndarray]]] = [[] for _ in range(bs)]

    fwd = jax.jit(lambda p, i: M.forward_full(
        cfg, p, i,
        (jnp.arange(S)[None, None, :] >= vf[:, None, None])
        & jnp.ones((bs, S, 1), bool),
        collect_hidden=True))

    jids = jnp.asarray(ids)
    for b in range(cfg.num_blocks):
        lo, hi = P + b * B, P + (b + 1) * B
        for _ in range(spb):
            masked = jids[:, lo:hi] == vocab.MASK
            if not bool(masked.any()):
                break
            logits, hidden = fwd(params, jids)
            key, sub = jax.random.split(key)
            tok, conf = sample_tokens(logits[:, lo:hi, :], temperature, sub)
            # finalize the top-m highest-confidence masked positions
            m = max(1, int(np.ceil(B / spb)))
            conf = jnp.where(masked, conf, -1.0)
            order = jnp.argsort(-conf, axis=-1)[:, :m]  # [bs, m]
            take = jnp.zeros_like(masked).at[
                jnp.arange(bs)[:, None], order].set(True) & masked
            new_blk = jnp.where(take, tok, jids[:, lo:hi])
            jids = jids.at[:, lo:hi].set(new_blk)
            steps += 1
            if collect:
                h_np = np.asarray(hidden[:, lo:hi, :])
                take_np = np.asarray(take)
                tok_np = np.asarray(tok)
                for r in range(bs):
                    for j in np.nonzero(take_np[r])[0]:
                        trace[r].append(
                            (lo + int(j), int(tok_np[r, j]), h_np[r, j]))
    ids = np.asarray(jids)
    gl = np.array([_gen_length(ids[r, P:]) for r in range(bs)])
    return DecodeResult(ids, steps, gl, trace if collect else None)


def student_cdlm_decode(cfg: M.ModelConfig, params, prompts: np.ndarray,
                        tau_conf: float = 0.9,
                        block_size: int | None = None) -> DecodeResult:
    """Reference CDLM inference (paper §4.3): block-causal student with
    exact KV caching, confidence-thresholded parallel finalization, and
    <eos> early stopping at block boundaries.

    This mirrors the rust `methods/cdlm.rs` engine step for step; parity
    is enforced by integration tests. ``block_size`` may differ from the
    training block (Fig. 8 sensitivity sweep) as long as it divides Lg.
    """
    bs = prompts.shape[0]
    P, Lg, S = cfg.prompt_len, cfg.gen_len, cfg.seq_len
    B = cfg.block_size if block_size is None else block_size
    assert Lg % B == 0
    nblocks = Lg // B
    vf = jnp.asarray(_valid_from(prompts))

    prefill = jax.jit(lambda p, i, v: M.student_prefill(cfg, p, i, v))
    step_fn = jax.jit(lambda p, kc, vc, cl, v, blk, pos: M.student_block_step(
        cfg, p, kc, vc, cl, v, blk, pos))

    k_blkcache, v_blkcache = prefill(params, jnp.asarray(prompts), vf)
    # full-size cache buffers [L, bs, H, S, dh], prompt KV installed
    L, _, H, _, dh = k_blkcache.shape
    k_cache = jnp.zeros((L, bs, H, S, dh), jnp.float32)
    v_cache = jnp.zeros((L, bs, H, S, dh), jnp.float32)
    k_cache = k_cache.at[:, :, :, :P, :].set(k_blkcache)
    v_cache = v_cache.at[:, :, :, :P, :].set(v_blkcache)

    gen = np.full((bs, Lg), vocab.MASK, np.int32)
    steps = np.zeros(bs, np.int64)
    done = np.zeros(bs, bool)
    cache_len = P
    for b in range(nblocks):
        lo = b * B
        active = ~done
        if not active.any():
            break
        blk = jnp.asarray(gen[:, lo:lo + B])
        while True:
            masked = np.asarray(blk) == vocab.MASK
            if not masked[active].any():
                break
            _, tok, conf, kb, vb = step_fn(
                params, k_cache, v_cache, jnp.int32(cache_len), vf, blk,
                jnp.int32(P + lo))
            steps[active] += 1
            tok_np, conf_np = np.asarray(tok), np.asarray(conf)
            for r in np.nonzero(active)[0]:
                mrow = masked[r]
                if not mrow.any():
                    continue
                sel = mrow & (conf_np[r] >= tau_conf)
                if not sel.any():
                    # always finalize at least the most confident token
                    cand = np.where(mrow, conf_np[r], -1.0)
                    sel = np.zeros_like(mrow)
                    sel[int(cand.argmax())] = True
                row = np.array(blk[r])  # copy: jax arrays are read-only
                row[sel] = tok_np[r][sel]
                blk = blk.at[r].set(jnp.asarray(row))
            gen[:, lo:lo + B] = np.asarray(blk)
        # commit: one extra pass over the finalized block so the cache
        # holds KV of the *final* tokens (exact caching; DESIGN.md §7)
        _, _, _, kb, vb = step_fn(
            params, k_cache, v_cache, jnp.int32(cache_len), vf, blk,
            jnp.int32(P + lo))
        k_cache = k_cache.at[:, :, :, cache_len:cache_len + B, :].set(kb)
        v_cache = v_cache.at[:, :, :, cache_len:cache_len + B, :].set(vb)
        cache_len += B
        # early stop: a finalized <eos> inside the block ends the request
        for r in range(bs):
            if not done[r] and (gen[r, lo:lo + B] == vocab.EOS).any():
                done[r] = True
    ids = np.concatenate([prompts, gen], axis=1)
    gl = np.array([_gen_length(gen[r]) for r in range(bs)])
    return DecodeResult(ids, steps, gl)


def score_batch(cfg: M.ModelConfig, res: DecodeResult, samples) -> float:
    """Exact-match accuracy over decoded answers (tasks.score protocol)."""
    from . import tasks
    P = cfg.prompt_len
    n_ok = 0
    for r, s in enumerate(samples):
        text = vocab.decode(res.ids[r, P:])
        n_ok += bool(tasks.score(text, s))
    return n_ok / max(1, len(samples))
