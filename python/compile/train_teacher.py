"""Teacher pretraining: bidirectional masked-diffusion objective (Eq. 6).

Produces the two backbones of the paper's evaluation:
  dream-tiny   uniform mixture over all four task families (stand-in for
               Dream-7B-Instruct trained on the Bespoke-derived subset);
  llada-tiny   math-augmented mixture — 2x weight on the arithmetic
               families, mirroring the paper's LLaDA corpus augmentation
               with 7.5k math-style DParallel prompts (§5.2.2, A.1).

Run via ``python -m compile.train_teacher --backbone dream`` (aot.py
drives this as part of ``make artifacts``).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import decoding
from . import model as M
from . import train_common as TC

MIXTURES = {
    "dream": {"chain-arith": 1.0, "deep-arith": 1.0,
              "str-transform": 1.0, "list-op": 1.0},
    "llada": {"chain-arith": 2.0, "deep-arith": 2.0,
              "str-transform": 1.0, "list-op": 1.0},
}
SEEDS = {"dream": 0, "llada": 1}


def train_teacher(cfg: M.ModelConfig, backbone: str, steps: int,
                  batch_size: int = 16, lr: float = 1e-3,
                  corpus_n: int = 4096, log_every: int = 100,
                  eval_every: int | None = None, eval_n: int = 32):
    seed = SEEDS[backbone]
    prompts, answers, _ = TC.make_corpus(
        cfg, MIXTURES[backbone], corpus_n, seed=seed + 100)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = TC.AdamW(lr, total_steps=steps, weight_decay=0.01)
    ost = opt.init(params)

    @jax.jit
    def step_fn(params, ost, p, a, key):
        loss, grads = jax.value_and_grad(
            lambda pp: TC.dlm_loss(cfg, pp, p, a, key))(params)
        params, ost = opt.update(params, grads, ost)
        return params, ost, loss

    key = jax.random.PRNGKey(seed + 7)
    rng = np.random.RandomState(seed + 13)
    t0 = time.time()
    history = []
    for it in range(steps):
        sel = rng.randint(0, len(prompts), batch_size)
        key, sub = jax.random.split(key)
        params, ost, loss = step_fn(
            params, ost, jnp.asarray(prompts[sel]), jnp.asarray(answers[sel]),
            sub)
        if (it + 1) % log_every == 0:
            print(f"[teacher-{backbone}] step {it+1}/{steps} "
                  f"loss {float(loss):.4f} ({time.time()-t0:.0f}s)",
                  flush=True)
        if eval_every and (it + 1) % eval_every == 0:
            acc = quick_eval(cfg, params, eval_n, seed=seed + 999)
            history.append({"step": it + 1, "acc": acc})
            print(f"[teacher-{backbone}] eval acc {acc:.3f}", flush=True)
    return params, history


def quick_eval(cfg: M.ModelConfig, params, n: int, seed: int,
               family: str = "chain-arith") -> float:
    p, _, samples = TC.encode_family_batch(cfg, family, n, seed)
    res = decoding.teacher_block_decode(cfg, params, p)
    return decoding.score_batch(cfg, res, samples)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backbone", choices=("dream", "llada"), required=True)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cfg = M.ModelConfig()
    steps = args.steps or (150 if TC.fast_mode() else 1200)
    params, _ = train_teacher(cfg, args.backbone, steps)
    acc = quick_eval(cfg, params, 64, seed=4242)
    print(f"[teacher-{args.backbone}] final chain-arith acc {acc:.3f}")
    out = args.out or f"../artifacts/weights_teacher_{args.backbone}.npz"
    TC.save_params(out, params)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
