"""Toy character-level vocabulary shared between the python build path and
the rust request path.

The vocabulary is the single source of truth for token ids. ``aot.py``
exports it to ``artifacts/vocab.json``; the rust ``tokenizer`` module loads
that file, and golden tests on both sides pin the mapping.

Layout (V = 64):
  0     <pad>     left-padding for prompts / right-padding for answers
  1     <mask>    the DLM [MASK] token
  2     <bos>     prompt start marker
  3     <eos>     answer terminator (early-stop trigger, paper §4.3)
  4..13 digits '0'..'9'
  14..39 lowercase 'a'..'z'
  40..  symbols '+ - * = ; # : ? ( ) , . > < [ ]' and space
  rest  reserved (never produced)
"""

from __future__ import annotations

import json

PAD, MASK, BOS, EOS = 0, 1, 2, 3

_SYMBOLS = "+-*=;#:?(),.><[] "

VOCAB_SIZE = 64


def _build_tables():
    tok_to_id = {"<pad>": PAD, "<mask>": MASK, "<bos>": BOS, "<eos>": EOS}
    idx = 4
    for ch in "0123456789":
        tok_to_id[ch] = idx
        idx += 1
    for o in range(26):
        tok_to_id[chr(ord("a") + o)] = idx
        idx += 1
    for ch in _SYMBOLS:
        tok_to_id[ch] = idx
        idx += 1
    assert idx <= VOCAB_SIZE, f"vocab overflow: {idx} > {VOCAB_SIZE}"
    id_to_tok = {v: k for k, v in tok_to_id.items()}
    return tok_to_id, id_to_tok


TOK_TO_ID, ID_TO_TOK = _build_tables()


def encode(text: str) -> list[int]:
    """Encode a string to token ids. Raises on unknown characters."""
    return [TOK_TO_ID[ch] for ch in text]


def decode(ids, stop_at_eos: bool = True) -> str:
    """Decode token ids back to a string.

    Special tokens are dropped; decoding stops at the first <eos> when
    ``stop_at_eos`` (mirrors the paper's generation-length accounting,
    §A.3: valid tokens exclude <endoftext> and anything after it).
    """
    out = []
    for i in ids:
        i = int(i)
        if i == EOS and stop_at_eos:
            break
        if i in (PAD, MASK, BOS, EOS):
            continue
        out.append(ID_TO_TOK.get(i, "?"))
    return "".join(out)


def to_json() -> str:
    """Serialize the vocab for the rust tokenizer (artifacts/vocab.json)."""
    return json.dumps(
        {
            "vocab_size": VOCAB_SIZE,
            "pad": PAD,
            "mask": MASK,
            "bos": BOS,
            "eos": EOS,
            "id_to_tok": {str(k): v for k, v in ID_TO_TOK.items()},
        },
        indent=1,
    )
