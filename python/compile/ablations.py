"""Ablation sweeps (paper Table 3): loss-weight composition.

Trains six short CDLM students on the dream backbone with the paper's
weight grid and records (score, steps-to-convergence) on the validation
suite — the same two quantities Table 3 reports. Results land in
``artifacts/ablations/table3.json``; the rust bench
``table3_loss_weights`` formats them as the paper table.

Run via ``make ablations`` (not part of the default build: it retrains
six students).
"""

from __future__ import annotations

import argparse
import json
import os

from . import model as M
from . import train_common as TC
from .aot import eval_suite
from .train_cdlm import train_cdlm
from .trajectory import TrajectoryDataset

# (w_distill, w_cons, w_dlm) — rows of paper Table 3 ('X' -> 0.0)
GRID = [
    (1.0, 0.0, 0.01),
    (0.0, 1.0, 0.01),
    (1.0, 1.0, 0.01),
    (1.0, 1.0, 0.0),
    (1.0, 0.1, 0.01),
    (1.0, 0.1, 0.0),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    cfg = M.ModelConfig()
    steps = args.steps or (40 if TC.fast_mode() else 120)
    teacher = TC.load_params(os.path.join(args.out, "weights_teacher_dream.npz"))
    traj = TrajectoryDataset.load(os.path.join(args.out, "traj_dream.npz"))
    rows = []
    for (wd, wc, wm) in GRID:
        print(f"[ablation] training w=({wd}, {wc}, {wm}) for {steps} steps",
              flush=True)
        student, _ = train_cdlm(cfg, teacher, traj, steps,
                                weights=(wd, wc, wm), seed=7, log_every=100)
        m = eval_suite(cfg, student, n=24)
        m_math = m
        m_code = eval_suite(cfg, student, n=24, seed=0xC0DE)
        rows.append({
            "w_distill": wd, "w_cons": wc, "w_dlm": wm,
            "score": m_math["score"] * 100.0,
            "steps_to_convergence": m_math["steps"],
            "score_alt": m_code["score"] * 100.0,
            "steps_alt": m_code["steps"],
        })
        print(f"[ablation] -> {rows[-1]}", flush=True)
    os.makedirs(os.path.join(args.out, "ablations"), exist_ok=True)
    with open(os.path.join(args.out, "ablations", "table3.json"), "w") as f:
        json.dump({"steps": steps, "rows": rows}, f, indent=1)
    print("[ablation] wrote ablations/table3.json")


if __name__ == "__main__":
    main()
