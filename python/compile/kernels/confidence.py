"""L1 Pallas kernel: fused confidence head for thresholded finalization.

After each refinement step the coordinator needs, for every masked
position of the active block, the greedy token and its softmax
probability (the paper's token-level confidence, §4.3 / Fast-dLLM). Doing
this on-device fuses the softmax + argmax into the decode executable, so
the rust hot path never sees raw logits unless it asks for them.

Numerically this is a single-pass max / log-sum-exp: conf = exp(max - lse).
Oracle: ``ref.ref_confidence``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conf_kernel(lg_ref, tok_ref, conf_ref):
    """One grid cell per block position: [1, V] logits -> token + conf."""
    lg = lg_ref[0].astype(jnp.float32)  # [V]
    m = jnp.max(lg)
    tok_ref[0] = jnp.argmax(lg).astype(jnp.int32)
    lse = m + jnp.log(jnp.sum(jnp.exp(lg - m)))
    conf_ref[0] = jnp.exp(m - lse)


@jax.jit
def confidence(logits):
    """Greedy token + confidence per position.

    logits [B, V] -> (tok int32 [B], conf float32 [B]).
    """
    B, V = logits.shape
    return pl.pallas_call(
        _conf_kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, V), lambda b: (b, 0))],
        out_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        interpret=True,
    )(logits)


def confidence_batched(logits):
    """vmap over a leading batch dim: [bs, B, V] -> ([bs, B], [bs, B])."""
    return jax.vmap(confidence)(logits)
