"""L1 Pallas kernel: fused block-causal cached attention.

This is the serving hot-spot of CDLM decoding: at every refinement step of
the active block, the block's queries attend to (i) the exact KV cache of
the prompt and all previously committed blocks and (ii) the freshly
computed K/V of the active block itself (fully bidirectional within the
block, paper Fig. 2).

Hardware adaptation (paper targets A100 CUDA; we restate for a TPU-style
memory hierarchy — DESIGN.md §3):

* The KV cache lives in HBM and is streamed into VMEM in
  ``(KV_TILE, dh)`` tiles by an **online-softmax (flash-style) loop**; the
  tiny active-block Q tile stays VMEM-resident for the whole kernel. This
  is the BlockSpec/fori_loop expression of the paper's "amortize one
  weight/cache load over B tokens" argument (§5.4): arithmetic intensity
  scales with the block size because the same tiles feed B query rows.
* Matmuls accumulate in f32 (MXU-style), scores are masked with an
  iota-vs-scalar comparison (no materialized [S, S] masks).
* ``interpret=True`` is mandatory here: we run on CPU PJRT, and real TPU
  lowering would emit a Mosaic custom-call the CPU plugin cannot execute.

Correctness oracle: ``ref.ref_block_attn`` (pytest + hypothesis sweeps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# Default HBM->VMEM streaming tile along the cache length dimension.
# 32 keeps (KV_TILE x dh) aligned to the 8x128-lane vector layout when
# scaled to real TPU shapes; at our toy geometry it gives 3 tiles over a
# 96-slot cache, which exercises the online-softmax carry logic.
DEFAULT_KV_TILE = 32


def _attn_kernel(cache_len_ref, valid_from_ref, excl_ref, q_ref, kc_ref,
                 vc_ref, kb_ref, vb_ref, o_ref, *, kv_tile: int,
                 sm_scale: float, intra_causal: bool):
    """One (head,) grid cell: online-softmax attention over cache tiles
    followed by the active-block tile.

    Ref shapes (leading head dim of 1 from the BlockSpec):
      q_ref, kb_ref, vb_ref: [1, B, dh]   o_ref: [1, B, dh]
      kc_ref, vc_ref:        [1, T, dh]
      cache_len_ref, valid_from_ref: [1] int32; excl_ref: [2] int32
      (SMEM-style scalar operands: exclusion window start/len)
    """
    B = q_ref.shape[1]
    dh = q_ref.shape[2]
    T = kc_ref.shape[1]
    num_tiles = T // kv_tile

    cache_len = cache_len_ref[0]
    valid_from = valid_from_ref[0]
    excl_start = excl_ref[0]
    excl_end = excl_ref[0] + excl_ref[1]

    q = q_ref[0].astype(jnp.float32) * sm_scale  # [B, dh] VMEM-resident

    # Online-softmax carries: running max, running denominator, accum.
    m0 = jnp.full((B,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B,), jnp.float32)
    acc0 = jnp.zeros((B, dh), jnp.float32)

    def tile_step(t, carry):
        m, l, acc = carry
        base = t * kv_tile
        k = kc_ref[0, pl.ds(base, kv_tile), :].astype(jnp.float32)
        v = vc_ref[0, pl.ds(base, kv_tile), :].astype(jnp.float32)
        s = q @ k.T  # [B, kv_tile]
        idx = base + jax.lax.iota(jnp.int32, kv_tile)
        valid = (idx >= valid_from) & (idx < cache_len)
        valid &= ~((idx >= excl_start) & (idx < excl_end))
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_tiles, tile_step, (m0, l0, acc0))

    # Final tile: the active block itself. Fully visible for DLM-style
    # block attention; lower-triangular when `intra_causal` (the AR
    # verify path of the speculative-decoding extension, Appendix C).
    kb = kb_ref[0].astype(jnp.float32)
    vb = vb_ref[0].astype(jnp.float32)
    s = q @ kb.T  # [B, B]
    if intra_causal:
        qi = jax.lax.iota(jnp.int32, B)
        s = jnp.where(qi[None, :] <= qi[:, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc = acc * corr[:, None] + p @ vb

    o_ref[0] = acc / l_new[:, None]


def pick_kv_tile(T: int, preferred: int = DEFAULT_KV_TILE) -> int:
    """Largest power-of-two tile <= preferred that divides the cache
    length (toy geometries in tests are not always multiples of 32)."""
    t = preferred
    while t > 1 and T % t != 0:
        t //= 2
    return t


@functools.partial(jax.jit, static_argnames=("kv_tile", "intra_causal"))
def block_attn(q, k_cache, v_cache, k_blk, v_blk, cache_len, valid_from,
               excl_start=0, excl_len=0, kv_tile: int | None = None,
               intra_causal: bool = False):
    """Fused block-causal cached attention (single sequence).

    Args:
      q, k_blk, v_blk: [H, B, dh] — active-block queries / fresh K / V.
      k_cache, v_cache: [H, T, dh] — committed KV cache (padded to T;
        T must be a multiple of ``kv_tile``).
      cache_len: int32 scalar — #valid cache slots (prefix semantics).
      valid_from: int32 scalar — first valid slot (left-pad masking).
      excl_start, excl_len: int32 scalars — cache slots to hide (the
        Fast-dLLM dual-cache stale copy of the active block).

    Returns: o [H, B, dh] float32.
    """
    H, B, dh = q.shape
    T = k_cache.shape[1]
    if kv_tile is None:
        kv_tile = pick_kv_tile(T)
    if T % kv_tile != 0:
        raise ValueError(f"cache length {T} not a multiple of kv_tile {kv_tile}")
    sm_scale = 1.0 / (dh ** 0.5)
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape(1)
    valid_from = jnp.asarray(valid_from, jnp.int32).reshape(1)
    excl = jnp.stack([jnp.asarray(excl_start, jnp.int32),
                      jnp.asarray(excl_len, jnp.int32)])

    head_spec = lambda shape: pl.BlockSpec(shape, lambda h: (h, 0, 0))
    return pl.pallas_call(
        functools.partial(_attn_kernel, kv_tile=kv_tile, sm_scale=sm_scale,
                          intra_causal=intra_causal),
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),        # cache_len
            pl.BlockSpec((1,), lambda h: (0,)),        # valid_from
            pl.BlockSpec((2,), lambda h: (0,)),        # excl window
            head_spec((1, B, dh)),                      # q
            head_spec((1, T, dh)),                      # k_cache
            head_spec((1, T, dh)),                      # v_cache
            head_spec((1, B, dh)),                      # k_blk
            head_spec((1, B, dh)),                      # v_blk
        ],
        out_specs=head_spec((1, B, dh)),
        out_shape=jax.ShapeDtypeStruct((H, B, dh), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(cache_len, valid_from, excl, q, k_cache, v_cache, k_blk, v_blk)


def block_attn_batched(q, k_cache, v_cache, k_blk, v_blk, cache_len,
                       valid_from, excl_start=0, excl_len=0,
                       kv_tile: int | None = None,
                       intra_causal: bool = False):
    """vmap of :func:`block_attn` over a leading batch dimension.

    q/k_blk/v_blk [bs, H, B, dh]; k_cache/v_cache [bs, H, T, dh];
    cache_len scalar (shared decode phase); valid_from [bs] (per-sequence
    left padding); exclusion window shared.
    """
    fn = functools.partial(block_attn, kv_tile=kv_tile,
                           intra_causal=intra_causal)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, None, 0, None, None))(
        q, k_cache, v_cache, k_blk, v_blk, cache_len, valid_from,
        excl_start, excl_len)
