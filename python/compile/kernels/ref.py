"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest (with hypothesis sweeps over
shapes / cache lengths / dtypes) asserts the Pallas kernels in
``block_attn.py`` and ``confidence.py`` match these to tight tolerances.
They are also used directly by the teacher model (full bidirectional
attention is not the serving hot-spot, so it stays as plain jnp / XLA).
"""

from __future__ import annotations

import jax.nn
import jax.numpy as jnp

NEG_INF = -1e30


def ref_block_attn(q, k_cache, v_cache, k_blk, v_blk, cache_len, valid_from,
                   sm_scale=None, excl_start=0, excl_len=0,
                   intra_causal=False):
    """Reference block-causal cached attention for one sequence.

    The active block's queries attend to
      * cache positions ``valid_from <= idx < cache_len`` (prompt +
        previously committed blocks; left-pad positions below
        ``valid_from`` are masked), minus an optional exclusion window
        ``[excl_start, excl_start + excl_len)`` — used by the Fast-dLLM
        dual-cache baseline, whose *stale* full-sequence cache must not
        shadow the freshly computed active block, and
      * every position of the active block itself (within-block attention
        is fully bidirectional — the defining property of block-causal
        DLMs, paper Fig. 2).

    Shapes: q/k_blk/v_blk [H, B, dh]; k_cache/v_cache [H, T, dh].
    Returns o [H, B, dh] (f32).
    """
    H, B, dh = q.shape
    T = k_cache.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / jnp.sqrt(dh)
    q = q.astype(jnp.float32) * scale
    sc = jnp.einsum("hbd,htd->hbt", q, k_cache.astype(jnp.float32))
    sb = jnp.einsum("hbd,hkd->hbk", q, k_blk.astype(jnp.float32))
    idx = jnp.arange(T)
    mask_c = (idx >= valid_from) & (idx < cache_len)
    mask_c &= ~((idx >= excl_start) & (idx < excl_start + excl_len))
    sc = jnp.where(mask_c[None, None, :], sc, NEG_INF)
    if intra_causal:
        qi = jnp.arange(B)
        sb = jnp.where(qi[None, None, :] <= qi[None, :, None], sb, NEG_INF)
    s = jnp.concatenate([sc, sb], axis=-1)  # [H, B, T+B]
    p = jax.nn.softmax(s, axis=-1)
    v = jnp.concatenate(
        [v_cache.astype(jnp.float32), v_blk.astype(jnp.float32)], axis=1
    )
    return jnp.einsum("hbt,htd->hbd", p, v)


def ref_confidence(logits):
    """Reference confidence head: per-position greedy token + probability.

    logits [..., V] -> (tok i32 [...], conf f32 [...]) where conf is the
    softmax probability of the argmax token (the paper's token-level
    confidence for thresholded parallel finalization, §4.3).
    """
    lg = logits.astype(jnp.float32)
    tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    m = jnp.max(lg, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1))
    conf = jnp.exp(m - lse)
    return tok, conf


def ref_masked_attention(x_q, x_kv, mask):
    """Generic masked attention used by model-level tests.

    x_q [Sq, H, dh], x_kv [Sk, H, dh], mask [Sq, Sk] boolean.
    """
    Sq, H, dh = x_q.shape
    scale = 1.0 / jnp.sqrt(dh)
    s = jnp.einsum("qhd,khd->hqk", x_q.astype(jnp.float32) * scale,
                   x_kv.astype(jnp.float32))
    s = jnp.where(mask[None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, x_kv.astype(jnp.float32))
