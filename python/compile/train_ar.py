"""Autoregressive baseline training (paper Fig. 3 / §5.2.3).

Equal-size causal transformer trained with next-token prediction on the
same corpus as its DLM counterpart (stand-ins for Qwen2.5-7B-Instruct /
Llama-3.1-8B-Instruct, which cannot be downloaded here).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import train_common as TC
from . import vocab
from .train_teacher import MIXTURES, SEEDS


def train_ar(cfg: M.ModelConfig, backbone: str, steps: int,
             batch_size: int = 16, lr: float = 1e-3, corpus_n: int = 4096,
             log_every: int = 100):
    seed = SEEDS[backbone] + 50
    prompts, answers, _ = TC.make_corpus(
        cfg, MIXTURES[backbone], corpus_n, seed=seed + 100)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = TC.AdamW(lr, total_steps=steps, weight_decay=0.01)
    ost = opt.init(params)

    @jax.jit
    def step_fn(params, ost, p, a):
        loss, grads = jax.value_and_grad(
            lambda pp: TC.ar_loss(cfg, pp, p, a))(params)
        params, ost = opt.update(params, grads, ost)
        return params, ost, loss

    rng = np.random.RandomState(seed + 13)
    t0 = time.time()
    for it in range(steps):
        sel = rng.randint(0, len(prompts), batch_size)
        params, ost, loss = step_fn(
            params, ost, jnp.asarray(prompts[sel]), jnp.asarray(answers[sel]))
        if (it + 1) % log_every == 0:
            print(f"[ar-{backbone}] step {it+1}/{steps} "
                  f"loss {float(loss):.4f} ({time.time()-t0:.0f}s)",
                  flush=True)
    return params


def greedy_decode(cfg: M.ModelConfig, params, prompts: np.ndarray):
    """Reference greedy AR decoding (parity oracle for rust methods/ar.rs)."""
    bs = prompts.shape[0]
    P, Lg, S = cfg.prompt_len, cfg.gen_len, cfg.seq_len
    vf = jnp.argmin(jnp.asarray(prompts) == vocab.PAD, axis=1).astype(jnp.int32)
    pre = jax.jit(lambda p, i, v: M.ar_prefill(cfg, p, i, v))
    stp = jax.jit(lambda p, kc, vc, cl, v, t: M.ar_step(cfg, p, kc, vc, cl, v, t))
    _, tok, _, k, v = pre(params, jnp.asarray(prompts), vf)
    L, _, H, _, dh = k.shape
    k_cache = jnp.zeros((L, bs, H, S, dh), jnp.float32).at[:, :, :, :P].set(k)
    v_cache = jnp.zeros((L, bs, H, S, dh), jnp.float32).at[:, :, :, :P].set(v)
    gen = np.full((bs, Lg), vocab.PAD, np.int32)
    done = np.zeros(bs, bool)
    steps = np.zeros(bs, np.int64)
    cur = tok
    for i in range(Lg):
        gen[~done, i] = np.asarray(cur)[~done]
        steps[~done] += 1
        done |= np.asarray(cur) == vocab.EOS
        if done.all() or i == Lg - 1:
            break
        _, tok, _, k1, v1 = stp(params, k_cache, v_cache, jnp.int32(P + i),
                                vf, cur)
        k_cache = k_cache.at[:, :, :, P + i:P + i + 1].set(k1)
        v_cache = v_cache.at[:, :, :, P + i:P + i + 1].set(v1)
        cur = tok
    return gen, steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backbone", choices=("dream", "llada"), required=True)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cfg = M.ModelConfig()
    steps = args.steps or (150 if TC.fast_mode() else 1000)
    params = train_ar(cfg, args.backbone, steps)
    # quick accuracy probe
    from . import tasks
    p, _, samples = TC.encode_family_batch(cfg, "chain-arith", 32, 4242)
    gen, _ = greedy_decode(cfg, params, p)
    acc = np.mean([tasks.score(vocab.decode(gen[r]), samples[r])
                   for r in range(len(samples))])
    print(f"[ar-{args.backbone}] chain-arith acc {acc:.3f}")
    out = args.out or f"../artifacts/weights_ar_{args.backbone}.npz"
    TC.save_params(out, params)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
