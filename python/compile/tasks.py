"""Synthetic benchmark task families.

The paper evaluates on GSM8K(-CoT), MATH, HumanEval and MBPP. Those need
real model downloads and an execution sandbox, neither of which exists
here (repro band 0), so we substitute four procedurally generated,
deterministically scorable families that preserve the *structure* the
paper's evaluation exercises:

  chain-arith   GSM8K-like: multi-step arithmetic with a chain-of-thought
                (intermediate equations) before the final answer.
  deep-arith    MATH-like: deeper nesting / more steps, harder mix.
  str-transform HumanEval-like: deterministic string manipulation,
                scored 0-shot by "executing" the spec (exact output match
                plays the role of pass@1).
  list-op       MBPP-like: list/digit-sequence operations, 0-shot.

Answer format: a CoT of ``lhs=rhs;`` steps (arith families) followed by
``#<answer>`` and <eos>. Scoring extracts the text after the final '#'
(before ';' or <eos>) and exact-matches against the reference — the same
"truncate at stop-sequence, then exact-match / execute" protocol as
lm-eval-harness (§A.3).

All generation is driven by SplitMix64 so the rust `workload` module can
reproduce byte-identical prompt sets (golden files pin this).
"""

from __future__ import annotations

from dataclasses import dataclass

from . import vocab

FAMILIES = ("chain-arith", "deep-arith", "str-transform", "list-op")

# Mapping used in docs/benches: paper benchmark -> our family.
PAPER_ANALOGUE = {
    "chain-arith": "GSM8K-CoT",
    "deep-arith": "MATH",
    "str-transform": "HumanEval",
    "list-op": "MBPP",
}


class SplitMix64:
    """Deterministic RNG, mirrored exactly in rust/src/util/rng.rs."""

    MASK = (1 << 64) - 1

    def __init__(self, seed: int):
        self.state = seed & self.MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & self.MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & self.MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & self.MASK
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        """Uniform in [0, n) (mod bias negligible for tiny n)."""
        return self.next_u64() % n


@dataclass
class Sample:
    prompt: str  # raw prompt text (no few-shot prefix)
    answer: str  # reference CoT + '#ans' (no <eos>)
    final: str   # reference final answer (text after '#')


def _gen_chain_arith(rng: SplitMix64) -> Sample:
    """a*b+c or a+b*c style two-step problems, single-digit operands."""
    a, b, c = rng.below(5) + 1, rng.below(5) + 1, rng.below(9) + 1
    if rng.below(2) == 0:
        # a*b+c  -> p=a*b ; r=p+c
        p = a * b
        r = p + c
        prompt = f"q:{a}*{b}+{c}=?"
        answer = f"{a}*{b}={p};{p}+{c}={r};#{r}"
    else:
        # a+b*c with CoT evaluating the product first
        b2, c2 = rng.below(5) + 1, rng.below(5) + 1
        p = b2 * c2
        r = a + p
        prompt = f"q:{a}+{b2}*{c2}=?"
        answer = f"{b2}*{c2}={p};{a}+{p}={r};#{r}"
    return Sample(prompt, answer, answer.rsplit("#", 1)[1])


def _gen_deep_arith(rng: SplitMix64) -> Sample:
    """((a+b)*c-d): three chained steps, slightly larger intermediates."""
    a, b = rng.below(6) + 1, rng.below(6) + 1
    c = rng.below(3) + 2
    s1 = a + b
    s2 = s1 * c
    d = rng.below(min(s2, 9)) + 1
    s3 = s2 - d
    prompt = f"q:(({a}+{b})*{c}-{d})=?"
    answer = f"{a}+{b}={s1};{s1}*{c}={s2};{s2}-{d}={s3};#{s3}"
    return Sample(prompt, answer, str(s3))


_WORDS = [
    "cat", "dog", "sun", "map", "key", "box", "fig", "hat", "ink", "jar",
    "kit", "log", "mud", "net", "oak", "pie", "rug", "saw", "tin", "urn",
]


def _gen_str_transform(rng: SplitMix64) -> Sample:
    """rev(w) or dup(w): deterministic string ops, 0-shot."""
    w = _WORDS[rng.below(len(_WORDS))] + chr(ord("a") + rng.below(26))
    if rng.below(2) == 0:
        prompt = f"q:rev({w})=?"
        out = w[::-1]
    else:
        prompt = f"q:dup({w})=?"
        out = w + w
    return Sample(prompt, f"#{out}", out)


def _gen_list_op(rng: SplitMix64) -> Sample:
    """sort/max/min over a 5-digit sequence, 0-shot."""
    digits = [rng.below(10) for _ in range(5)]
    s = "".join(str(d) for d in digits)
    k = rng.below(3)
    if k == 0:
        prompt = f"q:sort({s})=?"
        out = "".join(sorted(s))
    elif k == 1:
        prompt = f"q:max({s})=?"
        out = str(max(digits))
    else:
        prompt = f"q:min({s})=?"
        out = str(min(digits))
    return Sample(prompt, f"#{out}", out)


_GENERATORS = {
    "chain-arith": _gen_chain_arith,
    "deep-arith": _gen_deep_arith,
    "str-transform": _gen_str_transform,
    "list-op": _gen_list_op,
}

# Few-shot protocol mirrors the paper: few-shot for math, 0-shot for
# "coding" (str-transform / list-op). Shots are drawn from a fixed stream.
NUM_SHOTS = {"chain-arith": 1, "deep-arith": 1, "str-transform": 0, "list-op": 0}

_FAMILY_SEED = {
    "chain-arith": 0x11AA, "deep-arith": 0x22BB,
    "str-transform": 0x33CC, "list-op": 0x44DD,
}


def generate(family: str, n: int, seed: int) -> list[Sample]:
    rng = SplitMix64(seed ^ _FAMILY_SEED[family])
    gen = _GENERATORS[family]
    return [gen(rng) for _ in range(n)]


def build_prompt_text(family: str, sample: Sample, shots: list[Sample]) -> str:
    """Assemble the full prompt (few-shot examples merged into one prompt,
    as the paper does for math: no fewshot_as_multiturn, §A.3)."""
    parts = [f"{s.prompt}a:{s.answer};" for s in shots]
    parts.append(f"{sample.prompt}a:")
    return "".join(parts)


def few_shot_examples(family: str) -> list[Sample]:
    """Fixed shots per family (deterministic, disjoint from eval seeds)."""
    k = NUM_SHOTS[family]
    return generate(family, k, seed=0xF00D) if k else []


def extract_final(text: str) -> str | None:
    """Scoring rule: text after the last '#', truncated at ';'.

    Returns None if no '#' was emitted (counts as wrong)."""
    if "#" not in text:
        return None
    tail = text.rsplit("#", 1)[1]
    return tail.split(";", 1)[0]


def score(generated_text: str, sample: Sample) -> bool:
    return extract_final(generated_text) == sample.final


def encode_example(family: str, sample: Sample, prompt_len: int,
                   gen_len: int) -> tuple[list[int], list[int]]:
    """Tokenize to fixed geometry: left-padded prompt, right-padded answer.

    Prompt: [<pad>..., <bos>, prompt tokens]; answer: [tokens..., <eos>,
    <pad>...]. Raises if the text does not fit (generators are sized so it
    always does)."""
    shots = few_shot_examples(family)
    ptext = build_prompt_text(family, sample, shots)
    pids = [vocab.BOS] + vocab.encode(ptext)
    if len(pids) > prompt_len:
        raise ValueError(f"prompt too long ({len(pids)} > {prompt_len}): {ptext!r}")
    pids = [vocab.PAD] * (prompt_len - len(pids)) + pids
    aids = vocab.encode(sample.answer + ";") + [vocab.EOS]
    if len(aids) > gen_len:
        raise ValueError(f"answer too long ({len(aids)} > {gen_len}): {sample.answer!r}")
    # Pad the answer tail with <eos>, NOT <pad>: every generation
    # position must be supervised so that inference-time states (all
    # positions masked) stay in-distribution — the model learns "after
    # the answer, everything is <eos>", which is also what makes
    # confidence-thresholded finalization and block early-stop work
    # (LLaDA pads generations with EOS for the same reason).
    aids = aids + [vocab.EOS] * (gen_len - len(aids))
    return pids, aids
