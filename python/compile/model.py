"""L2: the transformer backbone for teacher / student / AR variants.

A single purely-functional architecture serves all three roles — only the
attention *mask* (and for the student, LoRA adapters) differs:

  teacher   fully bidirectional over the whole padded sequence (paper
            Fig. 2 left);
  student   block-wise causal: every position sees the full prompt;
            generation position i in block b sees generation blocks <= b,
            with full bidirectional attention inside a block (Fig. 2
            right);
  AR        standard causal mask (the equal-size autoregressive baseline
            of Fig. 3).

Architecture: pre-RMSNorm, RoPE, multi-head attention, SwiGLU MLP,
untied lm_head. All decode-path entry points (prefill / block_step /
ar_step / teacher block-approx) call the L1 Pallas kernels so that the
AOT-lowered HLO contains the fused hot path.

Everything here is init/apply style over a flat dict of jnp arrays, so
weights round-trip trivially through ``weights.npz`` to the rust runtime.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.block_attn import block_attn_batched
from .kernels.confidence import confidence, confidence_batched
from .kernels.ref import NEG_INF


@dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 64
    d_model: int = 96
    n_layers: int = 3
    n_heads: int = 4
    d_ff: int = 192
    prompt_len: int = 64   # P: prompts left-padded to this length
    gen_len: int = 32      # Lg: generation budget (paper: 256)
    block_size: int = 8    # B: decode block (paper: 32)
    rope_base: float = 10000.0
    lora_rank: int = 8     # student LoRA rank (paper: 32/64)
    lora_alpha: float = 16.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def seq_len(self) -> int:
        return self.prompt_len + self.gen_len

    @property
    def num_blocks(self) -> int:
        assert self.gen_len % self.block_size == 0
        return self.gen_len // self.block_size


# LoRA is applied to the same projection set the paper targets (Table 5):
# attention q/k/v/o and the SwiGLU gate/up/down.
LORA_TARGETS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")


# --------------------------------------------------------------------------
# Parameter init / manipulation
# --------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> dict[str, tuple[int, ...]]:
    """Flat name -> shape map. The sorted key order is the canonical weight
    argument order of every AOT program (manifest + rust agree on it)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    shapes: dict[str, tuple[int, ...]] = {"emb": (v, d), "head": (d, v), "lnf": (d,)}
    for l in range(cfg.n_layers):
        p = f"l{l}."
        shapes[p + "wq"] = (d, d)
        shapes[p + "wk"] = (d, d)
        shapes[p + "wv"] = (d, d)
        shapes[p + "wo"] = (d, d)
        shapes[p + "wg"] = (d, f)
        shapes[p + "wu"] = (d, f)
        shapes[p + "wd"] = (f, d)
        shapes[p + "ln1"] = (d,)
        shapes[p + "ln2"] = (d,)
    return shapes


def init_params(cfg: ModelConfig, key) -> dict[str, jnp.ndarray]:
    shapes = param_shapes(cfg)
    params = {}
    keys = jax.random.split(key, len(shapes))
    for k, (name, shp) in zip(keys, sorted(shapes.items())):
        if name.endswith(("ln1", "ln2", "lnf")):
            params[name] = jnp.ones(shp, jnp.float32)
        else:
            fan_in = shp[0]
            params[name] = (jax.random.normal(k, shp, jnp.float32)
                            / jnp.sqrt(fan_in))
    return params


def init_lora(cfg: ModelConfig, key) -> dict[str, jnp.ndarray]:
    """LoRA adapters: for every target W [m, n], A [m, r] ~ N(0, 1/m) and
    B [r, n] = 0 (standard zero-init so the student starts == teacher)."""
    lora = {}
    shapes = param_shapes(cfg)
    targets = [n for n in sorted(shapes) if n.split(".")[-1] in LORA_TARGETS]
    keys = jax.random.split(key, len(targets))
    for k, name in zip(keys, targets):
        m, n = shapes[name]
        r = cfg.lora_rank
        lora[name + ".A"] = jax.random.normal(k, (m, r), jnp.float32) / jnp.sqrt(m)
        lora[name + ".B"] = jnp.zeros((r, n), jnp.float32)
    return lora


def merge_lora(cfg: ModelConfig, params, lora) -> dict[str, jnp.ndarray]:
    """Fold adapters into dense weights: W' = W + (alpha/r) A @ B.

    Exported students are always merged, so every AOT program takes one
    dense weight set regardless of how it was trained."""
    scale = cfg.lora_alpha / cfg.lora_rank
    out = dict(params)
    for name in params:
        a, b = lora.get(name + ".A"), lora.get(name + ".B")
        if a is not None:
            out[name] = params[name] + scale * (a @ b)
    return out


def apply_lora(cfg: ModelConfig, params, lora):
    """Functional view of merged weights (used inside the training step so
    gradients flow to the adapters only)."""
    return merge_lora(cfg, params, lora)


# --------------------------------------------------------------------------
# Masks
# --------------------------------------------------------------------------

def bidirectional_mask(cfg: ModelConfig, valid_from):
    """[S, S]: everyone attends to every valid (non-pad) position."""
    S = cfg.seq_len
    idx = jnp.arange(S)
    valid = idx >= valid_from
    return valid[None, :] & jnp.ones((S, 1), bool)


def causal_mask(cfg: ModelConfig, valid_from):
    S = cfg.seq_len
    idx = jnp.arange(S)
    valid = idx >= valid_from
    return (idx[None, :] <= idx[:, None]) & valid[None, :]


def block_causal_mask(cfg: ModelConfig, valid_from):
    """The student mask (paper Fig. 2 right).

    * Every position sees the full (non-pad) prompt.
    * A generation position in block b sees generation blocks <= b; within
      a block, attention is fully bidirectional.
    * Prompt positions see only the prompt.
    """
    S, P, B = cfg.seq_len, cfg.prompt_len, cfg.block_size
    idx = jnp.arange(S)
    valid = idx >= valid_from
    is_prompt = idx < P
    blk = jnp.where(is_prompt, -1, (idx - P) // B)
    allowed = is_prompt[None, :] | (blk[None, :] <= blk[:, None])
    return allowed & valid[None, :]


# --------------------------------------------------------------------------
# Core transformer pieces
# --------------------------------------------------------------------------

def rms_norm(x, g, eps: float = 1e-6):
    x = x.astype(jnp.float32)
    return g * x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope(x, positions, base: float):
    """Rotary embedding. x [..., S, H, dh]; positions [..., S] int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _qkv(cfg: ModelConfig, params, layer: int, x, positions):
    """Project + reshape + RoPE. x [..., S, d] -> q,k,v [..., S, H, dh]."""
    p = f"l{layer}."
    H, dh = cfg.n_heads, cfg.d_head
    shp = x.shape[:-1] + (H, dh)
    q = (x @ params[p + "wq"]).reshape(shp)
    k = (x @ params[p + "wk"]).reshape(shp)
    v = (x @ params[p + "wv"]).reshape(shp)
    q = rope(q, positions, cfg.rope_base)
    k = rope(k, positions, cfg.rope_base)
    return q, k, v


def _mlp(cfg: ModelConfig, params, layer: int, x):
    p = f"l{layer}."
    return (jax.nn.silu(x @ params[p + "wg"]) * (x @ params[p + "wu"])) \
        @ params[p + "wd"]


def forward_full(cfg: ModelConfig, params, ids, mask, collect_kv=False,
                 collect_hidden=False):
    """Full-sequence forward with an explicit [S, S] (or [bs, S, S]) mask.

    ids [bs, S] int32. Returns logits [bs, S, V], plus optionally the
    per-layer post-RoPE K/V stacks ([L, bs, H, S, dh]) and the final
    pre-head hidden states ([bs, S, d] — the paper's hidden-state buffer
    source, §4.1).
    """
    bs, S = ids.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (bs, S))
    x = params["emb"][ids]
    if mask.ndim == 2:
        mask = jnp.broadcast_to(mask, (bs, S, S))
    ks, vs = [], []
    scale = 1.0 / jnp.sqrt(cfg.d_head)
    for l in range(cfg.n_layers):
        h = rms_norm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(cfg, params, l, h, positions)
        if collect_kv:
            ks.append(k)
            vs.append(v)
        s = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(bs, S, cfg.d_model)
        x = x + o @ params[f"l{l}.wo"]
        x = x + _mlp(cfg, params, l, rms_norm(x, params[f"l{l}.ln2"]))
    hidden = rms_norm(x, params["lnf"])
    logits = hidden @ params["head"]
    out = [logits]
    if collect_kv:
        # [L, bs, H, S, dh] — head-major to match the Pallas cache layout
        out.append(jnp.stack(ks).transpose(0, 1, 3, 2, 4))
        out.append(jnp.stack(vs).transpose(0, 1, 3, 2, 4))
    if collect_hidden:
        out.append(hidden)
    return tuple(out) if len(out) > 1 else logits


# --------------------------------------------------------------------------
# Decode-path programs (these are what aot.py lowers)
# --------------------------------------------------------------------------

def student_prefill(cfg: ModelConfig, params, prompt_ids, valid_from):
    """Prompt -> exact prompt KV cache.

    prompt_ids [bs, P]; valid_from [bs] (first non-pad index).
    Returns (k, v) [L, bs, H, P, dh]. Within the prompt, attention is fully
    bidirectional (the prompt is given context, visible to all blocks —
    Fig. 2 right), with left-pad masking.
    """
    bs, P = prompt_ids.shape
    idx = jnp.arange(P)
    mask = (idx[None, None, :] >= valid_from[:, None, None]) \
        & jnp.ones((bs, P, 1), bool)
    positions = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (bs, P))
    x = params["emb"][prompt_ids]
    ks, vs = [], []
    scale = 1.0 / jnp.sqrt(cfg.d_head)
    for l in range(cfg.n_layers):
        h = rms_norm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(cfg, params, l, h, positions)
        ks.append(k)
        vs.append(v)
        s = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(bs, P, cfg.d_model)
        x = x + o @ params[f"l{l}.wo"]
        x = x + _mlp(cfg, params, l, rms_norm(x, params[f"l{l}.ln2"]))
    k = jnp.stack(ks).transpose(0, 1, 3, 2, 4)  # [L, bs, H, P, dh]
    v = jnp.stack(vs).transpose(0, 1, 3, 2, 4)
    return k, v


def _cached_block_forward(cfg: ModelConfig, params, k_cache, v_cache,
                          cache_len, valid_from, blk_ids, pos0,
                          excl_start=0, excl_len=0, intra_causal=False):
    """Shared body of student_block_step / teacher_block_approx / ar_step.

    k_cache/v_cache [L, bs, H, T, dh]; blk_ids [bs, Bq]; pos0 scalar int32
    (absolute position of the block's first token; shared across the batch
    because batched sequences decode in lockstep). Returns
    (logits [bs, Bq, V], k_blk, v_blk [L, bs, H, Bq, dh]).
    """
    bs, Bq = blk_ids.shape
    positions = pos0 + jnp.broadcast_to(
        jnp.arange(Bq, dtype=jnp.int32), (bs, Bq))
    x = params["emb"][blk_ids]
    kbs, vbs = [], []
    for l in range(cfg.n_layers):
        h = rms_norm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(cfg, params, l, h, positions)
        kbs.append(k)
        vbs.append(v)
        # -> [bs, H, Bq, dh] for the Pallas kernel
        o = block_attn_batched(
            q.transpose(0, 2, 1, 3), k_cache[l], v_cache[l],
            k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            cache_len, valid_from, excl_start, excl_len,
            intra_causal=intra_causal)
        o = o.transpose(0, 2, 1, 3).reshape(bs, Bq, cfg.d_model)
        x = x + o @ params[f"l{l}.wo"]
        x = x + _mlp(cfg, params, l, rms_norm(x, params[f"l{l}.ln2"]))
    logits = rms_norm(x, params["lnf"]) @ params["head"]
    k_blk = jnp.stack(kbs).transpose(0, 1, 3, 2, 4)
    v_blk = jnp.stack(vbs).transpose(0, 1, 3, 2, 4)
    return logits, k_blk, v_blk


def student_block_step(cfg: ModelConfig, params, k_cache, v_cache, cache_len,
                       valid_from, blk_ids, pos0):
    """One refinement step of the active block under the block-causal mask.

    Returns (logits [bs, B, V], tok [bs, B], conf [bs, B],
    k_blk, v_blk [L, bs, H, B, dh]). ``tok``/``conf`` come from the fused
    L1 confidence kernel; the rust scheduler applies the threshold and
    remask policy. k_blk/v_blk are returned every step so the final call
    on the finalized block doubles as the cache commit (DESIGN.md §7).
    """
    logits, k_blk, v_blk = _cached_block_forward(
        cfg, params, k_cache, v_cache, cache_len, valid_from, blk_ids, pos0)
    tok, conf = confidence_batched(logits)
    return logits, tok, conf, k_blk, v_blk


def teacher_block_approx(cfg: ModelConfig, params, k_cache, v_cache,
                         valid_from, blk_ids, pos0):
    """Approximate-cache step for the Fast-dLLM dual-cache / dLLM-Cache
    baselines: the bidirectional teacher recomputes only the active block,
    attending to the *stale* full-sequence KV (prompt + prefix + suffix of
    still-masked tokens) with the stale copy of the active block excluded
    in favour of the fresh one.
    """
    T = k_cache.shape[3]
    logits, k_blk, v_blk = _cached_block_forward(
        cfg, params, k_cache, v_cache, jnp.int32(T), valid_from, blk_ids,
        pos0, excl_start=pos0, excl_len=blk_ids.shape[1])
    tok, conf = confidence_batched(logits)
    return logits, tok, conf, k_blk, v_blk


def teacher_denoise(cfg: ModelConfig, params, ids, valid_from):
    """One vanilla full-bidirectional denoising step: logits + confidence
    for every position (the vanilla-DLM / Fast-dLLM(Par.) baselines)."""
    bs, S = ids.shape
    idx = jnp.arange(S)
    mask = (idx[None, None, :] >= valid_from[:, None, None]) \
        & jnp.ones((bs, S, 1), bool)
    logits = forward_full(cfg, params, ids, mask)
    tok, conf = confidence_batched(logits)
    return logits, tok, conf


def teacher_full_cache(cfg: ModelConfig, params, ids, valid_from):
    """Full denoising step that also emits the KV stacks — the refresh
    step of the approximate-cache baselines."""
    bs, S = ids.shape
    idx = jnp.arange(S)
    mask = (idx[None, None, :] >= valid_from[:, None, None]) \
        & jnp.ones((bs, S, 1), bool)
    logits, k, v = forward_full(cfg, params, ids, mask, collect_kv=True)
    tok, conf = confidence_batched(logits)
    return logits, tok, conf, k, v


def ar_prefill(cfg: ModelConfig, params, prompt_ids, valid_from):
    """Causal prefill for the AR baseline: prompt KV + last-position
    logits (the first generated token's distribution)."""
    bs, P = prompt_ids.shape
    idx = jnp.arange(P)
    mask = (idx[None, None, :] <= idx[None, :, None]) \
        & (idx[None, None, :] >= valid_from[:, None, None])
    logits, k, v = forward_full_prompt_causal(cfg, params, prompt_ids, mask)
    # [bs, V]: batch rows play the role of the block dimension here
    tok, conf = confidence(logits[:, -1, :])
    return logits[:, -1, :], tok, conf, k, v


def forward_full_prompt_causal(cfg: ModelConfig, params, ids, mask):
    """Causal forward over the prompt only (length P, not S)."""
    bs, P = ids.shape
    positions = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (bs, P))
    x = params["emb"][ids]
    ks, vs = [], []
    scale = 1.0 / jnp.sqrt(cfg.d_head)
    for l in range(cfg.n_layers):
        h = rms_norm(x, params[f"l{l}.ln1"])
        q, k, v = _qkv(cfg, params, l, h, positions)
        ks.append(k)
        vs.append(v)
        s = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
        s = jnp.where(mask[:, None, :, :], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(bs, P, cfg.d_model)
        x = x + o @ params[f"l{l}.wo"]
        x = x + _mlp(cfg, params, l, rms_norm(x, params[f"l{l}.ln2"]))
    logits = rms_norm(x, params["lnf"]) @ params["head"]
    k = jnp.stack(ks).transpose(0, 1, 3, 2, 4)
    v = jnp.stack(vs).transpose(0, 1, 3, 2, 4)
    return logits, k, v


def ar_verify(cfg: ModelConfig, params, k_cache, v_cache, cache_len,
              valid_from, blk_ids, pos0):
    """Parallel AR verification of a drafted block (Appendix C: CDLM as
    a speculative-decoding drafter for an AR verifier).

    Teacher-forced causal forward over the B drafted tokens against the
    AR model's exact cache: position i attends to the cache plus drafted
    tokens <= i (intra-block causal mask in the L1 kernel). Returns the
    AR logits at every drafted position (logits[i] predicts token i+1;
    the first draft token is judged by the *previous* step's logits) and
    the block K/V for committing the accepted prefix.
    """
    logits, k_blk, v_blk = _cached_block_forward(
        cfg, params, k_cache, v_cache, cache_len, valid_from, blk_ids,
        pos0, intra_causal=True)
    tok, conf = confidence_batched(logits)
    return logits, tok, conf, k_blk, v_blk


def ar_step(cfg: ModelConfig, params, k_cache, v_cache, cache_len,
            valid_from, tok_ids):
    """One AR decode step: a 1-token "block" attending to the cache + itself.

    tok_ids [bs]; position of the new token == cache_len.
    Returns (logits [bs, V], tok [bs], conf [bs], k1, v1 [L, bs, H, 1, dh]).
    """
    blk_ids = tok_ids[:, None]
    logits, k1, v1 = _cached_block_forward(
        cfg, params, k_cache, v_cache, cache_len, valid_from, blk_ids,
        cache_len)
    tok, conf = confidence_batched(logits)
    return logits[:, 0, :], tok[:, 0], conf[:, 0], k1, v1
