"""Shared training infrastructure: AdamW, batch assembly, checkpoints.

optax is unavailable in this offline image, so AdamW is implemented
directly (decoupled weight decay, bias-corrected moments) over flat
name->array parameter dicts.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import tasks
from . import vocab


# --------------------------------------------------------------------------
# AdamW over flat dicts
# --------------------------------------------------------------------------

class AdamW:
    def __init__(self, lr: float, betas=(0.9, 0.95), eps: float = 1e-8,
                 weight_decay: float = 0.0, warmup_frac: float = 0.05,
                 total_steps: int = 1000):
        self.lr = lr
        self.b1, self.b2 = betas
        self.eps = eps
        self.wd = weight_decay
        self.warmup = max(1, int(warmup_frac * total_steps))

    def init(self, params):
        z = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
        return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        t = state["t"] + 1
        # constant schedule with linear warmup (paper Tables 5/6)
        lr = self.lr * jnp.minimum(1.0, t / self.warmup)
        m = {k: self.b1 * state["m"][k] + (1 - self.b1) * grads[k]
             for k in params}
        v = {k: self.b2 * state["v"][k] + (1 - self.b2) * grads[k] ** 2
             for k in params}
        mh = {k: m[k] / (1 - self.b1 ** t) for k in params}
        vh = {k: v[k] / (1 - self.b2 ** t) for k in params}
        new = {k: params[k] - lr * (mh[k] / (jnp.sqrt(vh[k]) + self.eps)
                                    + self.wd * params[k])
               for k in params}
        return new, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# Data
# --------------------------------------------------------------------------

def encode_family_batch(cfg: M.ModelConfig, family: str, n: int, seed: int):
    """n samples of a family -> (prompts [n, P], answers [n, Lg], samples)."""
    samples = tasks.generate(family, n, seed)
    P, Lg = cfg.prompt_len, cfg.gen_len
    prompts = np.zeros((n, P), np.int32)
    answers = np.zeros((n, Lg), np.int32)
    for i, s in enumerate(samples):
        p, a = tasks.encode_example(family, s, P, Lg)
        prompts[i] = p
        answers[i] = a
    return prompts, answers, samples


def make_corpus(cfg: M.ModelConfig, mixture: dict[str, float], n: int,
                seed: int):
    """Training corpus with a family mixture (dream-tiny: uniform;
    llada-tiny: math-augmented, mirroring §5.2.2 / Appendix A.1)."""
    fams, weights = zip(*mixture.items())
    weights = np.asarray(weights, np.float64)
    weights = weights / weights.sum()
    counts = np.floor(weights * n).astype(int)
    counts[0] += n - counts.sum()
    ps, as_, ss = [], [], []
    for fam, c in zip(fams, counts):
        p, a, s = encode_family_batch(cfg, fam, int(c), seed)
        ps.append(p)
        as_.append(a)
        ss.extend(s)
    prompts = np.concatenate(ps)
    answers = np.concatenate(as_)
    rng = np.random.RandomState(seed)
    perm = rng.permutation(len(prompts))
    return prompts[perm], answers[perm], [ss[i] for i in perm]


# --------------------------------------------------------------------------
# Objectives
# --------------------------------------------------------------------------

def dlm_loss(cfg: M.ModelConfig, params, prompts, answers, key,
             mask_fn=None):
    """Masked-denoising objective (paper Eq. 6): sample t ~ U(0,1) per
    sequence, mask each answer token independently w.p. t, predict the
    original tokens at masked positions with 1/t weighting.

    ``mask_fn(cfg, valid_from)`` selects the attention mask (bidirectional
    for the teacher, block-causal for the student's auxiliary loss)."""
    bs = prompts.shape[0]
    P, Lg, S = cfg.prompt_len, cfg.gen_len, cfg.seq_len
    kt, km = jax.random.split(key)
    t = jax.random.uniform(kt, (bs, 1), minval=0.05, maxval=1.0)
    # every answer position is supervised (answers are EOS-padded)
    drop = jax.random.uniform(km, (bs, Lg)) < t
    gen = jnp.where(drop, vocab.MASK, answers)
    ids = jnp.concatenate([prompts, gen], axis=1)
    vf = jnp.argmin(prompts == vocab.PAD, axis=1).astype(jnp.int32)
    if mask_fn is None:
        mask_fn = M.bidirectional_mask
    idx = jnp.arange(S)
    if mask_fn is M.bidirectional_mask:
        mask = (idx[None, None, :] >= vf[:, None, None]) \
            & jnp.ones((bs, S, 1), bool)
    else:
        mask = jax.vmap(lambda v: mask_fn(cfg, v))(vf)
    logits = M.forward_full(cfg, params, ids, mask)
    lp = jax.nn.log_softmax(logits[:, P:, :].astype(jnp.float32), axis=-1)
    tok_lp = jnp.take_along_axis(lp, answers[..., None], axis=-1)[..., 0]
    w = drop.astype(jnp.float32) / t
    return -jnp.sum(tok_lp * w) / (jnp.sum(drop) + 1e-6)


def ar_loss(cfg: M.ModelConfig, params, prompts, answers):
    """Next-token prediction over the answer span (causal mask)."""
    bs = prompts.shape[0]
    P, S = cfg.prompt_len, cfg.seq_len
    ids = jnp.concatenate([prompts, answers], axis=1)
    vf = jnp.argmin(prompts == vocab.PAD, axis=1).astype(jnp.int32)
    idx = jnp.arange(S)
    mask = (idx[None, None, :] <= idx[None, :, None]) \
        & (idx[None, None, :] >= vf[:, None, None])
    logits = M.forward_full(cfg, params, ids, mask)
    # predict answers[i] from position P-1+i
    lp = jax.nn.log_softmax(logits[:, P - 1:S - 1, :].astype(jnp.float32), -1)
    tok_lp = jnp.take_along_axis(lp, answers[..., None], axis=-1)[..., 0]
    w = (answers != vocab.PAD).astype(jnp.float32)
    return -jnp.sum(tok_lp * w) / jnp.sum(w)


# --------------------------------------------------------------------------
# Checkpoints
# --------------------------------------------------------------------------

def save_params(path: str, params: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> dict:
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def fast_mode() -> bool:
    """CDLM_FAST=1 shrinks every training run for quick iteration."""
    return os.environ.get("CDLM_FAST", "0") == "1"
