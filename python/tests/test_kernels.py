"""L1 kernel correctness: Pallas vs pure-jnp oracle.

hypothesis sweeps shapes, dtypes, cache lengths and exclusion windows; any
mismatch against ref.py is a hard failure.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.block_attn import block_attn, block_attn_batched
from compile.kernels.confidence import confidence, confidence_batched
from compile.kernels.ref import ref_block_attn, ref_confidence

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def _rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _attn_inputs(seed, H, B, dh, T, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = _rand(ks[0], H, B, dh, dtype=dtype)
    kc = _rand(ks[1], H, T, dh, dtype=dtype)
    vc = _rand(ks[2], H, T, dh, dtype=dtype)
    kb = _rand(ks[3], H, B, dh, dtype=dtype)
    vb = _rand(ks[4], H, B, dh, dtype=dtype)
    return q, kc, vc, kb, vb


@given(seed=st.integers(0, 2**31 - 1),
       H=st.sampled_from([1, 2, 4]),
       B=st.sampled_from([1, 2, 8]),
       dh=st.sampled_from([8, 24]),
       tiles=st.integers(1, 3),
       kv_tile=st.sampled_from([16, 32]))
def test_block_attn_matches_ref(seed, H, B, dh, tiles, kv_tile):
    T = tiles * kv_tile
    q, kc, vc, kb, vb = _attn_inputs(seed, H, B, dh, T)
    rng = np.random.RandomState(seed % 2**31)
    cache_len = int(rng.randint(0, T + 1))
    valid_from = int(rng.randint(0, max(1, cache_len + 1)))
    got = block_attn(q, kc, vc, kb, vb, cache_len, valid_from,
                     kv_tile=kv_tile)
    want = ref_block_attn(q, kc, vc, kb, vb, cache_len, valid_from)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@given(seed=st.integers(0, 2**31 - 1),
       excl_start=st.integers(0, 80),
       excl_len=st.sampled_from([0, 4, 8, 16]))
def test_block_attn_exclusion_window(seed, excl_start, excl_len):
    H, B, dh, T = 2, 8, 8, 96
    q, kc, vc, kb, vb = _attn_inputs(seed, H, B, dh, T)
    got = block_attn(q, kc, vc, kb, vb, T, 0, excl_start, excl_len)
    want = ref_block_attn(q, kc, vc, kb, vb, T, 0,
                          excl_start=excl_start, excl_len=excl_len)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_block_attn_empty_cache():
    """cache_len == 0: attention only over the block itself."""
    q, kc, vc, kb, vb = _attn_inputs(0, 2, 4, 8, 32)
    got = block_attn(q, kc, vc, kb, vb, 0, 0)
    want = ref_block_attn(q, jnp.zeros_like(kc), jnp.zeros_like(vc),
                          kb, vb, 0, 0)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_block_attn_ignores_stale_cache_contents():
    """Invalid cache slots must not influence the output at all."""
    q, kc, vc, kb, vb = _attn_inputs(1, 2, 4, 8, 64)
    cache_len = 20
    o1 = block_attn(q, kc, vc, kb, vb, cache_len, 0)
    kc2 = kc.at[:, cache_len:, :].set(1e6)
    vc2 = vc.at[:, cache_len:, :].set(-1e6)
    o2 = block_attn(q, kc2, vc2, kb, vb, cache_len, 0)
    np.testing.assert_allclose(o1, o2, rtol=1e-6, atol=1e-6)


def test_block_attn_valid_from_masks_left_pad():
    q, kc, vc, kb, vb = _attn_inputs(2, 2, 4, 8, 64)
    o1 = block_attn(q, kc, vc, kb, vb, 40, 10)
    kc2 = kc.at[:, :10, :].set(99.0)
    o2 = block_attn(q, kc2, vc, kb, vb, 40, 10)
    np.testing.assert_allclose(o1, o2, rtol=1e-6, atol=1e-6)


def test_block_attn_rejects_bad_tile():
    q, kc, vc, kb, vb = _attn_inputs(0, 1, 2, 8, 40)
    with pytest.raises(ValueError):
        block_attn(q, kc, vc, kb, vb, 0, 0, kv_tile=32)


def test_block_attn_bf16_inputs():
    """bf16 K/V with f32 accumulation stays close to the f32 oracle."""
    q, kc, vc, kb, vb = _attn_inputs(3, 2, 4, 8, 32, dtype=jnp.bfloat16)
    got = block_attn(q, kc, vc, kb, vb, 32, 0)
    want = ref_block_attn(q, kc, vc, kb, vb, 32, 0)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_block_attn_batched_matches_per_row():
    bs = 3
    rows = [_attn_inputs(10 + r, 2, 8, 8, 64) for r in range(bs)]
    q, kc, vc, kb, vb = [jnp.stack([r[i] for r in rows]) for i in range(5)]
    vf = jnp.array([0, 5, 63], jnp.int32)
    got = block_attn_batched(q, kc, vc, kb, vb, 64, vf)
    for r in range(bs):
        want = ref_block_attn(*rows[r], 64, int(vf[r]))
        np.testing.assert_allclose(got[r], want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# confidence kernel
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1),
       B=st.sampled_from([1, 4, 8, 16]),
       V=st.sampled_from([16, 64, 128]),
       scale=st.sampled_from([0.1, 1.0, 10.0]))
def test_confidence_matches_ref(seed, B, V, scale):
    lg = jax.random.normal(jax.random.PRNGKey(seed), (B, V)) * scale
    tok, conf = confidence(lg)
    rtok, rconf = ref_confidence(lg)
    assert (tok == rtok).all()
    np.testing.assert_allclose(conf, rconf, rtol=1e-5, atol=1e-6)


def test_confidence_is_probability():
    lg = jax.random.normal(jax.random.PRNGKey(0), (32, 64)) * 5
    _, conf = confidence(lg)
    assert (conf > 0).all() and (conf <= 1.0 + 1e-6).all()


def test_confidence_onehot_certainty():
    lg = jnp.full((2, 64), -30.0).at[0, 7].set(30.0).at[1, 3].set(30.0)
    tok, conf = confidence(lg)
    assert tok.tolist() == [7, 3]
    np.testing.assert_allclose(conf, [1.0, 1.0], rtol=1e-5)


def test_confidence_batched_shape():
    lg = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64))
    tok, conf = confidence_batched(lg)
    assert tok.shape == (4, 8) and conf.shape == (4, 8)
    rtok, rconf = ref_confidence(lg)
    assert (tok == rtok).all()
    np.testing.assert_allclose(conf, rconf, rtol=1e-5, atol=1e-6)


@given(seed=st.integers(0, 2**31 - 1), B=st.sampled_from([2, 4, 8]))
def test_block_attn_intra_causal(seed, B):
    """AR-verify path: within-block lower-triangular masking."""
    H, dh, T = 2, 8, 32
    q, kc, vc, kb, vb = _attn_inputs(seed, H, B, dh, T)
    got = block_attn(q, kc, vc, kb, vb, 16, 0, intra_causal=True)
    want = ref_block_attn(q, kc, vc, kb, vb, 16, 0, intra_causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_intra_causal_first_position_ignores_rest_of_block():
    """Row 0 under the causal mask sees only the cache + itself, so
    changing later block tokens must not affect it."""
    H, B, dh, T = 2, 4, 8, 32
    q, kc, vc, kb, vb = _attn_inputs(5, H, B, dh, T)
    o1 = block_attn(q, kc, vc, kb, vb, 20, 0, intra_causal=True)
    kb2 = kb.at[:, 1:, :].set(99.0)
    vb2 = vb.at[:, 1:, :].set(-99.0)
    o2 = block_attn(q, kc, vc, kb2, vb2, 20, 0, intra_causal=True)
    np.testing.assert_allclose(o1[:, 0, :], o2[:, 0, :], rtol=1e-6)
