"""Decoder + trajectory invariants (Algorithm 1 structure)."""

import jax
import numpy as np
import pytest

from compile import decoding, tasks, vocab
from compile import model as M
from compile import train_common as TC
from compile.trajectory import TrajectoryDataset, collect

CFG = M.ModelConfig(d_model=48, n_layers=2, n_heads=2, d_ff=96,
                    prompt_len=32, gen_len=16, block_size=4)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(1))


@pytest.fixture(scope="module")
def prompts():
    p, _, _ = TC.encode_family_batch(CFG, "list-op", 4, seed=5)
    return p


def test_teacher_decode_finalizes_everything(params, prompts):
    res = decoding.teacher_block_decode(CFG, params, prompts)
    gen = res.ids[:, CFG.prompt_len:]
    assert (gen != vocab.MASK).all(), "all positions must be finalized"
    assert (res.steps == CFG.gen_len).all(), "N = Lg steps (one per token)"


def test_teacher_decode_respects_block_order(params, prompts):
    res = decoding.teacher_block_decode(CFG, params, prompts, collect=True)
    B = CFG.block_size
    for tr in res.trace:
        blocks = [(pos - CFG.prompt_len) // B for pos, _, _ in tr]
        assert blocks == sorted(blocks), "blocks must complete in order"
        # exactly B finalizations per block
        for b in range(CFG.num_blocks):
            assert blocks.count(b) == B


def test_teacher_decode_deterministic_at_tau0(params, prompts):
    r1 = decoding.teacher_block_decode(CFG, params, prompts)
    r2 = decoding.teacher_block_decode(CFG, params, prompts)
    assert (r1.ids == r2.ids).all()


def test_temperature_changes_trajectories(params, prompts):
    r0 = decoding.teacher_block_decode(CFG, params, prompts, temperature=0.0)
    r1 = decoding.teacher_block_decode(CFG, params, prompts, temperature=1.0,
                                       seed=3)
    # with random init weights, sampling at tau=1 differs from greedy
    assert (r0.ids != r1.ids).any()


def test_step_truncation_budget(params, prompts):
    """steps_per_block < B: the Table 4 naive-truncation baseline uses
    ceil(B/spb) finalizations per step and stays within budget."""
    res = decoding.teacher_block_decode(CFG, params, prompts,
                                        steps_per_block=2)
    assert (res.steps <= 2 * CFG.num_blocks).all()
    gen = res.ids[:, CFG.prompt_len:]
    assert (gen != vocab.MASK).all()


def test_student_decode_terminates_and_counts(params, prompts):
    res = decoding.student_cdlm_decode(CFG, params, prompts, tau_conf=0.9)
    gen = res.ids[:, CFG.prompt_len:]
    assert gen.shape == (4, CFG.gen_len)
    assert (res.steps >= 1).all()
    # at most B steps + nothing beyond budget
    assert (res.steps <= CFG.gen_len).all()


def test_student_decode_low_threshold_is_fast(params, prompts):
    """tau=0 finalizes a whole block per step: steps == #blocks decoded."""
    res = decoding.student_cdlm_decode(CFG, params, prompts, tau_conf=0.0)
    assert (res.steps <= CFG.num_blocks).all()


def test_gen_length_accounting():
    row = np.array([5, 6, vocab.EOS, 7, vocab.MASK])
    assert decoding._gen_length(row) == 2
    row = np.array([5, vocab.MASK, 6])
    assert decoding._gen_length(row) == 2  # masks don't count


def test_valid_from():
    p = np.array([[vocab.PAD, vocab.PAD, vocab.BOS, 5],
                  [vocab.BOS, 5, 6, 7]], np.int32)
    np.testing.assert_array_equal(decoding._valid_from(p), [2, 0])


# ---------------------------------------------------------------------------
# trajectory collection (Algorithm 1)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traj(params):
    mix = {"list-op": 1.0}
    return collect(CFG, params, mix, 4, seed=9, batch_size=4,
                   temperatures=(0.0,), log=lambda *_: None)


def test_trajectory_order_is_permutation(traj):
    for r in range(len(traj)):
        assert sorted(traj.order[r]) == list(range(CFG.gen_len))


def test_trajectory_hidden_buffer_written_once(traj):
    """Every position's hidden state is written exactly when finalized,
    so no row of H may be all-zero (paper Fig. 6 write-once buffer)."""
    assert not (np.abs(traj.hbuf).sum(axis=-1) == 0).any()


def test_trajectory_state_reconstruction(traj):
    """state_at(t) must have exactly t finalized tokens, matching the
    finalization order."""
    row = 0
    s0 = traj.state_at(row, 0, CFG)
    assert (s0[CFG.prompt_len:] == vocab.MASK).all()
    s3 = traj.state_at(row, 3, CFG)
    gen = s3[CFG.prompt_len:]
    assert (gen != vocab.MASK).sum() == 3
    for t in range(3):
        assert gen[traj.order[row, t]] == traj.toks[row, t]


def test_trajectory_final_matches_tokens(traj):
    row = 0
    full = traj.state_at(row, CFG.gen_len, CFG)
    np.testing.assert_array_equal(full[CFG.prompt_len:], traj.final[row])


def test_trajectory_save_load_roundtrip(tmp_path, traj):
    p = str(tmp_path / "t.npz")
    traj.save(p)
    t2 = TrajectoryDataset.load(p)
    np.testing.assert_array_equal(t2.order, traj.order)
    np.testing.assert_array_equal(t2.hbuf, traj.hbuf)
