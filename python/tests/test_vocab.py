import json

import pytest

from compile import vocab


def test_specials_fixed():
    assert (vocab.PAD, vocab.MASK, vocab.BOS, vocab.EOS) == (0, 1, 2, 3)


def test_roundtrip_simple():
    s = "q:3*4+5=?a:3*4=12;12+5=17;#17;"
    assert vocab.decode(vocab.encode(s)) == s


def test_all_symbols_roundtrip():
    s = "0123456789abcdefghijklmnopqrstuvwxyz+-*=;#:?(),.><[] "
    ids = vocab.encode(s)
    assert len(set(ids)) == len(ids), "symbol ids must be unique"
    assert vocab.decode(ids) == s


def test_decode_stops_at_eos():
    ids = vocab.encode("#17") + [vocab.EOS] + vocab.encode("garbage")
    assert vocab.decode(ids) == "#17"


def test_decode_skips_specials_without_eos_stop():
    ids = [vocab.PAD, vocab.BOS] + vocab.encode("ab") + [vocab.MASK]
    assert vocab.decode(ids, stop_at_eos=False) == "ab"


def test_unknown_char_raises():
    with pytest.raises(KeyError):
        vocab.encode("A")  # uppercase not in vocab


def test_vocab_size_bound():
    assert max(vocab.ID_TO_TOK) < vocab.VOCAB_SIZE


def test_json_export_parses_and_matches():
    data = json.loads(vocab.to_json())
    assert data["vocab_size"] == vocab.VOCAB_SIZE
    assert data["id_to_tok"][str(vocab.TOK_TO_ID["7"])] == "7"
    assert data["eos"] == vocab.EOS
