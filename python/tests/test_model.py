"""L2 model invariants.

The load-bearing test is cache parity: decode-path programs (prefill +
block_step with KV cache) must produce exactly the same logits as a full
forward pass under the block-causal mask — that is what makes the
student's KV caching *exact* rather than approximate (the paper's core
systems claim, §4.3).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import vocab

CFG = M.ModelConfig(d_model=48, n_layers=2, n_heads=2, d_ff=96,
                    prompt_len=32, gen_len=16, block_size=4)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def _random_ids(key, lo_valid=0):
    S = CFG.seq_len
    ids = jax.random.randint(key, (2, S), 4, CFG.vocab_size)
    return ids.astype(jnp.int32)


def test_param_shapes_cover_all_params(params):
    assert set(params) == set(M.param_shapes(CFG))


def test_mask_shapes_and_prompt_visibility():
    m = M.block_causal_mask(CFG, 0)
    P, B = CFG.prompt_len, CFG.block_size
    assert m.shape == (CFG.seq_len, CFG.seq_len)
    # every generation position sees the whole prompt
    assert bool(m[P:, :P].all())
    # prompt sees only prompt
    assert not bool(m[:P, P:].any())
    # gen block 0 does not see gen block 1
    assert not bool(m[P, P + B:].any())
    # within-block bidirectional
    assert bool(m[P:P + B, P:P + B].all())


def test_block_causal_mask_is_superset_of_causal_on_blocks():
    mb = np.asarray(M.block_causal_mask(CFG, 0))
    mc = np.asarray(M.causal_mask(CFG, 0))
    P = CFG.prompt_len
    # causal visibility within generation implies block-causal visibility
    assert (mc[P:, P:] <= mb[P:, P:]).all()


def test_valid_from_masks_columns():
    m = np.asarray(M.bidirectional_mask(CFG, 5))
    assert not m[:, :5].any()
    assert m[:, 5:].all()


def test_rope_rotation_properties():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8))
    pos = jnp.arange(4, dtype=jnp.int32)
    y = M.rope(x, pos, 10000.0)
    # norm-preserving
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5)
    # position 0 is identity
    np.testing.assert_allclose(y[0], x[0], rtol=1e-6)


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 8)) * 7
    y = M.rms_norm(x, jnp.ones(8))
    np.testing.assert_allclose(
        jnp.mean(y * y, axis=-1), jnp.ones(3), rtol=1e-4)


def test_forward_full_shapes(params):
    ids = _random_ids(jax.random.PRNGKey(3))
    mask = M.bidirectional_mask(CFG, 0)
    logits, k, v, h = M.forward_full(CFG, params, ids, mask,
                                     collect_kv=True, collect_hidden=True)
    S, L, H, dh = CFG.seq_len, CFG.n_layers, CFG.n_heads, CFG.d_head
    assert logits.shape == (2, S, CFG.vocab_size)
    assert k.shape == (L, 2, H, S, dh)
    assert h.shape == (2, S, CFG.d_model)


def test_hidden_buffer_reconstructs_logits(params):
    """lm_head(hidden) == logits — the paper's 30x storage trick (A.1)
    relies on this identity."""
    ids = _random_ids(jax.random.PRNGKey(4))
    mask = M.bidirectional_mask(CFG, 0)
    logits, h = M.forward_full(CFG, params, ids, mask, collect_hidden=True)
    np.testing.assert_allclose(h @ params["head"], logits, rtol=1e-4,
                               atol=1e-4)


def test_cache_parity_student(params):
    """prefill + block_step(cache) == forward_full(block-causal mask).

    Exact KV caching: for the first generation block, the cached decode
    path must reproduce the full-sequence student forward bit-for-bit
    (up to float tolerance)."""
    key = jax.random.PRNGKey(5)
    P, B, S = CFG.prompt_len, CFG.block_size, CFG.seq_len
    prompts = jax.random.randint(key, (2, P), 4, 40).astype(jnp.int32)
    vf = jnp.array([0, 3], jnp.int32)
    prompts = jnp.where(jnp.arange(P)[None, :] >= vf[:, None], prompts,
                        vocab.PAD)
    gen = jnp.full((2, CFG.gen_len), vocab.MASK, jnp.int32)
    blk = jax.random.randint(jax.random.PRNGKey(6), (2, B), 4, 40)
    gen = gen.at[:, :B].set(blk)
    ids = jnp.concatenate([prompts, gen], axis=1)

    # full forward under the student mask; rows mask their own padding
    mask = jax.vmap(lambda v: M.block_causal_mask(CFG, v))(vf)
    full_logits = M.forward_full(CFG, params, ids, mask)

    # decode path: prefill prompt, then one block step
    k, v = M.student_prefill(CFG, params, prompts, vf)
    L, H, dh = CFG.n_layers, CFG.n_heads, CFG.d_head
    kc = jnp.zeros((L, 2, H, S, dh)).at[:, :, :, :P].set(k)
    vc = jnp.zeros((L, 2, H, S, dh)).at[:, :, :, :P].set(v)
    logits, tok, conf, kb, vb = M.student_block_step(
        CFG, params, kc, vc, jnp.int32(P), vf, blk.astype(jnp.int32),
        jnp.int32(P))
    np.testing.assert_allclose(logits, full_logits[:, P:P + B, :],
                               rtol=2e-4, atol=2e-4)


def test_cache_parity_second_block(params):
    """After committing block 0's KV, block 1 decode matches full fwd."""
    key = jax.random.PRNGKey(7)
    P, B, S = CFG.prompt_len, CFG.block_size, CFG.seq_len
    prompts = jax.random.randint(key, (1, P), 4, 40).astype(jnp.int32)
    vf = jnp.zeros(1, jnp.int32)
    g1 = jax.random.randint(jax.random.PRNGKey(8), (1, B), 4, 40)
    g2 = jax.random.randint(jax.random.PRNGKey(9), (1, B), 4, 40)
    gen = jnp.full((1, CFG.gen_len), vocab.MASK, jnp.int32)
    gen = gen.at[:, :B].set(g1).at[:, B:2 * B].set(g2)
    ids = jnp.concatenate([prompts, gen], axis=1)
    mask = jax.vmap(lambda v: M.block_causal_mask(CFG, v))(vf)
    full_logits = M.forward_full(CFG, params, ids, mask)

    k, v = M.student_prefill(CFG, params, prompts, vf)
    L, H, dh = CFG.n_layers, CFG.n_heads, CFG.d_head
    kc = jnp.zeros((L, 1, H, S, dh)).at[:, :, :, :P].set(k)
    vc = jnp.zeros((L, 1, H, S, dh)).at[:, :, :, :P].set(v)
    # commit block 0
    _, _, _, kb, vb = M.student_block_step(
        CFG, params, kc, vc, jnp.int32(P), vf, g1.astype(jnp.int32),
        jnp.int32(P))
    kc = kc.at[:, :, :, P:P + B].set(kb)
    vc = vc.at[:, :, :, P:P + B].set(vb)
    logits, *_ = M.student_block_step(
        CFG, params, kc, vc, jnp.int32(P + B), vf, g2.astype(jnp.int32),
        jnp.int32(P + B))
    np.testing.assert_allclose(logits, full_logits[:, P + B:P + 2 * B, :],
                               rtol=2e-4, atol=2e-4)


def test_ar_cache_parity(params):
    """AR prefill + steps == causal forward_full logits."""
    P, S = CFG.prompt_len, CFG.seq_len
    prompts = jax.random.randint(jax.random.PRNGKey(10), (1, P), 4, 40)
    prompts = prompts.astype(jnp.int32)
    vf = jnp.zeros(1, jnp.int32)
    t1 = jnp.array([5], jnp.int32)
    ids = jnp.concatenate(
        [prompts, t1[:, None],
         jnp.full((1, CFG.gen_len - 1), vocab.PAD, jnp.int32)], axis=1)
    mask = M.causal_mask(CFG, 0)
    full_logits = M.forward_full(CFG, params, ids, mask)

    last, tok, conf, k, v = M.ar_prefill(CFG, params, prompts, vf)
    np.testing.assert_allclose(last, full_logits[:, P - 1, :], rtol=2e-4,
                               atol=2e-4)
    L, H, dh = CFG.n_layers, CFG.n_heads, CFG.d_head
    kc = jnp.zeros((L, 1, H, S, dh)).at[:, :, :, :P].set(k)
    vc = jnp.zeros((L, 1, H, S, dh)).at[:, :, :, :P].set(v)
    lg, *_ = M.ar_step(CFG, params, kc, vc, jnp.int32(P), vf, t1)
    np.testing.assert_allclose(lg, full_logits[:, P, :], rtol=2e-4,
                               atol=2e-4)


def test_teacher_block_approx_refresh_equals_full(params):
    """With a fresh cache (refreshed this step), the approximate-cache
    block step must equal the full bidirectional forward on the block —
    the dual-cache correctness anchor (refresh_every=1 ⇒ exact)."""
    P, B, S = CFG.prompt_len, CFG.block_size, CFG.seq_len
    ids = _random_ids(jax.random.PRNGKey(11))
    vf = jnp.zeros(2, jnp.int32)
    full_logits, k, v = M.forward_full(
        CFG, params, ids, M.bidirectional_mask(CFG, 0), collect_kv=True)
    pos0 = P + B  # second generation block
    blk = ids[:, pos0:pos0 + B]
    logits, *_ = M.teacher_block_approx(CFG, params, k, v, vf, blk,
                                        jnp.int32(pos0))
    np.testing.assert_allclose(logits, full_logits[:, pos0:pos0 + B, :],
                               rtol=2e-4, atol=2e-4)


def test_lora_zero_init_is_identity(params):
    lora = M.init_lora(CFG, jax.random.PRNGKey(12))
    merged = M.merge_lora(CFG, params, lora)
    for k in params:
        np.testing.assert_allclose(merged[k], params[k])


def test_lora_targets_paper_projections():
    lora = M.init_lora(CFG, jax.random.PRNGKey(13))
    kinds = {k.split(".")[-2] if k.count(".") == 2 else k.split(".")[0]
             for k in lora}
    for t in M.LORA_TARGETS:
        assert any(k.endswith(f"{t}.A") for k in lora), t


def test_lora_merge_changes_weights():
    params = M.init_params(CFG, jax.random.PRNGKey(14))
    lora = M.init_lora(CFG, jax.random.PRNGKey(15))
    lora = {k: (v + 0.1 if k.endswith(".B") else v) for k, v in lora.items()}
    merged = M.merge_lora(CFG, params, lora)
    assert not np.allclose(merged["l0.wq"], params["l0.wq"])
    # non-target weights untouched
    np.testing.assert_allclose(merged["emb"], params["emb"])
