"""Algorithm 2 objective properties (Eqs. 4-7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train_common as TC
from compile import vocab
from compile.train_cdlm import _states_from_batch, cdlm_losses, train_cdlm
from compile.trajectory import collect

CFG = M.ModelConfig(d_model=48, n_layers=2, n_heads=2, d_ff=96,
                    prompt_len=32, gen_len=16, block_size=4)


@pytest.fixture(scope="module")
def teacher():
    return M.init_params(CFG, jax.random.PRNGKey(2))


@pytest.fixture(scope="module")
def traj(teacher):
    return collect(CFG, teacher, {"list-op": 1.0}, 4, seed=11,
                   batch_size=4, temperatures=(0.0,), log=lambda *_: None)


def _batch_states(traj, t_start, t_end):
    n = len(traj)
    return _states_from_batch(
        CFG, traj.order[:n], traj.toks[:n],
        np.full(n, t_start), np.full(n, t_end))


def test_state_sets_partition(traj):
    """U (newly unmasked) and S (still masked) partition the masked-at-y
    positions; finalized-at-y positions are in neither."""
    gen_y, gen_ys, U, Sm, _ = _batch_states(traj, 3, 8)
    masked_y = gen_y == vocab.MASK
    assert ((U | Sm) == masked_y).all()
    assert not (U & Sm).any()
    assert (U.sum(1) == 5).all()  # t_end - t_start newly unmasked


def test_block_completion_state(traj):
    """y* fully unmasks the active block of y and nothing else."""
    B = CFG.block_size
    t_start, t_end = 5, 8  # inside block 1
    gen_y, gen_ys, U, Sm, _ = _batch_states(traj, t_start, t_end)
    # positions finalized in steps [t_start, t_end) belong to block 1
    for r in range(len(traj)):
        pos_new = np.nonzero(U[r])[0]
        assert (pos_new // B == 1).all()
        # block 1 fully unmasked at y*
        assert (gen_ys[r][B:2 * B] != vocab.MASK).all()


def test_losses_finite_and_nonnegative(teacher, traj):
    gen_y, gen_ys, U, Sm, _ = _batch_states(traj, 3, 8)
    w = {"distill": 1.0, "cons": 0.5, "dlm": 0.01}
    total, parts = cdlm_losses(
        CFG, teacher, teacher, jnp.asarray(traj.prompts),
        jnp.asarray(gen_y), jnp.asarray(gen_ys), jnp.asarray(U),
        jnp.asarray(Sm), jnp.asarray(traj.hbuf), jnp.asarray(traj.answers),
        jax.random.PRNGKey(0), w)
    assert np.isfinite(float(total))
    assert float(parts["distill"]) >= 0  # KL >= 0
    assert float(parts["cons"]) >= 0
    assert float(parts["dlm"]) >= 0


def test_consistency_zero_when_states_equal(teacher, traj):
    """If y == y* the consistency KL must vanish identically."""
    gen_y, gen_ys, U, Sm, _ = _batch_states(traj, 4, 4)
    assert (gen_y == gen_ys).all() and not U.any()
    w = {"distill": 0.0, "cons": 1.0, "dlm": 0.0}
    _, parts = cdlm_losses(
        CFG, teacher, teacher, jnp.asarray(traj.prompts),
        jnp.asarray(gen_y), jnp.asarray(gen_ys), jnp.asarray(U),
        jnp.asarray(Sm), jnp.asarray(traj.hbuf), jnp.asarray(traj.answers),
        jax.random.PRNGKey(0), w)
    assert abs(float(parts["cons"])) < 1e-5


def test_distill_gradient_reaches_lora_only(teacher, traj):
    """Gradients must flow to LoRA adapters, not the frozen base."""
    lora = M.init_lora(CFG, jax.random.PRNGKey(3))
    gen_y, gen_ys, U, Sm, _ = _batch_states(traj, 3, 8)
    w = {"distill": 1.0, "cons": 0.5, "dlm": 0.01}

    def loss_fn(lo):
        merged = M.apply_lora(CFG, teacher, lo)
        t, _ = cdlm_losses(
            CFG, teacher, merged, jnp.asarray(traj.prompts),
            jnp.asarray(gen_y), jnp.asarray(gen_ys), jnp.asarray(U),
            jnp.asarray(Sm), jnp.asarray(traj.hbuf),
            jnp.asarray(traj.answers), jax.random.PRNGKey(0), w)
        return t

    grads = jax.grad(loss_fn)(lora)
    gnorm = sum(float(jnp.abs(g).sum()) for g in grads.values())
    assert gnorm > 0, "no gradient reached the adapters"


def test_train_cdlm_smoke_reduces_loss(teacher, traj):
    """A few steps of Algorithm 2 run end-to-end and return a merged
    student that differs from the teacher."""
    student, _ = train_cdlm(CFG, teacher, traj, steps=4, batch_size=4,
                            log_every=100)
    assert set(student) == set(teacher)
    diff = float(jnp.abs(student["l0.wq"] - teacher["l0.wq"]).max())
    assert diff > 0


def test_dlm_loss_masks_only_answers(teacher):
    """The DLM loss never corrupts the prompt and weights by 1/t."""
    prompts, answers, _ = TC.encode_family_batch(CFG, "list-op", 4, 21)
    val = TC.dlm_loss(CFG, teacher, jnp.asarray(prompts),
                      jnp.asarray(answers), jax.random.PRNGKey(4))
    assert np.isfinite(float(val)) and float(val) > 0
