import numpy as np
import pytest

from compile import tasks, vocab
from compile.model import ModelConfig

CFG = ModelConfig()


def test_splitmix64_reference_values():
    """Pinned outputs — the rust util::rng mirror must match these."""
    rng = tasks.SplitMix64(0)
    vals = [rng.next_u64() for _ in range(3)]
    assert vals == [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]


def test_splitmix64_seeded_determinism():
    a = tasks.SplitMix64(42)
    b = tasks.SplitMix64(42)
    assert [a.next_u64() for _ in range(10)] == [b.next_u64() for _ in range(10)]


@pytest.mark.parametrize("family", tasks.FAMILIES)
def test_generation_deterministic(family):
    s1 = tasks.generate(family, 16, seed=7)
    s2 = tasks.generate(family, 16, seed=7)
    assert [(a.prompt, a.answer) for a in s1] == \
        [(a.prompt, a.answer) for a in s2]


@pytest.mark.parametrize("family", tasks.FAMILIES)
def test_answers_are_correct(family):
    """The CoT must actually evaluate to the final answer."""
    for s in tasks.generate(family, 64, seed=3):
        assert s.answer.rsplit("#", 1)[1] == s.final
        # every CoT equation must be arithmetically true
        body = s.answer.rsplit("#", 1)[0]
        for eq in filter(None, body.split(";")):
            lhs, rhs = eq.split("=")
            assert eval(lhs) == int(rhs), f"{family}: bad CoT step {eq}"


def test_str_transform_semantics():
    for s in tasks.generate("str-transform", 64, seed=11):
        arg = s.prompt[s.prompt.index("(") + 1:s.prompt.index(")")]
        if s.prompt.startswith("q:rev"):
            assert s.final == arg[::-1]
        else:
            assert s.final == arg + arg


def test_list_op_semantics():
    for s in tasks.generate("list-op", 64, seed=13):
        arg = s.prompt[s.prompt.index("(") + 1:s.prompt.index(")")]
        if "sort" in s.prompt:
            assert s.final == "".join(sorted(arg))
        elif "max" in s.prompt:
            assert s.final == max(arg)
        else:
            assert s.final == min(arg)


@pytest.mark.parametrize("family", tasks.FAMILIES)
def test_encode_fits_geometry(family):
    """Every generated sample must fit the fixed prompt/gen geometry."""
    for s in tasks.generate(family, 128, seed=17):
        p, a = tasks.encode_example(family, s, CFG.prompt_len, CFG.gen_len)
        assert len(p) == CFG.prompt_len
        assert len(a) == CFG.gen_len
        assert vocab.EOS in a


def test_encode_left_pads_prompt():
    s = tasks.generate("list-op", 1, seed=1)[0]
    p, _ = tasks.encode_example("list-op", s, CFG.prompt_len, CFG.gen_len)
    first = next(i for i, t in enumerate(p) if t != vocab.PAD)
    assert p[first] == vocab.BOS
    assert all(t == vocab.PAD for t in p[:first])
    assert all(t != vocab.PAD for t in p[first:])


def test_few_shot_protocol():
    assert tasks.NUM_SHOTS["chain-arith"] == 1
    assert tasks.NUM_SHOTS["str-transform"] == 0  # coding: 0-shot (paper)
    shots = tasks.few_shot_examples("chain-arith")
    assert len(shots) == 1
    # shots are fixed across calls
    assert tasks.few_shot_examples("chain-arith")[0].prompt == shots[0].prompt


def test_extract_final_and_score():
    assert tasks.extract_final("3*4=12;#17;") == "17"
    assert tasks.extract_final("nothing here") is None
    s = tasks.Sample("q", "a", "17")
    assert tasks.score("blah#17;<pad>", s)
    assert not tasks.score("blah#18;", s)
    assert not tasks.score("17", s)


def test_scoring_truncates_at_semicolon():
    s = tasks.Sample("q", "a", "17")
    assert tasks.score("#17;junk#99", s) is False  # last '#' wins
    assert tasks.score("x#17;trailing", s)
