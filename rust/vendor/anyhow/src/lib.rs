//! Minimal, std-only stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no vendored registry
//! beyond this repository, so the serving crate depends on this shim by
//! path. It covers exactly the surface the codebase uses:
//!
//! * [`Error`] — an opaque error that any `std::error::Error` converts
//!   into via `?`, displayable with `{e}` (top message) and `{e:#}`
//!   (full cause chain, `a: b: c`);
//! * [`Result`] — `Result<T, Error>` alias;
//! * [`anyhow!`], [`ensure!`], [`bail!`] — the construction macros.
//!
//! Context methods and downcasting are intentionally omitted; add them
//! here if a future caller needs them rather than reaching for the real
//! crate.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Opaque boxed error with a cause chain.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Build an error from a plain message.
    pub fn msg(message: impl Into<String>) -> Error {
        Error(Box::new(MessageError(message.into())))
    }

    /// Build an error from any concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error(Box::new(error))
    }

    /// The top-level cause chain, starting at this error.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        Chain { next: Some(&*self.0 as &(dyn StdError + 'static)) }
    }
}

struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: the full cause chain, anyhow-style
            for (i, cause) in self.chain().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{cause}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.0)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let causes: Vec<_> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// A plain-message error (what `anyhow!("...")` produces).
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl StdError for MessageError {}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                ::std::concat!("condition failed: ", ::std::stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("boom {}", 42))
    }

    #[test]
    fn message_roundtrip() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");
    }

    #[test]
    fn std_errors_convert_via_question_mark() {
        fn parse() -> Result<i32> {
            let n: i32 = "not-a-number".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn ensure_formats_condition() {
        fn check(x: i32) -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(())
        }
        assert!(check(1).is_ok());
        let e = check(-1).unwrap_err();
        assert_eq!(e.to_string(), "x must be positive, got -1");
    }

    #[test]
    fn bail_returns_error() {
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert_eq!(f().unwrap_err().to_string(), "nope");
    }

    #[test]
    fn inline_captures_in_literal() {
        let key = "geometry";
        let e: Error = anyhow!("missing json key '{key}'");
        assert_eq!(e.to_string(), "missing json key 'geometry'");
    }
}
