//! API-surface stub of the offline `xla` crate (PJRT bindings).
//!
//! The real crate wraps the XLA PJRT C API and cannot live in this
//! repository (native closure, registry-less environment). What *can*
//! bit-rot silently is the `pjrt` feature's Rust code in
//! `rust/src/runtime/pjrt.rs`, which compiles only against this crate's
//! signatures. This stub mirrors exactly the API subset that code uses
//! so `cargo check --workspace --all-targets --features pjrt` stays a
//! meaningful CI gate.
//!
//! Semantics: constructors of plain values (`Literal::vec1`,
//! `Literal::scalar`, `XlaComputation::from_proto`) succeed; every
//! entry point that would touch PJRT returns [`Error`] at runtime. To
//! actually execute programs, replace this directory with the real
//! vendored xla crate closure — the signatures are compatible.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Stub error: every fallible entry point returns this at runtime.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "xla stub: {what} is unavailable (this build vendors the \
             API-surface stub of the xla crate; install the real \
             closure at rust/vendor/xla to execute PJRT programs)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can carry (the subset the seam uses).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host tensor value (stub: carries no data).
#[derive(Debug, Default)]
pub struct Literal;

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    /// Rank-0 i32 literal.
    pub fn scalar(_v: i32) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }

    pub fn copy_raw_from<T: NativeType>(&mut self, _data: &[T]) -> Result<()> {
        Err(Error::stub("Literal::copy_raw_from"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::stub("Literal::array_shape"))
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// npy/npz loading surface (the real crate implements this for
/// `Literal` over raw numpy bytes).
pub trait FromRawBytes: Sized {
    fn read_npz(path: &Path, config: &()) -> Result<Vec<(String, Self)>>;
    fn read_npy(path: &Path, config: &()) -> Result<Self>;
}

impl FromRawBytes for Literal {
    fn read_npz(_path: &Path, _config: &()) -> Result<Vec<(String, Self)>> {
        Err(Error::stub("Literal::read_npz"))
    }

    fn read_npy(_path: &Path, _config: &()) -> Result<Self> {
        Err(Error::stub("Literal::read_npy"))
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<B: Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub("PjRtClient::buffer_from_host_literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_succeed_and_runtime_calls_error() {
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(Literal::scalar(3).to_tuple().is_err());
        assert!(PjRtClient::cpu().is_err());
        let err = Literal::vec1(&[1i32]).to_vec::<i32>().unwrap_err();
        assert!(err.to_string().contains("xla stub"), "{err}");
    }
}
