//! Per-request decode state machine.
//!
//! A `SequenceState` tracks one request through block-wise refinement:
//! which generation positions are still `[MASK]`, the active block
//! cursor, step/model-call accounting (paper §A.3 protocol), and the
//! finalization policy (confidence-thresholded parallel finalization
//! with a guaranteed top-1 per step — paper §4.3 / Fast-dLLM).

use std::time::{Duration, Instant};

use super::methods::DecodeOutcome;
use crate::runtime::Geometry;
use crate::tokenizer::{EOS, MASK};

#[derive(Debug, Clone)]
pub struct SequenceState {
    pub prompt_ids: Vec<i32>, // [P], left-padded
    pub valid_from: i32,
    pub gen: Vec<i32>, // [Lg], MASK until finalized
    pub steps: u64,
    /// Model executions attributable to this sequence, including cache
    /// commits (steps counts only refinement steps, as the paper does).
    pub model_calls: u64,
    pub done: bool,
    started: Instant,
    /// When the first generation token was revealed (serving TTFT).
    first_finalized: Option<Instant>,
    finished: Option<Instant>,
}

impl SequenceState {
    /// Borrowing constructor: the prompt is copied exactly once, here —
    /// callers (including the scheduler's dead-lane padding) never need
    /// to own prompt buffers.
    pub fn new(geom: &Geometry, prompt_ids: &[i32]) -> Self {
        assert_eq!(prompt_ids.len(), geom.prompt_len, "prompt must be padded");
        let valid_from = prompt_ids
            .iter()
            .position(|&t| t != geom.pad)
            .unwrap_or(geom.prompt_len) as i32;
        Self {
            prompt_ids: prompt_ids.to_vec(),
            valid_from,
            gen: vec![MASK; geom.gen_len],
            steps: 0,
            model_calls: 0,
            done: false,
            started: Instant::now(),
            first_finalized: None,
            finished: None,
        }
    }

    pub fn restart_clock(&mut self) {
        self.started = Instant::now();
        self.first_finalized = None;
        self.finished = None;
    }

    /// Record the first-token instant. The finalize helpers call this;
    /// engines that write `gen` directly (AR, speculative) call it
    /// themselves after the write.
    pub fn note_finalized(&mut self) {
        if self.first_finalized.is_none() {
            self.first_finalized = Some(Instant::now());
        }
    }

    /// Masked positions within [lo, lo+len) of the generation span.
    pub fn masked_in(&self, lo: usize, len: usize) -> Vec<usize> {
        (lo..lo + len).filter(|&i| self.gen[i] == MASK).collect()
    }

    pub fn block_fully_finalized(&self, lo: usize, len: usize) -> bool {
        self.gen[lo..lo + len].iter().all(|&t| t != MASK)
    }

    /// Confidence-thresholded parallel finalization over one block
    /// (gen-span offsets [lo, lo+len)). Reveals every masked position
    /// with conf >= tau; if none clears the bar, reveals the single
    /// most-confident masked position so progress is guaranteed.
    /// Returns the number of tokens finalized.
    ///
    /// Runs as a single allocation-free pass (this is called once per
    /// lane per refinement step — the hot path's zero-allocation gate
    /// covers it): the reveal and the fallback argmax share one scan,
    /// with first-maximum tie-breaking (matches python argmax semantics
    /// — ties are real: softmax confidence saturates at 1.0).
    pub fn finalize_threshold(
        &mut self,
        lo: usize,
        toks: &[i32],  // [len] proposed tokens for the block
        confs: &[f32], // [len]
        tau: f32,
    ) -> usize {
        let len = toks.len();
        let mut finalized = 0;
        let mut best: Option<usize> = None; // first-max masked offset
        for i in 0..len {
            if self.gen[lo + i] != MASK {
                continue;
            }
            match best {
                Some(b) if confs[b] >= confs[i] => {}
                _ => best = Some(i),
            }
            if confs[i] >= tau {
                self.gen[lo + i] = toks[i];
                finalized += 1;
            }
        }
        let Some(best) = best else {
            return 0; // nothing masked in the block
        };
        if finalized == 0 {
            self.gen[lo + best] = toks[best];
            finalized = 1;
        }
        self.note_finalized();
        finalized
    }

    /// Top-m finalization (vanilla / truncated-step baselines): reveal
    /// the m most confident masked positions in the block.
    ///
    /// Allocation-free repeated selection instead of sort-and-take: m is
    /// small (1 in every configured baseline) and each round picks the
    /// first maximum among the still-masked positions, which reveals the
    /// exact set (and order) the old stable descending sort did.
    pub fn finalize_top_m(
        &mut self,
        lo: usize,
        toks: &[i32],
        confs: &[f32],
        m: usize,
    ) -> usize {
        let len = toks.len();
        let remaining =
            (0..len).filter(|&i| self.gen[lo + i] == MASK).count();
        if remaining == 0 {
            return 0;
        }
        let take = remaining.min(m.max(1));
        for _ in 0..take {
            let mut best: Option<usize> = None;
            for i in 0..len {
                if self.gen[lo + i] != MASK {
                    continue;
                }
                match best {
                    Some(b) if confs[b] >= confs[i] => {}
                    _ => best = Some(i),
                }
            }
            let b = best.expect("remaining masked positions cover take");
            self.gen[lo + b] = toks[b];
        }
        self.note_finalized();
        take
    }

    /// Early stop check: a finalized <eos> within [lo, lo+len)
    /// terminates the request at the block boundary (paper §4.3).
    pub fn eos_in(&self, lo: usize, len: usize) -> bool {
        self.gen[lo..lo + len].iter().any(|&t| t == EOS)
    }

    pub fn mark_done(&mut self) {
        if !self.done {
            self.done = true;
            self.finished = Some(Instant::now());
        }
    }

    pub fn latency(&self) -> Duration {
        self.finished.unwrap_or_else(Instant::now) - self.started
    }

    /// Time from decode start to the first revealed token (decode-side
    /// TTFT; the serving layer adds queueing delay on top).
    pub fn ttft(&self) -> Duration {
        self.first_finalized
            .or(self.finished)
            .unwrap_or_else(Instant::now)
            - self.started
    }

    /// Close out the sequence as a [`DecodeOutcome`] — the one place
    /// every engine (closed-batch and block-step machine) converts
    /// per-lane state into a result, so the §A.3 accounting fields are
    /// assembled identically everywhere.
    pub fn into_outcome(mut self) -> DecodeOutcome {
        self.mark_done();
        DecodeOutcome {
            gen_len: self.gen_length(),
            steps: self.steps,
            model_calls: self.model_calls,
            latency: self.latency(),
            ttft: self.ttft(),
            gen: std::mem::take(&mut self.gen),
        }
    }

    /// Valid generated tokens before the first <eos> (paper §A.3).
    pub fn gen_length(&self) -> usize {
        let end = self
            .gen
            .iter()
            .position(|&t| t == EOS)
            .unwrap_or(self.gen.len());
        self.gen[..end].iter().filter(|&&t| t != MASK).count()
    }

    /// Write the full sequence [P + Lg] (prompt + generation) into a
    /// caller-owned row — the allocation-free form the full-seq engines
    /// use with their reused id buffers.
    pub fn copy_full_ids_into(&self, row: &mut [i32]) {
        let p = self.prompt_ids.len();
        row[..p].copy_from_slice(&self.prompt_ids);
        row[p..].copy_from_slice(&self.gen);
    }

    /// Full sequence [P + Lg] as an owned vector.
    pub fn full_ids(&self) -> Vec<i32> {
        let mut out = vec![0; self.prompt_ids.len() + self.gen.len()];
        self.copy_full_ids_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::PAD;
    use crate::util::prop::check;
    use crate::util::rng::SplitMix64;

    fn geom() -> Geometry {
        Geometry {
            vocab_size: 64,
            d_model: 96,
            n_layers: 3,
            n_heads: 4,
            d_head: 24,
            d_ff: 192,
            prompt_len: 8,
            gen_len: 8,
            block_size: 4,
            seq_len: 16,
            pad: PAD,
            mask: MASK,
            bos: 2,
            eos: EOS,
        }
    }

    fn seq() -> SequenceState {
        let mut p = vec![PAD; 8];
        p[3] = 2;
        for (i, t) in p.iter_mut().enumerate().skip(4) {
            *t = 10 + i as i32;
        }
        SequenceState::new(&geom(), &p)
    }

    #[test]
    fn valid_from_detects_padding() {
        assert_eq!(seq().valid_from, 3);
    }

    #[test]
    fn threshold_finalizes_confident_tokens() {
        let mut s = seq();
        let toks = vec![5, 6, 7, 8];
        let confs = vec![0.95, 0.5, 0.91, 0.2];
        let n = s.finalize_threshold(0, &toks, &confs, 0.9);
        assert_eq!(n, 2);
        assert_eq!(s.gen[0], 5);
        assert_eq!(s.gen[1], MASK);
        assert_eq!(s.gen[2], 7);
    }

    #[test]
    fn threshold_guarantees_progress() {
        let mut s = seq();
        let confs = vec![0.1, 0.3, 0.2, 0.05];
        let n = s.finalize_threshold(0, &[5, 6, 7, 8], &confs, 0.9);
        assert_eq!(n, 1);
        assert_eq!(s.gen[1], 6, "most confident masked position wins");
    }

    #[test]
    fn threshold_skips_already_finalized() {
        let mut s = seq();
        s.gen[0] = 9;
        let n = s.finalize_threshold(0, &[5, 6, 7, 8], &[1.0, 1.0, 0.0, 0.0], 0.9);
        assert_eq!(n, 1); // only position 1 (position 0 already set)
        assert_eq!(s.gen[0], 9, "finalized tokens are immutable");
    }

    #[test]
    fn top_m_takes_most_confident() {
        let mut s = seq();
        let n = s.finalize_top_m(4, &[5, 6, 7, 8], &[0.1, 0.9, 0.5, 0.7], 2);
        assert_eq!(n, 2);
        assert_eq!(s.gen[5], 6);
        assert_eq!(s.gen[7], 8);
        assert_eq!(s.gen[4], MASK);
    }

    #[test]
    fn gen_length_stops_at_eos() {
        let mut s = seq();
        s.gen = vec![10, 11, EOS, 12, MASK, MASK, MASK, MASK];
        assert_eq!(s.gen_length(), 2);
    }

    #[test]
    fn eos_detection_block_scoped() {
        let mut s = seq();
        s.gen[5] = EOS;
        assert!(!s.eos_in(0, 4));
        assert!(s.eos_in(4, 4));
    }

    #[test]
    fn full_ids_concatenates() {
        let s = seq();
        let ids = s.full_ids();
        assert_eq!(ids.len(), 16);
        assert_eq!(&ids[..8], &s.prompt_ids[..]);
    }

    #[test]
    fn property_finalization_monotone_and_terminating() {
        // repeated threshold finalization must strictly reduce the
        // masked set and terminate within len steps, for any confidences
        check("finalize-terminates", 100, |r: &mut SplitMix64| {
            let mut s = seq();
            let tau = 0.5 + r.f64() as f32 * 0.5;
            let mut iters = 0;
            while !s.block_fully_finalized(0, 4) {
                let confs: Vec<f32> =
                    (0..4).map(|_| r.f64() as f32).collect();
                let before = s.masked_in(0, 4).len();
                let n = s.finalize_threshold(0, &[5, 6, 7, 8], &confs, tau);
                let after = s.masked_in(0, 4).len();
                if !(n >= 1 && after == before - n) {
                    return false;
                }
                iters += 1;
                if iters > 4 {
                    return false;
                }
            }
            true
        });
    }
}
