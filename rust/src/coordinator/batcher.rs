//! Dynamic batcher: groups concurrent requests by (backbone, method,
//! tau) and flushes when a full bucket accumulates or the batching
//! window expires — the standard continuous-serving front half
//! (vLLM-style), sized for the lockstep block-diffusion engines behind
//! it. The continuous worker additionally drains compatible requests
//! straight into in-flight batches with [`DynamicBatcher::take_for`].

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::methods::Method;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub backbone: String,
    pub method: Method,
    /// Confidence-threshold override, as bits (f32 is not `Hash`/`Eq`).
    /// The closed-batch path folds each request's tau override in here
    /// so a whole group decodes with one tau and no request ever decodes
    /// with another request's threshold; the block-step machine instead
    /// carries tau per lane and leaves this `None` to batch across
    /// overrides.
    pub tau_bits: Option<u32>,
}

impl GroupKey {
    pub fn new(backbone: impl Into<String>, method: Method) -> GroupKey {
        GroupKey { backbone: backbone.into(), method, tau_bits: None }
    }

    /// Fold a per-request tau override into the key (closed-batch path).
    pub fn with_tau(mut self, tau: Option<f32>) -> GroupKey {
        self.tau_bits = tau.map(f32::to_bits);
        self
    }

    pub fn tau(&self) -> Option<f32> {
        self.tau_bits.map(f32::from_bits)
    }
}

#[derive(Debug)]
pub struct Pending<T> {
    pub key: GroupKey,
    pub payload: T,
    pub enqueued: Instant,
    /// Client deadline: a request still queued past this instant is
    /// dead weight — [`DynamicBatcher::take_for`] refuses to admit it
    /// (no lane, no prefill, no prefix-chain pin) and hands it back as
    /// expired so the worker can answer it with a terminal abort.
    pub deadline: Option<Instant>,
}

/// Accumulates pending requests per group; `pop_ready` returns a batch
/// when a group fills `max_batch` or its oldest member exceeds
/// `max_wait`. A running element count keeps `len()` O(1) (it used to
/// walk every group queue), and each pop clones the popped `GroupKey`
/// exactly once.
pub struct DynamicBatcher<T> {
    pub max_batch: usize,
    pub max_wait: Duration,
    queues: HashMap<GroupKey, Vec<Pending<T>>>,
    count: usize,
    pub total_enqueued: u64,
    pub total_batches: u64,
}

impl<T> DynamicBatcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self {
            max_batch,
            max_wait,
            queues: HashMap::new(),
            count: 0,
            total_enqueued: 0,
            total_batches: 0,
        }
    }

    pub fn push(&mut self, p: Pending<T>) {
        self.total_enqueued += 1;
        self.count += 1;
        self.queues.entry(p.key.clone()).or_default().push(p);
    }

    /// Pending requests across all groups (running count, O(1)).
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Next batch to run, if any group is ready at `now`.
    pub fn pop_ready(
        &mut self,
        now: Instant,
    ) -> Option<(GroupKey, Vec<Pending<T>>)> {
        let key = self
            .queues
            .iter()
            .find(|(_, q)| {
                !q.is_empty()
                    && (q.len() >= self.max_batch
                        || now.duration_since(q[0].enqueued) >= self.max_wait)
            })
            .map(|(k, _)| k.clone())?;
        let batch = self.drain(&key, self.max_batch);
        self.total_batches += 1;
        Some((key, batch))
    }

    /// Force-flush the oldest group regardless of readiness (shutdown
    /// drain, and the continuous worker's batch opening — a block-step
    /// machine admits later arrivals mid-flight, so there is nothing to
    /// gain by holding requests back for a fuller bucket).
    pub fn pop_any(&mut self) -> Option<(GroupKey, Vec<Pending<T>>)> {
        let key = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q[0].enqueued)
            .map(|(k, _)| k.clone())?;
        let batch = self.drain(&key, self.max_batch);
        self.total_batches += 1;
        Some((key, batch))
    }

    /// Admission drain: up to `n` oldest *live* requests for exactly
    /// `key`, ignoring readiness — they are joining an in-flight batch
    /// at a block boundary, so waiting out the batching window would
    /// only add latency. Requests whose deadline already passed at
    /// `now` are skipped (they must not consume a lane, a prefill
    /// model call, or a prefix-chain pin) and returned as the second
    /// vector so the caller can terminate them; they do not count
    /// toward `n`. Does not count as a popped batch in
    /// `total_batches`.
    #[allow(clippy::type_complexity)]
    pub fn take_for(
        &mut self,
        key: &GroupKey,
        n: usize,
        now: Instant,
    ) -> (Vec<Pending<T>>, Vec<Pending<T>>) {
        let (mut fresh, mut expired) = (Vec::new(), Vec::new());
        let Some(q) = self.queues.get_mut(key).filter(|_| n > 0) else {
            return (fresh, expired);
        };
        // oldest first: stop once n live requests are in hand (later
        // expired entries are caught by the next admission pass)
        let mut consumed = 0;
        let mut live = 0;
        for p in q.iter() {
            if live >= n {
                break;
            }
            consumed += 1;
            if !p.deadline.is_some_and(|d| now > d) {
                live += 1;
            }
        }
        for p in q.drain(..consumed) {
            if p.deadline.is_some_and(|d| now > d) {
                expired.push(p);
            } else {
                fresh.push(p);
            }
        }
        if q.is_empty() {
            self.queues.remove(key);
        }
        self.count -= consumed;
        (fresh, expired)
    }

    /// Queued requests for exactly `key` (work-stealing victim probe).
    pub fn len_for(&self, key: &GroupKey) -> usize {
        self.queues.get(key).map_or(0, Vec::len)
    }

    /// Highest scheduling weight among `key`'s queued requests, scored
    /// by the caller's `weight` (the serving worker passes its
    /// age-boosted effective priority). `None` when nothing of `key` is
    /// queued — the preemption pass reads that as "no challenger" and
    /// leaves every live lane alone.
    pub fn max_priority_for(
        &self,
        key: &GroupKey,
        weight: impl Fn(&Pending<T>) -> i64,
    ) -> Option<i64> {
        self.queues.get(key)?.iter().map(weight).max()
    }

    /// Work-stealing drain: up to `n` oldest *live* requests of `key`
    /// that have already waited at least `min_wait` at `now`. The age
    /// gate keeps thieves honest — a fresh arrival routed here by
    /// prefix affinity is left for this shard to admit within its own
    /// batching window; only requests the shard failed to serve within
    /// that window are fair game for an idle sibling. Queues are
    /// oldest-first, so the scan stops at the first too-young request.
    /// Expired requests ahead of the cut are handed back separately,
    /// exactly like [`DynamicBatcher::take_for`].
    #[allow(clippy::type_complexity)]
    pub fn steal_for(
        &mut self,
        key: &GroupKey,
        n: usize,
        now: Instant,
        min_wait: Duration,
    ) -> (Vec<Pending<T>>, Vec<Pending<T>>) {
        let (mut fresh, mut expired) = (Vec::new(), Vec::new());
        let Some(q) = self.queues.get_mut(key).filter(|_| n > 0) else {
            return (fresh, expired);
        };
        let mut consumed = 0;
        let mut live = 0;
        for p in q.iter() {
            if live >= n || now.duration_since(p.enqueued) < min_wait {
                break;
            }
            consumed += 1;
            if !p.deadline.is_some_and(|d| now > d) {
                live += 1;
            }
        }
        for p in q.drain(..consumed) {
            if p.deadline.is_some_and(|d| now > d) {
                expired.push(p);
            } else {
                fresh.push(p);
            }
        }
        if q.is_empty() {
            self.queues.remove(key);
        }
        self.count -= consumed;
        (fresh, expired)
    }

    /// Drain every queued request (any key) whose deadline has passed
    /// at `now`. The serving workers run this once per loop iteration,
    /// so an expired request releases its queue permit and receives its
    /// terminal abort within one wakeup — it never has to wait for a
    /// free lane of its own key to be discovered by `take_for`.
    pub fn take_expired(&mut self, now: Instant) -> Vec<Pending<T>> {
        let mut out = Vec::new();
        self.queues.retain(|_key, q| {
            if q.iter().any(|p| p.deadline.is_some_and(|d| now > d)) {
                let mut kept = Vec::with_capacity(q.len());
                for p in q.drain(..) {
                    if p.deadline.is_some_and(|d| now > d) {
                        out.push(p);
                    } else {
                        kept.push(p);
                    }
                }
                *q = kept;
            }
            !q.is_empty()
        });
        self.count -= out.len();
        out
    }

    /// Pure queue removal (callers that pop whole batches account
    /// `total_batches` themselves).
    fn drain(&mut self, key: &GroupKey, n: usize) -> Vec<Pending<T>> {
        let Some(q) = self.queues.get_mut(key) else {
            return Vec::new();
        };
        let take = q.len().min(n);
        let batch: Vec<Pending<T>> = q.drain(..take).collect();
        if q.is_empty() {
            self.queues.remove(key); // keep ready-scans proportional to live groups
        }
        self.count -= batch.len();
        batch
    }

    /// Distinct queued group keys, oldest head-of-line first (the
    /// continuous worker opens block-step batches in this order).
    pub fn keys_by_age(&self) -> Vec<GroupKey> {
        let mut ks: Vec<(&GroupKey, Instant)> = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(k, q)| (k, q[0].enqueued))
            .collect();
        ks.sort_by_key(|&(_, t)| t);
        ks.into_iter().map(|(k, _)| k.clone()).collect()
    }

    /// Earliest deadline across queues (for the worker's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|p| p.enqueued + self.max_wait)
            .min()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn key(m: Method) -> GroupKey {
        GroupKey::new("dream", m)
    }

    fn pend(m: Method, v: u32, t: Instant) -> Pending<u32> {
        Pending { key: key(m), payload: v, enqueued: t, deadline: None }
    }

    fn payloads(batch: Vec<Pending<u32>>) -> Vec<u32> {
        batch.into_iter().map(|p| p.payload).collect()
    }

    #[test]
    fn flushes_on_full_bucket() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        assert!(b.pop_ready(t).is_none(), "not full, not timed out");
        b.push(pend(Method::Cdlm, 2, t));
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.method, Method::Cdlm);
        assert_eq!(payloads(batch), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(pend(Method::Ar, 7, t0));
        assert!(b.pop_ready(t0).is_none());
        let later = t0 + Duration::from_millis(6);
        let (_, batch) = b.pop_ready(later).unwrap();
        assert_eq!(payloads(batch), vec![7]);
    }

    #[test]
    fn groups_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        assert!(b.pop_ready(t).is_none(), "neither group full");
        b.push(pend(Method::Cdlm, 3, t));
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.method, Method::Cdlm);
        assert_eq!(payloads(batch), vec![1, 3]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn tau_overrides_never_share_a_group() {
        // satellite regression: the closed-batch path folds tau into the
        // key, so a 0.5-tau request can never decode with a 0.9-tau
        // group (it used to inherit whichever override came first)
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        let k_hi = key(Method::Cdlm).with_tau(Some(0.9));
        let k_lo = key(Method::Cdlm).with_tau(Some(0.5));
        assert_ne!(k_hi, k_lo);
        b.push(Pending {
            key: k_hi.clone(),
            payload: 1u32,
            enqueued: t,
            deadline: None,
        });
        b.push(Pending {
            key: k_lo.clone(),
            payload: 2u32,
            enqueued: t,
            deadline: None,
        });
        assert!(b.pop_ready(t).is_none(), "different taus, neither full");
        b.push(Pending {
            key: k_hi.clone(),
            payload: 3u32,
            enqueued: t,
            deadline: None,
        });
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.tau(), Some(0.9));
        assert_eq!(payloads(batch), vec![1, 3]);
    }

    #[test]
    fn batch_respects_max_size() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(0));
        let t = Instant::now();
        for i in 0..5 {
            b.push(pend(Method::Cdlm, i, t));
        }
        let (_, batch) = b.pop_ready(t).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn pop_any_drains_everything() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(100));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        assert!(b.pop_any().is_some());
        assert!(b.pop_any().is_some());
        assert!(b.pop_any().is_none());
    }

    #[test]
    fn take_for_drains_only_matching_key_ignoring_readiness() {
        let mut b = DynamicBatcher::new(8, Duration::from_secs(100));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        b.push(pend(Method::Cdlm, 3, t));
        // nothing is "ready" (bucket not full, window not expired) but
        // admission takes matching requests immediately
        assert!(b.pop_ready(t).is_none());
        let got = payloads(b.take_for(&key(Method::Cdlm), 1, t).0);
        assert_eq!(got, vec![1], "oldest matching request first");
        let got = payloads(b.take_for(&key(Method::Cdlm), 4, t).0);
        assert_eq!(got, vec![3]);
        assert!(b.take_for(&key(Method::Cdlm), 4, t).0.is_empty());
        assert_eq!(b.len(), 1, "other keys untouched");
        assert!(b.take_for(&key(Method::Ar), 0, t).0.is_empty());
        assert_eq!(payloads(b.take_for(&key(Method::Ar), 1, t).0), vec![2]);
        assert!(b.is_empty());
    }

    #[test]
    fn take_for_skips_expired_requests_without_consuming_lanes() {
        // satellite: a dead client's queued request must not get a
        // lane — take_for hands it back as expired, and the live
        // request behind it still fills the single requested lane
        let mut b = DynamicBatcher::new(8, Duration::from_secs(100));
        let t = Instant::now();
        let mut dead = pend(Method::Cdlm, 1, t);
        dead.deadline = Some(t);
        b.push(dead);
        b.push(pend(Method::Cdlm, 2, t));
        let later = t + Duration::from_millis(1);
        let (fresh, expired) = b.take_for(&key(Method::Cdlm), 1, later);
        assert_eq!(payloads(fresh), vec![2], "live request got the lane");
        assert_eq!(payloads(expired), vec![1], "expired handed back");
        assert!(b.is_empty(), "count balanced across both outcomes");
        // an unexpired deadline is admitted normally
        let mut live = pend(Method::Cdlm, 3, t);
        live.deadline = Some(later + Duration::from_secs(5));
        b.push(live);
        let (fresh, expired) = b.take_for(&key(Method::Cdlm), 1, later);
        assert_eq!(payloads(fresh), vec![3]);
        assert!(expired.is_empty());
    }

    #[test]
    fn steal_for_honors_the_age_gate() {
        let mut b = DynamicBatcher::new(8, Duration::from_secs(100));
        let t = Instant::now();
        let window = Duration::from_millis(10);
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Cdlm, 2, t + Duration::from_millis(8)));
        assert_eq!(b.len_for(&key(Method::Cdlm)), 2);
        // at t+5ms nothing has waited out the window: no steal
        let early = t + Duration::from_millis(5);
        let (fresh, _) = b.steal_for(&key(Method::Cdlm), 4, early, window);
        assert!(fresh.is_empty(), "fresh arrivals are not stealable");
        assert_eq!(b.len(), 2);
        // at t+12ms only the first request is old enough; the second is
        // behind it and too young, so the scan stops there
        let later = t + Duration::from_millis(12);
        let (fresh, _) = b.steal_for(&key(Method::Cdlm), 4, later, window);
        assert_eq!(payloads(fresh), vec![1]);
        assert_eq!(b.len(), 1, "younger request left for its own shard");
        assert_eq!(b.len_for(&key(Method::Cdlm)), 1);
        // once it too ages out, it is stealable — and expired requests
        // ahead of the cut are handed back, never stolen into a lane
        let mut dead = pend(Method::Cdlm, 3, t);
        dead.deadline = Some(t);
        b.push(dead);
        let done = t + Duration::from_secs(1);
        let (fresh, expired) = b.steal_for(&key(Method::Cdlm), 4, done, window);
        assert_eq!(payloads(fresh), vec![2]);
        assert_eq!(payloads(expired), vec![3]);
        assert!(b.is_empty(), "count balanced across both outcomes");
    }

    #[test]
    fn take_expired_sweeps_every_key_and_balances_the_count() {
        let mut b = DynamicBatcher::new(8, Duration::from_secs(100));
        let t = Instant::now();
        let mut dead_cdlm = pend(Method::Cdlm, 1, t);
        dead_cdlm.deadline = Some(t);
        let mut dead_ar = pend(Method::Ar, 2, t);
        dead_ar.deadline = Some(t);
        let mut live = pend(Method::Cdlm, 3, t);
        live.deadline = Some(t + Duration::from_secs(60));
        b.push(dead_cdlm);
        b.push(dead_ar);
        b.push(live);
        b.push(pend(Method::Vanilla, 4, t)); // no deadline: never expires
        let later = t + Duration::from_millis(1);
        let mut expired = payloads(b.take_expired(later));
        expired.sort_unstable();
        assert_eq!(expired, vec![1, 2], "both keys' dead requests swept");
        assert_eq!(b.len(), 2, "count released with the permits");
        assert!(b.take_expired(later).is_empty(), "idempotent");
        // the survivors are still poppable
        let mut rest = Vec::new();
        while let Some((_, batch)) = b.pop_any() {
            rest.extend(payloads(batch));
        }
        rest.sort_unstable();
        assert_eq!(rest, vec![3, 4]);
    }

    #[test]
    fn max_priority_scans_only_the_requested_key() {
        let mut b = DynamicBatcher::new(8, Duration::from_secs(100));
        let t = Instant::now();
        assert_eq!(
            b.max_priority_for(&key(Method::Cdlm), |p| p.payload as i64),
            None,
            "empty key has no challenger"
        );
        b.push(pend(Method::Cdlm, 3, t));
        b.push(pend(Method::Cdlm, 7, t));
        b.push(pend(Method::Ar, 99, t));
        assert_eq!(
            b.max_priority_for(&key(Method::Cdlm), |p| p.payload as i64),
            Some(7)
        );
        assert_eq!(
            b.max_priority_for(&key(Method::Vanilla), |p| p.payload as i64),
            None
        );
    }

    #[test]
    fn running_count_tracks_push_and_pop() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(0));
        let t = Instant::now();
        assert_eq!(b.len(), 0);
        for i in 0..5 {
            b.push(pend(Method::Cdlm, i, t));
        }
        b.push(pend(Method::Ar, 9, t));
        assert_eq!(b.len(), 6);
        let (_, batch) = b.pop_ready(t).unwrap();
        assert_eq!(b.len(), 6 - batch.len());
        while b.pop_any().is_some() {}
        assert_eq!(b.len(), 0);
        assert!(b.is_empty());
        assert_eq!(b.total_batches, 4, "5 cdlm in batches of 2 + 1 ar");
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        check("batcher-conservation", 50, |r| {
            let mut b =
                DynamicBatcher::new(1 + r.index(4), Duration::from_secs(100));
            let t = Instant::now();
            let n = 1 + r.index(30);
            let methods = [Method::Cdlm, Method::Ar, Method::Vanilla];
            for i in 0..n {
                b.push(pend(methods[r.index(3)], i as u32, t));
            }
            let mut seen = Vec::new();
            // interleave admission drains with batch pops
            loop {
                if r.below(2) == 0 {
                    let k = key(methods[r.index(3)]);
                    seen.extend(payloads(
                        b.take_for(&k, 1 + r.index(3), t).0,
                    ));
                } else if let Some((_, batch)) = b.pop_any() {
                    seen.extend(payloads(batch));
                } else if b.is_empty() {
                    break;
                }
            }
            seen.sort_unstable();
            seen == (0..n as u32).collect::<Vec<_>>()
        });
    }
}
