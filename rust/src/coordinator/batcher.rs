//! Dynamic batcher: groups concurrent requests by (backbone, method)
//! and flushes when a full bucket accumulates or the batching window
//! expires — the standard continuous-serving front half (vLLM-style),
//! sized for the lockstep block-diffusion engines behind it.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::methods::Method;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub backbone: String,
    pub method: Method,
}

#[derive(Debug)]
pub struct Pending<T> {
    pub key: GroupKey,
    pub payload: T,
    pub enqueued: Instant,
}

/// Accumulates pending requests per group; `pop_ready` returns a batch
/// when a group fills `max_batch` or its oldest member exceeds
/// `max_wait`. A running element count keeps `len()` O(1) (it used to
/// walk every group queue), and each pop clones the popped `GroupKey`
/// exactly once.
pub struct DynamicBatcher<T> {
    pub max_batch: usize,
    pub max_wait: Duration,
    queues: HashMap<GroupKey, Vec<Pending<T>>>,
    count: usize,
    pub total_enqueued: u64,
    pub total_batches: u64,
}

impl<T> DynamicBatcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self {
            max_batch,
            max_wait,
            queues: HashMap::new(),
            count: 0,
            total_enqueued: 0,
            total_batches: 0,
        }
    }

    pub fn push(&mut self, p: Pending<T>) {
        self.total_enqueued += 1;
        self.count += 1;
        self.queues.entry(p.key.clone()).or_default().push(p);
    }

    /// Pending requests across all groups (running count, O(1)).
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Next batch to run, if any group is ready at `now`.
    pub fn pop_ready(&mut self, now: Instant) -> Option<(GroupKey, Vec<T>)> {
        let key = self
            .queues
            .iter()
            .find(|(_, q)| {
                !q.is_empty()
                    && (q.len() >= self.max_batch
                        || now.duration_since(q[0].enqueued) >= self.max_wait)
            })
            .map(|(k, _)| k.clone())?;
        let batch = self.drain(&key);
        Some((key, batch))
    }

    /// Force-flush the oldest group regardless of readiness (shutdown).
    pub fn pop_any(&mut self) -> Option<(GroupKey, Vec<T>)> {
        let key = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q[0].enqueued)
            .map(|(k, _)| k.clone())?;
        let batch = self.drain(&key);
        Some((key, batch))
    }

    fn drain(&mut self, key: &GroupKey) -> Vec<T> {
        let q = self.queues.get_mut(key).unwrap();
        let take = q.len().min(self.max_batch);
        let batch: Vec<T> = q.drain(..take).map(|p| p.payload).collect();
        if q.is_empty() {
            self.queues.remove(key); // keep ready-scans proportional to live groups
        }
        self.count -= batch.len();
        self.total_batches += 1;
        batch
    }

    /// Earliest deadline across queues (for the worker's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|p| p.enqueued + self.max_wait)
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn key(m: Method) -> GroupKey {
        GroupKey { backbone: "dream".into(), method: m }
    }

    fn pend(m: Method, v: u32, t: Instant) -> Pending<u32> {
        Pending { key: key(m), payload: v, enqueued: t }
    }

    #[test]
    fn flushes_on_full_bucket() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        assert!(b.pop_ready(t).is_none(), "not full, not timed out");
        b.push(pend(Method::Cdlm, 2, t));
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.method, Method::Cdlm);
        assert_eq!(batch, vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(pend(Method::Ar, 7, t0));
        assert!(b.pop_ready(t0).is_none());
        let later = t0 + Duration::from_millis(6);
        let (_, batch) = b.pop_ready(later).unwrap();
        assert_eq!(batch, vec![7]);
    }

    #[test]
    fn groups_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        assert!(b.pop_ready(t).is_none(), "neither group full");
        b.push(pend(Method::Cdlm, 3, t));
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.method, Method::Cdlm);
        assert_eq!(batch, vec![1, 3]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn batch_respects_max_size() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(0));
        let t = Instant::now();
        for i in 0..5 {
            b.push(pend(Method::Cdlm, i, t));
        }
        let (_, batch) = b.pop_ready(t).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn pop_any_drains_everything() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(100));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        assert!(b.pop_any().is_some());
        assert!(b.pop_any().is_some());
        assert!(b.pop_any().is_none());
    }

    #[test]
    fn running_count_tracks_push_and_pop() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(0));
        let t = Instant::now();
        assert_eq!(b.len(), 0);
        for i in 0..5 {
            b.push(pend(Method::Cdlm, i, t));
        }
        b.push(pend(Method::Ar, 9, t));
        assert_eq!(b.len(), 6);
        let (_, batch) = b.pop_ready(t).unwrap();
        assert_eq!(b.len(), 6 - batch.len());
        while b.pop_any().is_some() {}
        assert_eq!(b.len(), 0);
        assert!(b.is_empty());
        assert_eq!(b.total_batches, 4, "5 cdlm in batches of 2 + 1 ar");
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        check("batcher-conservation", 50, |r| {
            let mut b = DynamicBatcher::new(1 + r.index(4), Duration::from_secs(100));
            let t = Instant::now();
            let n = 1 + r.index(30);
            let methods = [Method::Cdlm, Method::Ar, Method::Vanilla];
            for i in 0..n {
                b.push(pend(methods[r.index(3)], i as u32, t));
            }
            let mut seen = Vec::new();
            while let Some((_, batch)) = b.pop_any() {
                seen.extend(batch);
            }
            seen.sort_unstable();
            seen == (0..n as u32).collect::<Vec<_>>()
        });
    }
}
