//! Dynamic batcher: groups concurrent requests by (backbone, method,
//! tau) and flushes when a full bucket accumulates or the batching
//! window expires — the standard continuous-serving front half
//! (vLLM-style), sized for the lockstep block-diffusion engines behind
//! it. The continuous worker additionally drains compatible requests
//! straight into in-flight batches with [`DynamicBatcher::take_for`].

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::methods::Method;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct GroupKey {
    pub backbone: String,
    pub method: Method,
    /// Confidence-threshold override, as bits (f32 is not `Hash`/`Eq`).
    /// The closed-batch path folds each request's tau override in here
    /// so a whole group decodes with one tau and no request ever decodes
    /// with another request's threshold; the block-step machine instead
    /// carries tau per lane and leaves this `None` to batch across
    /// overrides.
    pub tau_bits: Option<u32>,
}

impl GroupKey {
    pub fn new(backbone: impl Into<String>, method: Method) -> GroupKey {
        GroupKey { backbone: backbone.into(), method, tau_bits: None }
    }

    /// Fold a per-request tau override into the key (closed-batch path).
    pub fn with_tau(mut self, tau: Option<f32>) -> GroupKey {
        self.tau_bits = tau.map(f32::to_bits);
        self
    }

    pub fn tau(&self) -> Option<f32> {
        self.tau_bits.map(f32::from_bits)
    }
}

#[derive(Debug)]
pub struct Pending<T> {
    pub key: GroupKey,
    pub payload: T,
    pub enqueued: Instant,
}

/// Accumulates pending requests per group; `pop_ready` returns a batch
/// when a group fills `max_batch` or its oldest member exceeds
/// `max_wait`. A running element count keeps `len()` O(1) (it used to
/// walk every group queue), and each pop clones the popped `GroupKey`
/// exactly once.
pub struct DynamicBatcher<T> {
    pub max_batch: usize,
    pub max_wait: Duration,
    queues: HashMap<GroupKey, Vec<Pending<T>>>,
    count: usize,
    pub total_enqueued: u64,
    pub total_batches: u64,
}

impl<T> DynamicBatcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self {
            max_batch,
            max_wait,
            queues: HashMap::new(),
            count: 0,
            total_enqueued: 0,
            total_batches: 0,
        }
    }

    pub fn push(&mut self, p: Pending<T>) {
        self.total_enqueued += 1;
        self.count += 1;
        self.queues.entry(p.key.clone()).or_default().push(p);
    }

    /// Pending requests across all groups (running count, O(1)).
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Next batch to run, if any group is ready at `now`.
    pub fn pop_ready(
        &mut self,
        now: Instant,
    ) -> Option<(GroupKey, Vec<Pending<T>>)> {
        let key = self
            .queues
            .iter()
            .find(|(_, q)| {
                !q.is_empty()
                    && (q.len() >= self.max_batch
                        || now.duration_since(q[0].enqueued) >= self.max_wait)
            })
            .map(|(k, _)| k.clone())?;
        let batch = self.drain(&key, self.max_batch);
        self.total_batches += 1;
        Some((key, batch))
    }

    /// Force-flush the oldest group regardless of readiness (shutdown
    /// drain, and the continuous worker's batch opening — a block-step
    /// machine admits later arrivals mid-flight, so there is nothing to
    /// gain by holding requests back for a fuller bucket).
    pub fn pop_any(&mut self) -> Option<(GroupKey, Vec<Pending<T>>)> {
        let key = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q[0].enqueued)
            .map(|(k, _)| k.clone())?;
        let batch = self.drain(&key, self.max_batch);
        self.total_batches += 1;
        Some((key, batch))
    }

    /// Admission drain: up to `n` oldest requests for exactly `key`,
    /// ignoring readiness — they are joining an in-flight batch at a
    /// block boundary, so waiting out the batching window would only
    /// add latency. Does not count as a popped batch in
    /// `total_batches`.
    pub fn take_for(&mut self, key: &GroupKey, n: usize) -> Vec<Pending<T>> {
        if n == 0 || !self.queues.contains_key(key) {
            return Vec::new();
        }
        self.drain(key, n)
    }

    /// Pure queue removal (callers that pop whole batches account
    /// `total_batches` themselves).
    fn drain(&mut self, key: &GroupKey, n: usize) -> Vec<Pending<T>> {
        let q = self.queues.get_mut(key).unwrap();
        let take = q.len().min(n);
        let batch: Vec<Pending<T>> = q.drain(..take).collect();
        if q.is_empty() {
            self.queues.remove(key); // keep ready-scans proportional to live groups
        }
        self.count -= batch.len();
        batch
    }

    /// Distinct queued group keys, oldest head-of-line first (the
    /// continuous worker opens block-step batches in this order).
    pub fn keys_by_age(&self) -> Vec<GroupKey> {
        let mut ks: Vec<(&GroupKey, Instant)> = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(k, q)| (k, q[0].enqueued))
            .collect();
        ks.sort_by_key(|&(_, t)| t);
        ks.into_iter().map(|(k, _)| k.clone()).collect()
    }

    /// Earliest deadline across queues (for the worker's sleep).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.first())
            .map(|p| p.enqueued + self.max_wait)
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn key(m: Method) -> GroupKey {
        GroupKey::new("dream", m)
    }

    fn pend(m: Method, v: u32, t: Instant) -> Pending<u32> {
        Pending { key: key(m), payload: v, enqueued: t }
    }

    fn payloads(batch: Vec<Pending<u32>>) -> Vec<u32> {
        batch.into_iter().map(|p| p.payload).collect()
    }

    #[test]
    fn flushes_on_full_bucket() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        assert!(b.pop_ready(t).is_none(), "not full, not timed out");
        b.push(pend(Method::Cdlm, 2, t));
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.method, Method::Cdlm);
        assert_eq!(payloads(batch), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = DynamicBatcher::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        b.push(pend(Method::Ar, 7, t0));
        assert!(b.pop_ready(t0).is_none());
        let later = t0 + Duration::from_millis(6);
        let (_, batch) = b.pop_ready(later).unwrap();
        assert_eq!(payloads(batch), vec![7]);
    }

    #[test]
    fn groups_do_not_mix() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        assert!(b.pop_ready(t).is_none(), "neither group full");
        b.push(pend(Method::Cdlm, 3, t));
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.method, Method::Cdlm);
        assert_eq!(payloads(batch), vec![1, 3]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn tau_overrides_never_share_a_group() {
        // satellite regression: the closed-batch path folds tau into the
        // key, so a 0.5-tau request can never decode with a 0.9-tau
        // group (it used to inherit whichever override came first)
        let mut b = DynamicBatcher::new(2, Duration::from_secs(10));
        let t = Instant::now();
        let k_hi = key(Method::Cdlm).with_tau(Some(0.9));
        let k_lo = key(Method::Cdlm).with_tau(Some(0.5));
        assert_ne!(k_hi, k_lo);
        b.push(Pending { key: k_hi.clone(), payload: 1u32, enqueued: t });
        b.push(Pending { key: k_lo.clone(), payload: 2u32, enqueued: t });
        assert!(b.pop_ready(t).is_none(), "different taus, neither full");
        b.push(Pending { key: k_hi.clone(), payload: 3u32, enqueued: t });
        let (k, batch) = b.pop_ready(t).unwrap();
        assert_eq!(k.tau(), Some(0.9));
        assert_eq!(payloads(batch), vec![1, 3]);
    }

    #[test]
    fn batch_respects_max_size() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(0));
        let t = Instant::now();
        for i in 0..5 {
            b.push(pend(Method::Cdlm, i, t));
        }
        let (_, batch) = b.pop_ready(t).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.len(), 3);
    }

    #[test]
    fn pop_any_drains_everything() {
        let mut b = DynamicBatcher::new(4, Duration::from_secs(100));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        assert!(b.pop_any().is_some());
        assert!(b.pop_any().is_some());
        assert!(b.pop_any().is_none());
    }

    #[test]
    fn take_for_drains_only_matching_key_ignoring_readiness() {
        let mut b = DynamicBatcher::new(8, Duration::from_secs(100));
        let t = Instant::now();
        b.push(pend(Method::Cdlm, 1, t));
        b.push(pend(Method::Ar, 2, t));
        b.push(pend(Method::Cdlm, 3, t));
        // nothing is "ready" (bucket not full, window not expired) but
        // admission takes matching requests immediately
        assert!(b.pop_ready(t).is_none());
        let got = payloads(b.take_for(&key(Method::Cdlm), 1));
        assert_eq!(got, vec![1], "oldest matching request first");
        let got = payloads(b.take_for(&key(Method::Cdlm), 4));
        assert_eq!(got, vec![3]);
        assert!(b.take_for(&key(Method::Cdlm), 4).is_empty());
        assert_eq!(b.len(), 1, "other keys untouched");
        assert!(b.take_for(&key(Method::Ar), 0).is_empty());
        assert_eq!(payloads(b.take_for(&key(Method::Ar), 1)), vec![2]);
        assert!(b.is_empty());
    }

    #[test]
    fn running_count_tracks_push_and_pop() {
        let mut b = DynamicBatcher::new(2, Duration::from_secs(0));
        let t = Instant::now();
        assert_eq!(b.len(), 0);
        for i in 0..5 {
            b.push(pend(Method::Cdlm, i, t));
        }
        b.push(pend(Method::Ar, 9, t));
        assert_eq!(b.len(), 6);
        let (_, batch) = b.pop_ready(t).unwrap();
        assert_eq!(b.len(), 6 - batch.len());
        while b.pop_any().is_some() {}
        assert_eq!(b.len(), 0);
        assert!(b.is_empty());
        assert_eq!(b.total_batches, 4, "5 cdlm in batches of 2 + 1 ar");
    }

    #[test]
    fn property_no_request_lost_or_duplicated() {
        check("batcher-conservation", 50, |r| {
            let mut b =
                DynamicBatcher::new(1 + r.index(4), Duration::from_secs(100));
            let t = Instant::now();
            let n = 1 + r.index(30);
            let methods = [Method::Cdlm, Method::Ar, Method::Vanilla];
            for i in 0..n {
                b.push(pend(methods[r.index(3)], i as u32, t));
            }
            let mut seen = Vec::new();
            // interleave admission drains with batch pops
            loop {
                if r.below(2) == 0 {
                    let k = key(methods[r.index(3)]);
                    seen.extend(payloads(b.take_for(&k, 1 + r.index(3))));
                } else if let Some((_, batch)) = b.pop_any() {
                    seen.extend(payloads(batch));
                } else if b.is_empty() {
                    break;
                }
            }
            seen.sort_unstable();
            seen == (0..n as u32).collect::<Vec<_>>()
        });
    }
}
