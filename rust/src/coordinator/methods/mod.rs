//! Decode engines: one per method row of paper Tables 1 & 2.
//!
//! | engine          | paper row             | cache           | step policy |
//! |-----------------|-----------------------|-----------------|-------------|
//! | `vanilla`       | Dream/LLaDA-Instruct  | none            | top-1/step  |
//! | `dllm_cache`    | dLLM-Cache            | approx, refresh | top-1/step  |
//! | `fast_dllm_par` | Fast-dLLM (Par.)      | none            | threshold   |
//! | `fast_dllm_dc`  | Fast-dLLM (Par.+D.C.) | approx dual     | threshold   |
//! | `cdlm`          | CDLM (ours)           | exact block     | threshold + early stop |
//! | `ar`            | AR baselines (Fig. 3) | exact token     | greedy      |
//!
//! Engines decode a fixed-size batch in lockstep with dead-lane masking:
//! per-sample step counts only advance while a lane still has masked
//! positions, and per-sample latency stops at lane completion (§A.3).
//!
//! Each engine exists in two forms that share the same per-step code
//! and accounting: the closed-batch run-to-completion `decode` function
//! (dispatched by [`decode_batch`], the trace-pinned reference path)
//! and `machine_prefill`/`machine_step`/`machine_commit` policy
//! functions driven by the resumable [`machine::BatchState`], which
//! adds lane retirement and mid-flight admission at block boundaries
//! for continuous serving.

pub mod ar;
pub mod bidirectional;
pub mod cached_teacher;
pub mod cdlm;
pub mod machine;
pub mod spec_decode;

use std::time::Duration;

use anyhow::Result;

use super::kv_cache::KvPool;
use crate::runtime::{Geometry, Programs, StepArena};

/// Per-machine decode scratch: the [`StepArena`] holding every program
/// output and padded program input. One instance lives in each
/// [`machine::BatchState`]; closed-batch engines build a local one per
/// decode call. Bucket padding of KV lanes happens inside
/// `KvPool::view_padded` (padded rows borrow the last real lane's
/// segment run), so no slot-padding buffer exists anymore. After the
/// first step of a batch shape, every buffer is warm and steady-state
/// decode steps allocate nothing — the property
/// `cdlm bench --scenario hotpath` gates.
#[derive(Default)]
pub struct StepScratch {
    pub arena: StepArena,
}

impl StepScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Decode-time knobs (paper defaults: tau=0.9, B=32 scaled to 8 here).
#[derive(Debug, Clone)]
pub struct DecodeOpts {
    pub tau_conf: f32,
    /// Inference block size (Fig. 8 sweeps this; must divide gen_len and
    /// have an exported program variant).
    pub block_size: usize,
    /// Vanilla-teacher step budget per block (Table 4 naive truncation:
    /// fewer steps => top-m finalization with m = ceil(B / spb)).
    pub steps_per_block: Option<usize>,
    /// Approximate-cache refresh period in steps (dLLM-Cache).
    pub refresh_every: usize,
}

impl DecodeOpts {
    pub fn defaults(geom: &Geometry) -> Self {
        Self {
            tau_conf: 0.9,
            block_size: geom.block_size,
            steps_per_block: None,
            refresh_every: 4,
        }
    }
}

/// Result of decoding one request.
#[derive(Debug, Clone)]
pub struct DecodeOutcome {
    pub gen: Vec<i32>,
    pub steps: u64,
    pub model_calls: u64,
    pub latency: Duration,
    /// Decode-side time to first revealed token (§A.3 latency starts at
    /// decode start; the serving layer adds queueing delay for TTFT).
    pub ttft: Duration,
    pub gen_len: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Vanilla,
    DllmCache,
    FastDllmPar,
    FastDllmDc,
    Cdlm,
    Ar,
}

pub const ALL_METHODS: [Method; 6] = [
    Method::Vanilla,
    Method::DllmCache,
    Method::FastDllmPar,
    Method::FastDllmDc,
    Method::Cdlm,
    Method::Ar,
];

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Vanilla => "vanilla",
            Method::DllmCache => "dllm-cache",
            Method::FastDllmPar => "fast-dllm-par",
            Method::FastDllmDc => "fast-dllm-dc",
            Method::Cdlm => "cdlm",
            Method::Ar => "ar",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        ALL_METHODS.iter().copied().find(|m| m.name() == s)
    }

    /// Paper-table label.
    pub fn paper_label(&self, backbone: &str) -> String {
        match self {
            Method::Vanilla => format!("{backbone}-Instruct (naive)"),
            Method::DllmCache => "dLLM-Cache".to_string(),
            Method::FastDllmPar => "Fast-dLLM (Par.)".to_string(),
            Method::FastDllmDc => "Fast-dLLM (Par.+D.C.)".to_string(),
            Method::Cdlm => format!("CDLM-{backbone} (ours)"),
            Method::Ar => "AR baseline".to_string(),
        }
    }

    /// Whether the method's finalization reads `tau_conf` at all.
    /// Top-m and greedy methods ignore it, so batching layers must not
    /// split their groups over tau overrides.
    pub fn uses_tau_conf(&self) -> bool {
        matches!(
            self,
            Method::FastDllmPar | Method::FastDllmDc | Method::Cdlm
        )
    }

    /// Whether the method allocates KV slots at decode time. The
    /// cache-less bidirectional baselines recompute the full sequence
    /// every step, so their lanes hold no slots and must not count
    /// against KV budgets.
    pub fn uses_kv_cache(&self) -> bool {
        !matches!(self, Method::Vanilla | Method::FastDllmPar)
    }

    /// Which weight set this method decodes with.
    pub fn weights_for(&self, backbone: &str) -> String {
        match self {
            Method::Cdlm => format!("cdlm_{backbone}"),
            Method::Ar => format!("ar_{backbone}"),
            _ => format!("teacher_{backbone}"),
        }
    }
}

/// Dispatch a batch decode. `prompts` length must equal the program
/// bucket `bs`; the scheduler handles padding (lanes are borrowed, so
/// padded lanes can alias a live prompt without copying it).
pub fn decode_batch(
    progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    method: Method,
    prompts: &[&[i32]],
    pool: &mut KvPool,
) -> Result<Vec<DecodeOutcome>> {
    match method {
        Method::Vanilla => bidirectional::decode(
            progs,
            geom,
            opts,
            prompts,
            bidirectional::Policy::TopM,
        ),
        Method::FastDllmPar => bidirectional::decode(
            progs,
            geom,
            opts,
            prompts,
            bidirectional::Policy::Threshold,
        ),
        Method::DllmCache => cached_teacher::decode(
            progs,
            geom,
            opts,
            prompts,
            pool,
            cached_teacher::Variant::DllmCache,
        ),
        Method::FastDllmDc => cached_teacher::decode(
            progs,
            geom,
            opts,
            prompts,
            pool,
            cached_teacher::Variant::DualCache,
        ),
        Method::Cdlm => cdlm::decode(progs, geom, opts, prompts, pool),
        Method::Ar => ar::decode(progs, geom, prompts, pool),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_roundtrip() {
        for m in ALL_METHODS {
            assert_eq!(Method::from_name(m.name()), Some(m));
        }
        assert_eq!(Method::from_name("bogus"), None);
    }

    #[test]
    fn tau_sensitivity_matches_finalization_policy() {
        // threshold finalizers read tau; top-m/greedy never do
        assert!(Method::Cdlm.uses_tau_conf());
        assert!(Method::FastDllmPar.uses_tau_conf());
        assert!(Method::FastDllmDc.uses_tau_conf());
        assert!(!Method::Vanilla.uses_tau_conf());
        assert!(!Method::DllmCache.uses_tau_conf());
        assert!(!Method::Ar.uses_tau_conf());
    }

    #[test]
    fn kv_usage_matches_cache_column() {
        // cache-less bidirectional baselines hold no slots; everything
        // else allocates per-lane KV
        assert!(!Method::Vanilla.uses_kv_cache());
        assert!(!Method::FastDllmPar.uses_kv_cache());
        assert!(Method::DllmCache.uses_kv_cache());
        assert!(Method::FastDllmDc.uses_kv_cache());
        assert!(Method::Cdlm.uses_kv_cache());
        assert!(Method::Ar.uses_kv_cache());
    }

    #[test]
    fn weight_selection() {
        assert_eq!(Method::Cdlm.weights_for("dream"), "cdlm_dream");
        assert_eq!(Method::Vanilla.weights_for("llada"), "teacher_llada");
        assert_eq!(Method::FastDllmDc.weights_for("dream"), "teacher_dream");
        assert_eq!(Method::Ar.weights_for("llada"), "ar_llada");
    }
}
