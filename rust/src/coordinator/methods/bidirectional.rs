//! Cache-less bidirectional decoding: the vanilla-DLM baseline (top-1
//! per step, N = Lg steps) and Fast-dLLM (Parallel) (confidence
//! threshold, no KV reuse). Every step recomputes the full padded
//! sequence with the `teacher_denoise` program — exactly the cost
//! profile §5.4 calls compute-bound.

use std::time::Instant;

use anyhow::Result;

use super::{DecodeOpts, DecodeOutcome, StepScratch};
use crate::coordinator::sequence::SequenceState;
use crate::runtime::{Geometry, Programs, TensorI32};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Vanilla: finalize the top-m most confident masked positions per
    /// step (m = 1 at the teacher's most performant point; m > 1 under
    /// Table 4's naive step truncation).
    TopM,
    /// Fast-dLLM (Par.): finalize everything above tau (>=1 guaranteed).
    Threshold,
}

pub fn decode(
    progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    prompts: &[&[i32]],
    policy: Policy,
) -> Result<Vec<DecodeOutcome>> {
    let bs = prompts.len();
    let (p_len, g_len, s_len) =
        (geom.prompt_len, geom.gen_len, geom.seq_len);
    let blk = opts.block_size;
    let num_blocks = g_len / blk;
    let m_per_step = opts
        .steps_per_block
        .map(|spb| blk.div_ceil(spb))
        .unwrap_or(1);

    let mut seqs: Vec<SequenceState> = prompts
        .iter()
        .map(|p| SequenceState::new(geom, p))
        .collect();
    let valid_from =
        TensorI32::from_vec(&[bs], seqs.iter().map(|s| s.valid_from).collect());

    // sized once, reused every step: ids buffer + denoise output
    let mut scratch = StepScratch::new();
    scratch.arena.ids.reuse(&[bs, s_len]);
    for b in 0..num_blocks {
        let lo = b * blk;
        loop {
            // lockstep: run while any lane still has masked positions in
            // the block; every lane ticks (python-reference accounting)
            let any = (0..bs).any(|r| !seqs[r].block_fully_finalized(lo, blk));
            if !any {
                break;
            }
            for (r, s) in seqs.iter().enumerate() {
                s.copy_full_ids_into(
                    &mut scratch.arena.ids.data[r * s_len..(r + 1) * s_len],
                );
            }
            progs.teacher_denoise(
                bs,
                &scratch.arena.ids,
                &valid_from,
                &mut scratch.arena.denoise,
            )?;
            let out = &scratch.arena.denoise;
            for r in 0..bs {
                let base = r * s_len + p_len + lo;
                let toks = &out.tok.data[base..base + blk];
                let confs = &out.conf.data[base..base + blk];
                if !seqs[r].block_fully_finalized(lo, blk) {
                    match policy {
                        Policy::TopM => {
                            seqs[r].finalize_top_m(lo, toks, confs, m_per_step)
                        }
                        Policy::Threshold => seqs[r].finalize_threshold(
                            lo,
                            toks,
                            confs,
                            opts.tau_conf,
                        ),
                    };
                }
                seqs[r].steps += 1;
                seqs[r].model_calls += 1;
            }
        }
        // bidirectional baselines decode every block (no early stop);
        // generation-length accounting truncates at <eos> afterwards.
    }
    Ok(seqs.into_iter().map(SequenceState::into_outcome).collect())
}

/// Block-step-machine policy: refine one cohort's current block to
/// completion. Mirrors the per-block loop of [`decode`] exactly — every
/// cohort lane ticks on every pass while any cohort lane still has
/// masked positions in the block (python-reference accounting) — so a
/// cohort holding the whole batch reproduces the closed-batch trace
/// byte-for-byte. Call rows beyond `seqs.len()` are padded by aliasing
/// the last live lane (the AOT bucket contract). All program inputs and
/// outputs live in the caller's [`StepScratch`]: once warm, a pass
/// allocates nothing.
#[allow(clippy::too_many_arguments)]
pub(crate) fn machine_step(
    progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    policy: Policy,
    seqs: &mut [&mut SequenceState],
    taus: &[f32],
    lo: usize,
    blk: usize,
    pad_to: usize,
    scratch: &mut StepScratch,
) -> Result<()> {
    let n = seqs.len();
    let (p_len, s_len) = (geom.prompt_len, geom.seq_len);
    let m_per_step = opts
        .steps_per_block
        .map(|spb| blk.div_ceil(spb))
        .unwrap_or(1);
    scratch.arena.valid_from.reuse(&[pad_to]);
    for r in 0..pad_to {
        scratch.arena.valid_from.data[r] = seqs[r.min(n - 1)].valid_from;
    }
    scratch.arena.ids.reuse(&[pad_to, s_len]);
    loop {
        let any = (0..n).any(|r| !seqs[r].block_fully_finalized(lo, blk));
        if !any {
            break;
        }
        for r in 0..pad_to {
            seqs[r.min(n - 1)].copy_full_ids_into(
                &mut scratch.arena.ids.data[r * s_len..(r + 1) * s_len],
            );
        }
        progs.teacher_denoise(
            pad_to,
            &scratch.arena.ids,
            &scratch.arena.valid_from,
            &mut scratch.arena.denoise,
        )?;
        let out = &scratch.arena.denoise;
        for r in 0..n {
            let base = r * s_len + p_len + lo;
            if !seqs[r].block_fully_finalized(lo, blk) {
                let toks = &out.tok.data[base..base + blk];
                let confs = &out.conf.data[base..base + blk];
                match policy {
                    Policy::TopM => {
                        seqs[r].finalize_top_m(lo, toks, confs, m_per_step)
                    }
                    Policy::Threshold => {
                        seqs[r].finalize_threshold(lo, toks, confs, taus[r])
                    }
                };
            }
            seqs[r].steps += 1;
            seqs[r].model_calls += 1;
        }
    }
    Ok(())
}

/// Convenience wrapper used by tests/benches for Table 4: vanilla with a
/// truncated step budget.
pub fn decode_truncated(
    progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    prompts: &[&[i32]],
    steps_per_block: usize,
) -> Result<Vec<DecodeOutcome>> {
    let mut o = opts.clone();
    o.steps_per_block = Some(steps_per_block);
    let t0 = Instant::now();
    let r = decode(progs, geom, &o, prompts, Policy::TopM);
    let _ = t0;
    r
}
