//! CDLM inference (paper §4.3) — the system under evaluation.
//!
//! Block-causal student + **exact** block KV caching:
//!   1. `student_prefill` writes the prompt KV once;
//!   2. within the active block, `student_block_step` attends to the
//!      cache + fresh block K/V; every masked position with confidence
//!      >= tau is finalized in parallel (>=1 per step guaranteed);
//!   3. when the block is complete, one commit call recomputes the
//!      block's K/V from its *final* tokens and appends it in place to
//!      the lane's pages (counted in `model_calls`, not `steps` — see
//!      rust/README.md);
//!   4. a finalized `<eos>` stops the request at the block boundary —
//!      no compute is spent on later blocks (early stopping).
//!
//! The cache never leaves the pool: every program call borrows a
//! zero-copy `KvView` over the paged slabs through the lanes'
//! [`KvLease`]s, and every program input/output lives in a reused
//! [`StepScratch`] arena — a steady-state refinement step touches no
//! allocator at all (the `hotpath` bench gates this).
//!
//! This mirrors `python/compile/decoding.py::student_cdlm_decode`
//! token-for-token; integration tests enforce parity via the
//! `decode_parity.json` golden (see rust/README.md §caches for the
//! step/model-call accounting).

use anyhow::Result;

use super::{machine, DecodeOpts, DecodeOutcome, StepScratch};
use crate::coordinator::kv_cache::{KvLease, KvPool};
use crate::coordinator::sequence::SequenceState;
use crate::runtime::{Geometry, Programs, TensorI32};

pub fn decode(
    progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    prompts: &[&[i32]],
    pool: &mut KvPool,
) -> Result<Vec<DecodeOutcome>> {
    let bs = prompts.len();
    let (p_len, g_len) = (geom.prompt_len, geom.gen_len);
    let blk = opts.block_size;
    anyhow::ensure!(g_len % blk == 0, "block {blk} must divide gen {g_len}");
    let num_blocks = g_len / blk;

    let mut seqs: Vec<SequenceState> = prompts
        .iter()
        .map(|p| SequenceState::new(geom, p))
        .collect();
    let valid_from =
        TensorI32::from_vec(&[bs], seqs.iter().map(|s| s.valid_from).collect());

    let mut scratch = StepScratch::new();

    // ---- prefill: exact prompt KV, once per request
    let mut prompt_ids = vec![0i32; bs * p_len];
    for (r, s) in seqs.iter().enumerate() {
        prompt_ids[r * p_len..(r + 1) * p_len].copy_from_slice(&s.prompt_ids);
    }
    progs.student_prefill(
        bs,
        &TensorI32::from_vec(&[bs, p_len], prompt_ids),
        &valid_from,
        &mut scratch.arena.prefill,
    )?;
    let leases: Vec<KvLease> =
        (0..bs).map(|_| pool.alloc()).collect::<Result<_>>()?;
    for (lane, lease) in leases.iter().enumerate() {
        pool.write_prefill(
            lease,
            lane,
            bs,
            &scratch.arena.prefill.k.data,
            &scratch.arena.prefill.v.data,
        )?;
    }
    for s in seqs.iter_mut() {
        s.model_calls += 1;
    }
    let lrefs: Vec<&KvLease> = leases.iter().collect();

    // reused every step and commit: one [bs, B] block-id buffer
    scratch.arena.blk.reuse(&[bs, blk]);
    for b in 0..num_blocks {
        let lo = b * blk;
        let any_active = seqs.iter().any(|s| !s.done);
        if !any_active {
            break;
        }
        // ---- refinement steps under the exact cache
        loop {
            // lockstep accounting (matches the python reference): every
            // not-done lane ticks while any lane still refines the block
            let any = (0..bs).any(|r| {
                !seqs[r].done && !seqs[r].block_fully_finalized(lo, blk)
            });
            if !any {
                break;
            }
            for (r, s) in seqs.iter().enumerate() {
                scratch.arena.blk.data[r * blk..(r + 1) * blk]
                    .copy_from_slice(&s.gen[lo..lo + blk]);
            }
            progs.student_block_step(
                bs,
                blk,
                &pool.view(&lrefs),
                &valid_from,
                &scratch.arena.blk,
                (p_len + lo) as i32,
                &mut scratch.arena.block,
            )?;
            let out = &scratch.arena.block;
            for r in 0..bs {
                if seqs[r].done {
                    continue;
                }
                if !seqs[r].block_fully_finalized(lo, blk) {
                    let base = r * blk;
                    seqs[r].finalize_threshold(
                        lo,
                        &out.tok.data[base..base + blk],
                        &out.conf.data[base..base + blk],
                        opts.tau_conf,
                    );
                }
                seqs[r].steps += 1;
                seqs[r].model_calls += 1;
            }
        }
        // ---- early stop at the block boundary
        for s in seqs.iter_mut() {
            if !s.done && s.eos_in(lo, blk) {
                s.mark_done();
            }
        }
        let still_running = seqs.iter().any(|s| !s.done);
        if !still_running || b + 1 == num_blocks {
            break; // no one needs this block's KV committed
        }
        // ---- commit: recompute block KV from the *final* tokens so the
        // cache is exact (one extra model call, not a refinement step).
        // Every lane commits — done lanes too: the paged view requires
        // each lane's pages to cover the lockstep cache_len, and the
        // memcpy costs no model call (the accounting stays gated below).
        for (r, s) in seqs.iter().enumerate() {
            scratch.arena.blk.data[r * blk..(r + 1) * blk]
                .copy_from_slice(&s.gen[lo..lo + blk]);
        }
        progs.student_block_step(
            bs,
            blk,
            &pool.view(&lrefs),
            &valid_from,
            &scratch.arena.blk,
            (p_len + lo) as i32,
            &mut scratch.arena.block,
        )?;
        for (lane, lease) in lrefs.iter().enumerate() {
            pool.commit_block(
                lease,
                lane,
                bs,
                blk,
                &scratch.arena.block.k_blk.data,
                &scratch.arena.block.v_blk.data,
            )?;
            if !seqs[lane].done {
                seqs[lane].model_calls += 1;
            }
        }
    }
    drop(lrefs);
    for lease in leases {
        pool.release(lease);
    }
    Ok(seqs.into_iter().map(SequenceState::into_outcome).collect())
}

// ---------------------------------------------------------------------------
// Block-step-machine policy (resumable per-lane decode)
// ---------------------------------------------------------------------------

/// Admission prefill for one lane: lease a lane and install the exact
/// prompt KV, padded up to the smallest exported bucket (`pad_to`) by
/// aliasing the one real prompt row — the same AOT bucket contract
/// every cohort call honors (a manifest need not export bucket 1).
/// Per-lane outputs equal the batched prefill of [`decode`] (lanes are
/// independent), so admitting a whole group lane-by-lane reproduces the
/// closed-batch trace.
///
/// With `prefix_tag` set (the serving layer's shared-prefix cache), a
/// fully cached prompt pins its resident chain and **skips the prefill
/// call** — the decode that follows is byte-identical because the
/// pages hold exactly what prefill would have produced (the backend is
/// deterministic in the prompt tokens), and `model_calls` drops by
/// exactly the skipped prefill. A miss prefills as usual and
/// installs the chain (copy-on-write at the first divergent block) so
/// later admissions can share it; if the page budget is exhausted by
/// pinned chains the lane falls back to a private-page prefill —
/// identical trace, no sharing.
pub(crate) fn machine_prefill(
    progs: &Programs,
    pool: &mut KvPool,
    seq: &mut SequenceState,
    pad_to: usize,
    prefix_tag: Option<u64>,
    scratch: &mut StepScratch,
) -> Result<KvLease> {
    let lease = pool.alloc()?;
    if let Some(tag) = prefix_tag {
        if let Some(pin) =
            pool.prefix_acquire_full(tag, &seq.prompt_ids, false)
        {
            pool.attach_chain(&lease, pin);
            return Ok(lease);
        }
    }
    let (pid, vf) = machine::padded_prompt(seq, pad_to);
    if let Err(e) =
        progs.student_prefill(pad_to, &pid, &vf, &mut scratch.arena.prefill)
    {
        // hand the lane back: a failed admission must not leak it
        pool.release(lease);
        return Err(e);
    }
    let pre = &scratch.arena.prefill;
    seq.model_calls += 1;
    if let Some(tag) = prefix_tag {
        if let Ok(pin) = pool.prefix_install(
            tag,
            &seq.prompt_ids,
            0,
            pad_to,
            &pre.k.data,
            &pre.v.data,
            None,
        ) {
            pool.attach_chain(&lease, pin);
            return Ok(lease);
        }
    }
    if let Err(e) = pool.write_prefill(&lease, 0, pad_to, &pre.k.data, &pre.v.data)
    {
        pool.release(lease);
        return Err(e);
    }
    Ok(lease)
}

/// Refine one cohort's block to completion + early-stop marking at the
/// boundary. Mirrors the per-block refinement loop of [`decode`]: every
/// not-done cohort lane ticks while any cohort lane still has masked
/// positions in the block. Rows beyond `seqs.len()` alias the last live
/// lane and its pages (bucket padding inside `view_padded`; never
/// finalized or committed). This is the hot path the `hotpath` bench
/// drives: once the scratch arena is warm, a refinement pass performs
/// zero heap allocations.
#[allow(clippy::too_many_arguments)]
pub(crate) fn machine_step(
    progs: &Programs,
    geom: &Geometry,
    pool: &KvPool,
    seqs: &mut [&mut SequenceState],
    taus: &[f32],
    leases: &[&KvLease],
    lo: usize,
    blk: usize,
    pad_to: usize,
    scratch: &mut StepScratch,
) -> Result<()> {
    let n = seqs.len();
    debug_assert_eq!(n, leases.len(), "cohort seqs/leases out of sync");
    let p_len = geom.prompt_len;
    debug_assert_eq!(
        pool.cache_len_of(leases[0]),
        p_len + lo,
        "cohort cache out of lockstep with the block cursor"
    );
    scratch.arena.valid_from.reuse(&[pad_to]);
    for r in 0..pad_to {
        scratch.arena.valid_from.data[r] = seqs[r.min(n - 1)].valid_from;
    }
    scratch.arena.blk.reuse(&[pad_to, blk]);
    loop {
        let any = (0..n)
            .any(|r| !seqs[r].done && !seqs[r].block_fully_finalized(lo, blk));
        if !any {
            break;
        }
        for r in 0..pad_to {
            scratch.arena.blk.data[r * blk..(r + 1) * blk]
                .copy_from_slice(&seqs[r.min(n - 1)].gen[lo..lo + blk]);
        }
        progs.student_block_step(
            pad_to,
            blk,
            &pool.view_padded(leases, pad_to),
            &scratch.arena.valid_from,
            &scratch.arena.blk,
            (p_len + lo) as i32,
            &mut scratch.arena.block,
        )?;
        let out = &scratch.arena.block;
        for r in 0..n {
            if seqs[r].done {
                continue;
            }
            if !seqs[r].block_fully_finalized(lo, blk) {
                let base = r * blk;
                seqs[r].finalize_threshold(
                    lo,
                    &out.tok.data[base..base + blk],
                    &out.conf.data[base..base + blk],
                    taus[r],
                );
            }
            seqs[r].steps += 1;
            seqs[r].model_calls += 1;
        }
    }
    // early stop at the block boundary (paper §4.3)
    for s in seqs.iter_mut() {
        if !s.done && s.eos_in(lo, blk) {
            s.mark_done();
        }
    }
    Ok(())
}

/// Commit the block KV for the cohort lanes that continue past the
/// boundary (one extra model call each, not a refinement step — the
/// same §A.3 accounting as [`decode`]). `seqs`/`leases` hold only
/// continuing lanes, in lockstep; callers skip the call entirely when
/// none continue. Shares the caller's [`StepScratch`] with
/// [`machine_step`] — the buffers are reshaped (`reuse`) when the
/// continuing-lane pad differs from the step pad, which zero-fills in
/// place without allocating once warm.
#[allow(clippy::too_many_arguments)]
pub(crate) fn machine_commit(
    progs: &Programs,
    geom: &Geometry,
    pool: &mut KvPool,
    seqs: &mut [&mut SequenceState],
    leases: &[&KvLease],
    lo: usize,
    blk: usize,
    pad_to: usize,
    scratch: &mut StepScratch,
) -> Result<()> {
    let n = seqs.len();
    if n == 0 {
        return Ok(());
    }
    debug_assert_eq!(n, leases.len(), "commit seqs/leases out of sync");
    let p_len = geom.prompt_len;
    scratch.arena.valid_from.reuse(&[pad_to]);
    for r in 0..pad_to {
        scratch.arena.valid_from.data[r] = seqs[r.min(n - 1)].valid_from;
    }
    scratch.arena.blk.reuse(&[pad_to, blk]);
    for r in 0..pad_to {
        scratch.arena.blk.data[r * blk..(r + 1) * blk]
            .copy_from_slice(&seqs[r.min(n - 1)].gen[lo..lo + blk]);
    }
    progs.student_block_step(
        pad_to,
        blk,
        &pool.view_padded(leases, pad_to),
        &scratch.arena.valid_from,
        &scratch.arena.blk,
        (p_len + lo) as i32,
        &mut scratch.arena.block,
    )?;
    for (lane, lease) in leases.iter().enumerate() {
        pool.commit_block(
            lease,
            lane,
            pad_to,
            blk,
            &scratch.arena.block.k_blk.data,
            &scratch.arena.block.v_blk.data,
        )?;
        seqs[lane].model_calls += 1;
    }
    Ok(())
}
