//! Approximate-cache baselines on the bidirectional teacher:
//!
//! * `DllmCache` — dLLM-Cache (Liu et al. 2025): keep the N = Lg step
//!   budget and top-1 finalization, but recompute only the active block
//!   against a *stale* full-sequence KV cache, refreshing the full cache
//!   every `refresh_every` steps (adaptive feature caching).
//! * `DualCache` — Fast-dLLM (Par.+D.C.) (Wu et al. 2025): confidence-
//!   thresholded parallel finalization + dual cache (stale prefix and
//!   suffix KV), refreshed at every block boundary.
//!
//! Both run `teacher_full_cache` for refresh steps and
//! `teacher_block_approx` in between — the latter excludes the stale
//! copy of the active block in favour of freshly computed K/V (the
//! "dual" part of dual caching). Refreshes overwrite the lanes' pages
//! in place; approx steps borrow a zero-copy `KvView` spanning the
//! whole (stale) sequence — no batch-major staging buffer exists on
//! this path, and every program input/output lives in a reused
//! [`StepScratch`] arena. With refresh_every = 1 the approx path
//! degenerates to exact recomputation, which the integration tests use
//! as a correctness anchor.

use anyhow::Result;

use super::{DecodeOpts, DecodeOutcome, StepScratch};
use crate::coordinator::kv_cache::{KvLease, KvPool};
use crate::coordinator::sequence::SequenceState;
use crate::runtime::{Geometry, Programs, TensorI32};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Variant {
    DllmCache,
    DualCache,
}

pub fn decode(
    progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    prompts: &[&[i32]],
    pool: &mut KvPool,
    variant: Variant,
) -> Result<Vec<DecodeOutcome>> {
    let bs = prompts.len();
    let (p_len, g_len, s_len) = (geom.prompt_len, geom.gen_len, geom.seq_len);
    let blk = opts.block_size;
    let num_blocks = g_len / blk;

    let mut seqs: Vec<SequenceState> = prompts
        .iter()
        .map(|p| SequenceState::new(geom, p))
        .collect();
    let valid_from =
        TensorI32::from_vec(&[bs], seqs.iter().map(|s| s.valid_from).collect());

    let leases: Vec<KvLease> =
        (0..bs).map(|_| pool.alloc()).collect::<Result<_>>()?;
    let lrefs: Vec<&KvLease> = leases.iter().collect();

    // reused across steps: [bs, S] refresh ids and [bs, B] block ids
    let mut scratch = StepScratch::new();
    scratch.arena.ids.reuse(&[bs, s_len]);
    scratch.arena.blk.reuse(&[bs, blk]);
    let mut steps_since_refresh = usize::MAX; // force refresh first

    for b in 0..num_blocks {
        let lo = b * blk;
        if variant == Variant::DualCache {
            steps_since_refresh = usize::MAX; // refresh at block boundary
        }
        loop {
            let any =
                (0..bs).any(|r| !seqs[r].block_fully_finalized(lo, blk));
            if !any {
                break;
            }
            let refresh = steps_since_refresh >= opts.refresh_every;
            if refresh {
                // full bidirectional pass: fresh logits + fresh KV stacks
                for (r, s) in seqs.iter().enumerate() {
                    s.copy_full_ids_into(
                        &mut scratch.arena.ids.data[r * s_len..(r + 1) * s_len],
                    );
                }
                progs.teacher_full_cache(
                    bs,
                    &scratch.arena.ids,
                    &valid_from,
                    &mut scratch.arena.full_cache,
                )?;
                for (lane, lease) in lrefs.iter().enumerate() {
                    pool.write_full(
                        lease,
                        lane,
                        bs,
                        &scratch.arena.full_cache.k.data,
                        &scratch.arena.full_cache.v.data,
                    )?;
                }
                let out = &scratch.arena.full_cache;
                for r in 0..bs {
                    if seqs[r].block_fully_finalized(lo, blk) {
                        continue;
                    }
                    let base = r * s_len + p_len + lo;
                    finalize(
                        &mut seqs[r],
                        lo,
                        &out.tok.data[base..base + blk],
                        &out.conf.data[base..base + blk],
                        opts.tau_conf,
                        variant,
                    );
                    seqs[r].steps += 1;
                    seqs[r].model_calls += 1;
                }
                steps_since_refresh = 1;
            } else {
                // approximate step: recompute the active block only,
                // reading the stale full-sequence cache through a view
                for (r, s) in seqs.iter().enumerate() {
                    scratch.arena.blk.data[r * blk..(r + 1) * blk]
                        .copy_from_slice(&s.gen[lo..lo + blk]);
                }
                progs.teacher_block_approx(
                    bs,
                    blk,
                    &pool.view(&lrefs),
                    &valid_from,
                    &scratch.arena.blk,
                    (p_len + lo) as i32,
                    &mut scratch.arena.block,
                )?;
                let out = &scratch.arena.block;
                for r in 0..bs {
                    if seqs[r].block_fully_finalized(lo, blk) {
                        continue;
                    }
                    let base = r * blk;
                    finalize(
                        &mut seqs[r],
                        lo,
                        &out.tok.data[base..base + blk],
                        &out.conf.data[base..base + blk],
                        opts.tau_conf,
                        variant,
                    );
                    seqs[r].steps += 1;
                    seqs[r].model_calls += 1;
                }
                steps_since_refresh += 1;
            }
        }
    }
    drop(lrefs);
    for lease in leases {
        pool.release(lease);
    }
    Ok(seqs.into_iter().map(SequenceState::into_outcome).collect())
}

fn finalize(
    seq: &mut SequenceState,
    lo: usize,
    toks: &[i32],
    confs: &[f32],
    tau: f32,
    variant: Variant,
) {
    match variant {
        // dLLM-Cache keeps the vanilla one-token-per-step schedule
        Variant::DllmCache => seq.finalize_top_m(lo, toks, confs, 1),
        // Fast-dLLM D.C. adds thresholded parallel finalization
        Variant::DualCache => seq.finalize_threshold(lo, toks, confs, tau),
    };
}

/// Block-step-machine policy: refine one cohort's block to completion
/// against the approximate cache, mirroring the per-block loop of
/// [`decode`]. The refresh counter is cohort-lockstep state in the
/// closed-batch engine; the machine carries it per lane (uniform within
/// a cohort that was admitted together), takes the cohort max on entry
/// — a refresh as soon as any lane needs one, exactly the legacy
/// behavior when counters agree — and returns the counter for write-
/// back. `DualCache` refreshes at every block boundary regardless.
/// Refreshes rewrite only the real lanes' pages; padded call rows alias
/// the last live lane and are never written back. Once the caller's
/// [`StepScratch`] is warm, a pass performs zero heap allocations.
#[allow(clippy::too_many_arguments)]
pub(crate) fn machine_step(
    progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    variant: Variant,
    pool: &mut KvPool,
    seqs: &mut [&mut SequenceState],
    taus: &[f32],
    leases: &[&KvLease],
    ssr_in: usize,
    lo: usize,
    blk: usize,
    pad_to: usize,
    scratch: &mut StepScratch,
) -> Result<usize> {
    let n = seqs.len();
    debug_assert_eq!(n, leases.len(), "cohort seqs/leases out of sync");
    let (p_len, s_len) = (geom.prompt_len, geom.seq_len);
    let mut ssr = if variant == Variant::DualCache {
        usize::MAX // refresh at the block boundary
    } else {
        ssr_in
    };
    scratch.arena.valid_from.reuse(&[pad_to]);
    for r in 0..pad_to {
        scratch.arena.valid_from.data[r] = seqs[r.min(n - 1)].valid_from;
    }
    scratch.arena.ids.reuse(&[pad_to, s_len]);
    scratch.arena.blk.reuse(&[pad_to, blk]);
    loop {
        let any = (0..n).any(|r| !seqs[r].block_fully_finalized(lo, blk));
        if !any {
            break;
        }
        if ssr >= opts.refresh_every {
            // full bidirectional pass: fresh logits + fresh KV stacks
            for r in 0..pad_to {
                seqs[r.min(n - 1)].copy_full_ids_into(
                    &mut scratch.arena.ids.data[r * s_len..(r + 1) * s_len],
                );
            }
            progs.teacher_full_cache(
                pad_to,
                &scratch.arena.ids,
                &scratch.arena.valid_from,
                &mut scratch.arena.full_cache,
            )?;
            for (lane, lease) in leases.iter().enumerate() {
                pool.write_full(
                    lease,
                    lane,
                    pad_to,
                    &scratch.arena.full_cache.k.data,
                    &scratch.arena.full_cache.v.data,
                )?;
            }
            let out = &scratch.arena.full_cache;
            for r in 0..n {
                if seqs[r].block_fully_finalized(lo, blk) {
                    continue;
                }
                let base = r * s_len + p_len + lo;
                finalize(
                    &mut *seqs[r],
                    lo,
                    &out.tok.data[base..base + blk],
                    &out.conf.data[base..base + blk],
                    taus[r],
                    variant,
                );
                seqs[r].steps += 1;
                seqs[r].model_calls += 1;
            }
            ssr = 1;
        } else {
            // approximate step: active block only, stale full-seq cache
            for r in 0..pad_to {
                scratch.arena.blk.data[r * blk..(r + 1) * blk]
                    .copy_from_slice(&seqs[r.min(n - 1)].gen[lo..lo + blk]);
            }
            progs.teacher_block_approx(
                pad_to,
                blk,
                &pool.view_padded(leases, pad_to),
                &scratch.arena.valid_from,
                &scratch.arena.blk,
                (p_len + lo) as i32,
                &mut scratch.arena.block,
            )?;
            let out = &scratch.arena.block;
            for r in 0..n {
                if seqs[r].block_fully_finalized(lo, blk) {
                    continue;
                }
                let base = r * blk;
                finalize(
                    &mut *seqs[r],
                    lo,
                    &out.tok.data[base..base + blk],
                    &out.conf.data[base..base + blk],
                    taus[r],
                    variant,
                );
                seqs[r].steps += 1;
                seqs[r].model_calls += 1;
            }
            ssr += 1;
        }
    }
    Ok(ssr)
}
