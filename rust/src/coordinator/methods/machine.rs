//! Resumable block-step decode machine — the engine layer behind
//! continuous batching.
//!
//! The closed-batch engines (`bidirectional::decode`, `cdlm::decode`,
//! …) are run-to-completion functions: a batch enters, nothing leaves
//! until the slowest lane finishes, and nothing joins. CDLM's
//! block-wise causal attention makes the KV cache exact and append-only
//! at block granularity (paper §4.3), which is precisely the property
//! that lets sequences enter and leave a running batch at block
//! boundaries. [`BatchState`] exploits it:
//!
//! * every request owns a **lane**: a [`SequenceState`], an optional KV
//!   lease, a per-lane tau, and a block cursor;
//! * [`BatchState::admit`] fills a free lane at any block boundary with
//!   a bucket-1 prefill (per-lane program outputs are independent of
//!   batch composition, so a lane admitted alone decodes exactly as it
//!   would inside a group — `tests/parallel_decode.rs` pins this);
//! * [`BatchState::step_cycle`] advances every live lane by one block:
//!   lanes are grouped into **cohorts** sharing a block cursor, each
//!   cohort runs the method's refinement loop to block completion in
//!   lockstep (one program call per pass, padded up to an exported
//!   bucket by aliasing the last live lane), then commits its block KV
//!   and applies the method's early-stop policy;
//! * [`BatchState::take_finished`] retires finished lanes immediately —
//!   the outcome is produced and the KV lease released mid-batch,
//!   instead of the lane dragging along dead until the group drains;
//! * [`BatchState::suspend_lane`] / [`BatchState::resume_lane`] park a
//!   live lane at a block boundary: its KV pages spill to a host-side
//!   cold tier ([`SuspendedKv`]) and the lane slot frees for another
//!   request; resuming restores the bytes exactly, so the continued
//!   decode is byte-identical to an uninterrupted run
//!   (`tests/preemption.rs` pins this for all six methods).
//!
//! The per-method step behavior (cache variant, finalization policy,
//! §A.3 step/model-call accounting) lives next to each closed-batch
//! engine as `machine_prefill` / `machine_step` / `machine_commit`
//! policy functions; this file only owns lane lifecycle and cohort
//! scheduling. With no mid-flight admission, the machine reproduces the
//! closed-batch decode traces (gen ids, steps, model calls)
//! byte-for-byte for all six methods — `tests/continuous_batching.rs`
//! pins this property against [`Engine::decode_serial`].
//!
//! [`Engine::decode_serial`]: crate::coordinator::scheduler::Engine::decode_serial

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use super::{ar, bidirectional, cached_teacher, cdlm};
use super::{DecodeOpts, DecodeOutcome, Method, StepScratch};
use crate::coordinator::kv_cache::{KvLease, KvPool, SuspendedKv};
use crate::coordinator::sequence::SequenceState;
use crate::runtime::{
    Geometry, ModelWeights, Programs, Runtime, TensorI32,
};

/// One lane's newly committed token run, reported by
/// [`BatchState::step_cycle`]: the generation span the cycle finalized
/// for that lane (one full block for the DLM methods, up to one block
/// of greedy tokens for AR). Runs arrive in generation order per lane,
/// so concatenating a lane's runs reproduces its final `gen` buffer up
/// to the last committed position — the streaming serving layer turns
/// each run into an incrementally detokenized delta
/// (`tests/streaming.rs` pins the concatenation byte-identical to the
/// one-shot decode). Tokens are copied verbatim from the lane's gen
/// buffer: positions past a lane's `<eos>` may be `[MASK]` (AR) or
/// refined-but-dead tokens (teacher baselines); the stream decoder
/// drops both.
#[derive(Debug, Clone)]
pub struct CommitRun {
    pub lane: usize,
    /// Gen-span offset where the run starts.
    pub start: usize,
    pub tokens: Vec<i32>,
}

/// One request's resumable decode state.
struct Lane {
    seq: SequenceState,
    /// Per-lane confidence threshold: a request's tau override never
    /// leaks onto its batch mates.
    tau: f32,
    /// Block cursor (DLM methods): blocks `< block` are fully decoded
    /// and, where the method caches, committed.
    block: usize,
    /// Steps since the last approximate-cache refresh (cached-teacher
    /// variants; `usize::MAX` forces a refresh first).
    ssr: usize,
    /// AR: pending next-token proposal entering the current position.
    cur_tok: i32,
    /// AR: next generation index to write.
    ar_pos: usize,
    lease: Option<KvLease>,
    /// Set at the block boundary where the lane completed; the lane
    /// stops stepping and waits for [`BatchState::take_finished`].
    finished: bool,
}

/// A lane parked off the machine by [`BatchState::suspend_lane`]: the
/// full decode state plus the lane's spilled KV pages (host-side cold
/// tier). Holds no pool resources except the shared-prefix chain pin
/// (kept so the cached prompt pages cannot be evicted out from under a
/// parked request); [`BatchState::resume_lane`] puts it back on a free
/// lane with byte-identical continuation, and
/// [`BatchState::discard_suspended`] drops it (unpinning the chain) if
/// the request is cancelled while parked.
pub struct SuspendedLane {
    seq: SequenceState,
    tau: f32,
    block: usize,
    ssr: usize,
    cur_tok: i32,
    ar_pos: usize,
    kv: Option<SuspendedKv>,
}

impl SuspendedLane {
    /// Bytes held in the cold tier for this lane (0 for cache-less
    /// methods, whose lanes have no KV to spill).
    pub fn spilled_bytes(&self) -> usize {
        self.kv.as_ref().map_or(0, SuspendedKv::spilled_bytes)
    }
}

/// A resumable lockstep batch: fixed lane capacity, per-lane state, an
/// owned KV pool whose paged lanes recycle as requests retire and
/// admissions take their place.
pub struct BatchState {
    rt: Arc<Runtime>,
    weights: Arc<ModelWeights>,
    pub method: Method,
    pub opts: DecodeOpts,
    geom: Geometry,
    /// Exported batch buckets, ascending; cohort calls pad up to the
    /// smallest bucket that fits.
    buckets: Vec<usize>,
    pool: KvPool,
    lanes: Vec<Option<Lane>>,
    /// Step arena + padded-call buffers, sized on first use and reused
    /// by every admission and `step_cycle` — the machine's steady-state
    /// decode steps allocate nothing (the `hotpath` bench gate).
    scratch: StepScratch,
    stepped: bool,
    /// Cross-request prompt-prefix reuse at admission (off by default:
    /// the closed-batch trace pins assume every admit prefills; the
    /// serving layer turns it on per `RouterConfig::prefix_cache`).
    prefix_cache: bool,
    pub total_admissions: u64,
    pub mid_flight_admissions: u64,
}

impl BatchState {
    /// A machine with `capacity` lanes (clamped to the largest exported
    /// bucket — a cohort must fit one program call).
    pub fn new(
        rt: Arc<Runtime>,
        weights: Arc<ModelWeights>,
        method: Method,
        opts: DecodeOpts,
        capacity: usize,
    ) -> Result<BatchState> {
        let geom = rt.manifest.geometry.clone();
        anyhow::ensure!(
            opts.block_size > 0 && geom.gen_len % opts.block_size == 0,
            "block {} must divide gen {}",
            opts.block_size,
            geom.gen_len
        );
        let mut buckets = rt.manifest.buckets.clone();
        buckets.sort_unstable();
        let max_bucket = buckets.last().copied().unwrap_or(1);
        let cap = capacity.clamp(1, max_bucket);
        // cache-less methods never lease a lane; skip their slabs.
        // Prefix pages are NOT budgeted here: the machine starts with
        // the prefix cache off, and `set_prefix_cache(true)` swaps in
        // the paged pool — a machine that never shares never pays for
        // page slabs.
        let pool_cap = if method.uses_kv_cache() { cap } else { 0 };
        let pool = KvPool::new(&geom, pool_cap);
        Ok(BatchState {
            rt,
            weights,
            method,
            opts,
            geom,
            buckets,
            pool,
            lanes: (0..cap).map(|_| None).collect(),
            scratch: StepScratch::new(),
            stepped: false,
            prefix_cache: false,
            total_admissions: 0,
            mid_flight_admissions: 0,
        })
    }

    /// A machine whose pool **under-provisions** its page budgets: the
    /// pressure cooker behind `cdlm bench --scenario preempt` and
    /// `tests/preemption.rs`. `prompt_budget` / `tail_budget` pages are
    /// shared by all lanes; when the tail free list cannot cover the
    /// next block wave the caller suspends lanes
    /// ([`BatchState::suspend_lane`]) to spill pages and make progress.
    /// One-owner full-slot provisioning of the same slab would cap live
    /// lanes at `tail_budget / tail_pages_full` — paged on-demand
    /// allocation sustains more, which is the whole point.
    pub fn with_kv_budgets(
        rt: Arc<Runtime>,
        weights: Arc<ModelWeights>,
        method: Method,
        opts: DecodeOpts,
        capacity: usize,
        prompt_budget: usize,
        tail_budget: usize,
    ) -> Result<BatchState> {
        let mut st = Self::new(rt, weights, method, opts, capacity)?;
        let pool_cap = if method.uses_kv_cache() { st.capacity() } else { 0 };
        st.pool = KvPool::with_page_budgets(
            &st.geom,
            pool_cap,
            prompt_budget,
            tail_budget,
            0,
        );
        Ok(st)
    }

    /// Enable (or disable) shared-prefix KV reuse for admissions. Warm
    /// full-prompt hits then skip the admission prefill: decode traces
    /// stay byte-identical (the chain pages hold exactly the prefill
    /// output for those tokens) with `model_calls` lower by exactly the
    /// skipped call — `tests/prefix_cache.rs` pins this per method.
    ///
    /// Enabling on a fresh machine (the serving layer does it right
    /// after construction) swaps in a pool with the default prefix-page
    /// budget. Enabling later — once lanes or counters exist — keeps
    /// the pageless pool: admissions then fall back to private-page
    /// prefills, which is always correct, just unshared.
    pub fn set_prefix_cache(&mut self, on: bool) {
        if on
            && self.pool.prefix_page_capacity() == 0
            && self.is_empty()
            && self.pool.total_allocs == 0
        {
            let cap = self.pool.capacity();
            self.pool = KvPool::with_prefix_pages(
                &self.geom,
                cap,
                KvPool::default_page_budget(&self.geom, cap),
            );
        }
        self.prefix_cache = on;
    }

    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_cache
    }

    pub fn capacity(&self) -> usize {
        self.lanes.len()
    }

    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    pub fn free_lanes(&self) -> usize {
        self.capacity() - self.live_lanes()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(Option::is_none)
    }

    /// KV lanes currently leased by live lanes.
    pub fn kv_in_use(&self) -> usize {
        self.pool.in_use()
    }

    /// Fault injection passthrough: fail this batch's next `n` KV
    /// allocations (see [`KvPool::inject_alloc_failures`]). The next
    /// admissions abort with a typed `admission failed` error instead
    /// of entering a lane.
    pub fn inject_kv_alloc_failures(&mut self, n: u64) {
        self.pool.inject_alloc_failures(n);
    }

    /// Lifetime lane allocations in this batch's pool — exceeds the
    /// lane count once retired lanes recycle into admissions.
    pub fn kv_total_allocs(&self) -> u64 {
        self.pool.total_allocs
    }

    /// Full-prompt chain hits: admissions that skipped their prefill.
    pub fn prefix_hits(&self) -> u64 {
        self.pool.prefix_hits
    }

    /// Cached blocks reused at admission (partial matches included).
    pub fn prefix_hit_blocks(&self) -> u64 {
        self.pool.prefix_hit_blocks
    }

    /// Chain blocks reclaimed by the LRU evictor under page pressure.
    pub fn prefix_evictions(&self) -> u64 {
        self.pool.prefix_evictions
    }

    /// Prefix pages resident in this batch's pool (pinned + retained).
    pub fn kv_shared_pages(&self) -> usize {
        self.pool.prefix_resident_pages()
    }

    /// Lanes suspended to the cold tier over this machine's lifetime.
    pub fn kv_preempts(&self) -> u64 {
        self.pool.preempts
    }

    /// Suspended lanes restored from the cold tier.
    pub fn kv_resumes(&self) -> u64 {
        self.pool.resumes
    }

    /// Total bytes ever spilled to the cold tier by suspensions.
    pub fn kv_spilled_bytes(&self) -> u64 {
        self.pool.spilled_bytes
    }

    /// Live lanes that have not reached their finish boundary — the
    /// preemption watermark's demand signal: each may commit one more
    /// block (at most one new tail page) next cycle.
    pub fn unfinished_lanes(&self) -> usize {
        self.lanes.iter().flatten().filter(|l| !l.finished).count()
    }

    /// Tail pages on the pool's free list (the watermark supply
    /// signal; see [`BatchState::with_kv_budgets`]).
    pub fn kv_tail_pages_free(&self) -> usize {
        self.pool.tail_pages_free()
    }

    pub fn kv_prompt_pages_free(&self) -> usize {
        self.pool.prompt_pages_free()
    }

    /// Tail pages provisioned in this machine's pool.
    pub fn kv_tail_page_budget(&self) -> usize {
        self.pool.tail_page_budget()
    }

    pub fn kv_prompt_page_budget(&self) -> usize {
        self.pool.prompt_page_budget()
    }

    /// Tail pages covering one full gen region; `tail_page_budget /
    /// tail_pages_full` is the one-owner contiguous-slot lane cap the
    /// preempt bench compares against.
    pub fn kv_tail_pages_full(&self) -> usize {
        self.pool.tail_pages_full()
    }

    /// Leak check: every leased pool lane is owned by exactly one live
    /// lane. Holds between any two machine calls (admissions release
    /// their lease on every error path; retirement, cancellation, and
    /// suspension free or spill eagerly). `tests/preemption.rs` and the
    /// fault-tolerance tests call this after draining a machine;
    /// [`KvPool::assert_no_leaks`] checks the page-level accounting
    /// underneath.
    pub fn assert_kv_balanced(&self) {
        let held = self
            .lanes
            .iter()
            .flatten()
            .filter(|l| l.lease.is_some())
            .count();
        assert_eq!(
            self.pool.in_use(),
            held,
            "leaked KV lanes: pool leases {} but lanes hold {}",
            self.pool.in_use(),
            held
        );
        if held == 0 {
            self.pool.assert_no_leaks();
        }
    }

    /// Diagnostic/test accessor: `(resident blocks, min refcount)` of a
    /// prompt's cached chain under this machine's weights.
    pub fn prefix_chain_info(
        &self,
        prompt_ids: &[i32],
    ) -> Option<(usize, usize)> {
        self.pool.prefix_chain_info(self.weights.seed, prompt_ids)
    }

    /// Admit one request into a free lane: a single-lane prefill
    /// (padded to the smallest exported bucket) for the caching
    /// methods, a lane lease only for the approximate-cache teachers,
    /// nothing for the cache-less baselines. Legal at any block
    /// boundary — the new lane starts at block 0 in its own cohort and
    /// never perturbs in-flight lanes.
    ///
    /// Admissions are per-lane by design (a mid-flight join has no one
    /// to share a call with). When a batch opens with several requests
    /// at once this costs one prefill launch per lane where the
    /// closed-batch engine runs one batched call — negligible on the
    /// reference backend; a batched group-admit entry point is the
    /// obvious extension if launch overhead ever dominates on a device
    /// backend.
    pub fn admit(
        &mut self,
        prompt_ids: &[i32],
        tau: Option<f32>,
    ) -> Result<usize> {
        anyhow::ensure!(
            prompt_ids.len() == self.geom.prompt_len,
            "prompt must be padded to {} tokens (got {})",
            self.geom.prompt_len,
            prompt_ids.len()
        );
        let idx = self
            .lanes
            .iter()
            .position(Option::is_none)
            .ok_or_else(|| anyhow::anyhow!("no free lane"))?;
        // a mid-flight join is an admission NEXT TO live lanes in a
        // machine that has stepped; an admission into a drained
        // (retained) machine is a fresh start, not a join
        let joins_live = self.lanes.iter().any(Option::is_some);
        let progs = Programs::new(&self.rt, &self.weights);
        let mut seq = SequenceState::new(&self.geom, prompt_ids);
        let tau = tau.unwrap_or(self.opts.tau_conf);
        // smallest exported bucket that fits one prompt row — a
        // manifest need not export bucket 1
        let pre_pad = pad_of(&self.buckets, 1);
        // the prefix trie is keyed by the weight identity: chains are
        // pure functions of (weights, prompt tokens), so two models
        // must never share one
        let prefix_tag =
            if self.prefix_cache { Some(self.weights.seed) } else { None };
        let (lease, cur_tok) = match self.method {
            Method::Vanilla | Method::FastDllmPar => (None, 0),
            Method::DllmCache | Method::FastDllmDc => {
                (Some(self.pool.alloc()?), 0)
            }
            Method::Cdlm => (
                Some(cdlm::machine_prefill(
                    &progs,
                    &mut self.pool,
                    &mut seq,
                    pre_pad,
                    prefix_tag,
                    &mut self.scratch,
                )?),
                0,
            ),
            Method::Ar => {
                let (lease, tok) = ar::machine_prefill(
                    &progs,
                    &mut self.pool,
                    &mut seq,
                    pre_pad,
                    prefix_tag,
                    &mut self.scratch,
                )?;
                (Some(lease), tok)
            }
        };
        self.lanes[idx] = Some(Lane {
            seq,
            tau,
            block: 0,
            ssr: usize::MAX,
            cur_tok,
            ar_pos: 0,
            lease,
            finished: false,
        });
        self.total_admissions += 1;
        if self.stepped && joins_live {
            self.mid_flight_admissions += 1;
        }
        Ok(idx)
    }

    /// Lane grouping key: lanes sharing a cursor share a committed
    /// cache length and block offset, so they can step in one lockstep
    /// program call.
    fn cursor_of(&self, lane: &Lane) -> usize {
        match self.method {
            Method::Ar => lane.ar_pos,
            _ => lane.block,
        }
    }

    /// Advance every unfinished lane by one block: cohorts (grouped by
    /// cursor, deterministic order) each refine their block to
    /// completion, apply the method's boundary policy, and commit block
    /// KV for lanes that continue. Afterwards, finished lanes wait in
    /// place for [`BatchState::take_finished`].
    ///
    /// Returns one [`CommitRun`] per lane stepped: which generation
    /// span that lane finalized this cycle (ascending cursor, then
    /// ascending lane — per-lane runs across cycles are therefore in
    /// generation order).
    pub fn step_cycle(&mut self) -> Result<Vec<CommitRun>> {
        self.stepped = true;
        let mut cohorts: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, l) in self.lanes.iter().enumerate() {
            if let Some(l) = l {
                if !l.finished {
                    cohorts.entry(self.cursor_of(l)).or_default().push(i);
                }
            }
        }
        let mut runs = Vec::new();
        for (cursor, idxs) in cohorts {
            self.step_cohort(cursor, &idxs, &mut runs)?;
        }
        Ok(runs)
    }

    /// Cancel a live lane at the block boundary: drop its state,
    /// release its KV lease (which also unpins any shared-prefix chain
    /// the admission attached — the pages stay resident as warm cache),
    /// and return the partial outcome so the caller can account the
    /// wasted steps/model calls. Legal between any two [`step_cycle`]
    /// calls; in-flight cohort mates are never perturbed (per-lane
    /// program outputs are independent of batch composition, the same
    /// property admission relies on). Returns `None` for a lane that is
    /// already empty.
    ///
    /// [`step_cycle`]: BatchState::step_cycle
    pub fn cancel_lane(&mut self, lane: usize) -> Option<DecodeOutcome> {
        let l = self.lanes.get_mut(lane)?.take()?;
        if let Some(lease) = l.lease {
            self.pool.release(lease);
        }
        Some(l.seq.into_outcome())
    }

    /// Suspend a live, unfinished lane at the block boundary: the KV
    /// pages spill to the pool's cold tier, the lane and its pool lane
    /// free immediately for another admission, and the decode state
    /// comes back as a [`SuspendedLane`] the caller parks. Returns
    /// `None` for an empty lane or one already finished (retire those
    /// through [`BatchState::take_finished`] instead — suspending a
    /// finished lane would only delay its response).
    ///
    /// Legal between any two [`step_cycle`] calls, like
    /// [`BatchState::cancel_lane`]. The shared-prefix chain pin (if
    /// any) stays pinned inside the spilled state so the cached prompt
    /// pages survive the parking.
    pub fn suspend_lane(&mut self, lane: usize) -> Option<SuspendedLane> {
        match self.lanes.get(lane)?.as_ref() {
            Some(l) if !l.finished => {}
            _ => return None,
        }
        let l = self.lanes[lane].take().expect("checked live above");
        let kv = l.lease.map(|lease| self.pool.suspend(lease));
        Some(SuspendedLane {
            seq: l.seq,
            tau: l.tau,
            block: l.block,
            ssr: l.ssr,
            cur_tok: l.cur_tok,
            ar_pos: l.ar_pos,
            kv,
        })
    }

    /// Whether [`BatchState::resume_lane`] would succeed right now: a
    /// free lane exists and the pool has pages for the spilled state.
    pub fn can_resume(&self, s: &SuspendedLane) -> bool {
        self.lanes.iter().any(Option::is_none)
            && match &s.kv {
                Some(kv) => self.pool.can_resume(kv),
                None => true,
            }
    }

    /// Resume a suspended lane onto a free lane: pages re-allocate, the
    /// spilled bytes copy back, and the lane continues from its block
    /// cursor byte-identically. On failure (no free lane, or the pool
    /// cannot seat the pages right now) the state is handed back intact
    /// for the caller to retry later.
    pub fn resume_lane(
        &mut self,
        mut s: SuspendedLane,
    ) -> std::result::Result<usize, SuspendedLane> {
        let Some(idx) = self.lanes.iter().position(Option::is_none) else {
            return Err(s);
        };
        let lease = match s.kv.take() {
            None => None,
            Some(kv) => match self.pool.resume(kv) {
                Ok(lease) => Some(lease),
                Err(kv) => {
                    s.kv = Some(kv);
                    return Err(s);
                }
            },
        };
        self.lanes[idx] = Some(Lane {
            seq: s.seq,
            tau: s.tau,
            block: s.block,
            ssr: s.ssr,
            cur_tok: s.cur_tok,
            ar_pos: s.ar_pos,
            lease,
            finished: false,
        });
        Ok(idx)
    }

    /// Drop a parked lane for good (request cancelled or its client
    /// gone): unpins any chain the spilled state still holds and
    /// returns the partial outcome for abort accounting.
    pub fn discard_suspended(&mut self, s: SuspendedLane) -> DecodeOutcome {
        if let Some(kv) = s.kv {
            self.pool.discard_suspended(kv);
        }
        s.seq.into_outcome()
    }

    /// Retire every finished lane: release its KV lease (mid-batch lane
    /// recycling — the pool lane is immediately reusable by the next
    /// admission) and convert its state into a [`DecodeOutcome`].
    /// Returns `(lane index, outcome)` pairs.
    pub fn take_finished(&mut self) -> Vec<(usize, DecodeOutcome)> {
        let mut out = Vec::new();
        for (i, entry) in self.lanes.iter_mut().enumerate() {
            if entry.as_ref().is_some_and(|l| l.finished) {
                let lane = entry.take().expect("checked above");
                if let Some(lease) = lane.lease {
                    self.pool.release(lease);
                }
                out.push((i, lane.seq.into_outcome()));
            }
        }
        out
    }

    /// One cohort's block: dispatch to the per-method policy functions
    /// that live beside each closed-batch engine, then report the span
    /// each lane committed as [`CommitRun`]s.
    fn step_cohort(
        &mut self,
        cursor: usize,
        idxs: &[usize],
        runs: &mut Vec<CommitRun>,
    ) -> Result<()> {
        let blk = self.opts.block_size;
        let num_blocks = self.geom.gen_len / blk;
        let progs = Programs::new(&self.rt, &self.weights);
        // disjoint &mut Lane refs, ascending lane order (idxs is sorted)
        let mut lane_refs: Vec<&mut Lane> = Vec::with_capacity(idxs.len());
        let mut rest: &mut [Option<Lane>] = &mut self.lanes;
        let mut consumed = 0usize;
        for &i in idxs {
            let (head, tail) = rest.split_at_mut(i - consumed + 1);
            lane_refs
                .push(head[i - consumed].as_mut().expect("cohort lane live"));
            consumed = i + 1;
            rest = tail;
        }
        let n = lane_refs.len();
        let pad_to = pad_of(&self.buckets, n);
        let taus: Vec<f32> = lane_refs.iter().map(|l| l.tau).collect();
        match self.method {
            Method::Vanilla | Method::FastDllmPar => {
                let policy = if self.method == Method::Vanilla {
                    bidirectional::Policy::TopM
                } else {
                    bidirectional::Policy::Threshold
                };
                {
                    let mut seqs: Vec<&mut SequenceState> =
                        lane_refs.iter_mut().map(|l| &mut l.seq).collect();
                    bidirectional::machine_step(
                        &progs,
                        &self.geom,
                        &self.opts,
                        policy,
                        &mut seqs,
                        &taus,
                        cursor * blk,
                        blk,
                        pad_to,
                        &mut self.scratch,
                    )?;
                }
                // no early stop in the bidirectional baselines
                for l in lane_refs {
                    l.block += 1;
                    if l.block >= num_blocks {
                        l.finished = true;
                    }
                }
            }
            Method::DllmCache | Method::FastDllmDc => {
                let variant = if self.method == Method::DllmCache {
                    cached_teacher::Variant::DllmCache
                } else {
                    cached_teacher::Variant::DualCache
                };
                let ssr_in =
                    lane_refs.iter().map(|l| l.ssr).max().unwrap_or(usize::MAX);
                let ssr_out = {
                    // split each lane borrow into disjoint seq + lease
                    let mut seqs: Vec<&mut SequenceState> =
                        Vec::with_capacity(n);
                    let mut leases: Vec<&KvLease> = Vec::with_capacity(n);
                    for l in lane_refs.iter_mut() {
                        let Lane { seq, lease, .. } = &mut **l;
                        seqs.push(seq);
                        leases.push(
                            lease.as_ref().expect("cached lane holds a lease"),
                        );
                    }
                    cached_teacher::machine_step(
                        &progs,
                        &self.geom,
                        &self.opts,
                        variant,
                        &mut self.pool,
                        &mut seqs,
                        &taus,
                        &leases,
                        ssr_in,
                        cursor * blk,
                        blk,
                        pad_to,
                        &mut self.scratch,
                    )?
                };
                for l in lane_refs {
                    l.ssr = ssr_out;
                    l.block += 1;
                    if l.block >= num_blocks {
                        l.finished = true;
                    }
                }
            }
            Method::Cdlm => {
                {
                    let mut seqs: Vec<&mut SequenceState> =
                        Vec::with_capacity(n);
                    let mut leases: Vec<&KvLease> = Vec::with_capacity(n);
                    for l in lane_refs.iter_mut() {
                        let Lane { seq, lease, .. } = &mut **l;
                        seqs.push(seq);
                        leases.push(
                            lease.as_ref().expect("cdlm lane holds a lease"),
                        );
                    }
                    cdlm::machine_step(
                        &progs,
                        &self.geom,
                        &self.pool,
                        &mut seqs,
                        &taus,
                        &leases,
                        cursor * blk,
                        blk,
                        pad_to,
                        &mut self.scratch,
                    )?;
                }
                // commit block KV only for lanes continuing past the
                // boundary (early-stopped lanes retire without paying
                // the commit call — same as the closed-batch engine;
                // their pages never need to cover later blocks because
                // retirement frees them before the cohort re-forms)
                if cursor + 1 < num_blocks {
                    let mut cseqs: Vec<&mut SequenceState> =
                        Vec::with_capacity(n);
                    let mut cleases: Vec<&KvLease> = Vec::with_capacity(n);
                    for l in lane_refs.iter_mut() {
                        if !l.seq.done {
                            let Lane { seq, lease, .. } = &mut **l;
                            cseqs.push(seq);
                            cleases.push(
                                lease
                                    .as_ref()
                                    .expect("cdlm lane holds a lease"),
                            );
                        }
                    }
                    let pad = pad_of(&self.buckets, cseqs.len());
                    cdlm::machine_commit(
                        &progs,
                        &self.geom,
                        &mut self.pool,
                        &mut cseqs,
                        &cleases,
                        cursor * blk,
                        blk,
                        pad,
                        &mut self.scratch,
                    )?;
                }
                for l in lane_refs {
                    if l.seq.done {
                        l.finished = true;
                    } else {
                        l.block += 1;
                        if l.block >= num_blocks {
                            l.finished = true;
                        }
                    }
                }
            }
            Method::Ar => {
                let mut curs: Vec<i32> =
                    lane_refs.iter().map(|l| l.cur_tok).collect();
                {
                    let mut seqs: Vec<&mut SequenceState> =
                        Vec::with_capacity(n);
                    let mut leases: Vec<&KvLease> = Vec::with_capacity(n);
                    for l in lane_refs.iter_mut() {
                        let Lane { seq, lease, .. } = &mut **l;
                        seqs.push(seq);
                        leases.push(
                            lease.as_ref().expect("ar lane holds a lease"),
                        );
                    }
                    ar::machine_step(
                        &progs,
                        &self.geom,
                        &mut self.pool,
                        &mut seqs,
                        &mut curs,
                        &leases,
                        cursor,
                        blk,
                        pad_to,
                        &mut self.scratch,
                    )?;
                }
                let g_len = self.geom.gen_len;
                for (l, cur) in lane_refs.into_iter().zip(curs) {
                    l.cur_tok = cur;
                    l.ar_pos = (cursor + blk).min(g_len);
                    if l.seq.done || l.ar_pos >= g_len {
                        l.finished = true;
                    }
                }
            }
        }
        // report the span each cohort lane committed this cycle (the
        // lane borrows above are released; read back through `lanes`)
        for &i in idxs {
            let l = self.lanes[i].as_ref().expect("cohort lane live");
            let (start, len) = match self.method {
                Method::Ar => (cursor, l.ar_pos - cursor),
                _ => (cursor * blk, blk),
            };
            runs.push(CommitRun {
                lane: i,
                start,
                tokens: l.seq.gen[start..start + len].to_vec(),
            });
        }
        Ok(())
    }
}

/// Smallest exported bucket that fits `n` call rows (free function so
/// callers holding `&mut` lane borrows can still consult the field).
fn pad_of(buckets: &[usize], n: usize) -> usize {
    buckets.iter().copied().find(|&b| b >= n).unwrap_or(n)
}

/// Build a bucket-padded per-row vector: rows `>= n` alias row `n - 1`
/// (the single pad-by-aliasing contract every machine policy function
/// shares — change the padding scheme here, not per engine).
pub(crate) fn pad_map<T>(
    n: usize,
    pad_to: usize,
    f: impl Fn(usize) -> T,
) -> Vec<T> {
    (0..pad_to).map(|r| f(r.min(n - 1))).collect()
}

/// Bucket-padded tensors for an admission prefill: `pad_to` copies of
/// the one real prompt row plus the matching `valid_from` column (the
/// shared scaffold of `cdlm::machine_prefill`/`ar::machine_prefill`).
pub(crate) fn padded_prompt(
    seq: &SequenceState,
    pad_to: usize,
) -> (TensorI32, TensorI32) {
    let p_len = seq.prompt_ids.len();
    let mut pid = Vec::with_capacity(pad_to * p_len);
    for _ in 0..pad_to {
        pid.extend_from_slice(&seq.prompt_ids);
    }
    (
        TensorI32::from_vec(&[pad_to, p_len], pid),
        TensorI32::from_vec(&[pad_to], vec![seq.valid_from; pad_to]),
    )
}
