//! Autoregressive baseline (paper Fig. 3 / §5.2.3): greedy decoding with
//! an exact token-level KV cache. One `ar_step` per generated token;
//! lanes stop at `<eos>` but the lockstep batch runs until all lanes
//! finish (dead lanes keep executing, their outputs ignored). Each step
//! borrows a zero-copy `KvView` of the lane pages through the cohort's
//! [`KvLease`]s and writes into the caller's reused [`StepScratch`]
//! arena — the pre-view per-token `[L, bs, H, S, dh]` gather (the
//! single largest memcpy in the old decode loop) no longer exists, and
//! a warm step allocates nothing.

use anyhow::Result;

use super::{machine, DecodeOutcome, StepScratch};
use crate::coordinator::kv_cache::{KvLease, KvPool};
use crate::coordinator::sequence::SequenceState;
use crate::runtime::{Geometry, Programs, TensorI32};
use crate::tokenizer::EOS;

pub fn decode(
    progs: &Programs,
    geom: &Geometry,
    prompts: &[&[i32]],
    pool: &mut KvPool,
) -> Result<Vec<DecodeOutcome>> {
    let bs = prompts.len();
    let (p_len, g_len) = (geom.prompt_len, geom.gen_len);

    let mut seqs: Vec<SequenceState> = prompts
        .iter()
        .map(|p| SequenceState::new(geom, p))
        .collect();
    let valid_from =
        TensorI32::from_vec(&[bs], seqs.iter().map(|s| s.valid_from).collect());

    let mut scratch = StepScratch::new();

    // ---- causal prefill: prompt KV + first-token logits
    let mut prompt_ids = vec![0i32; bs * p_len];
    for (r, s) in seqs.iter().enumerate() {
        prompt_ids[r * p_len..(r + 1) * p_len].copy_from_slice(&s.prompt_ids);
    }
    progs.ar_prefill(
        bs,
        &TensorI32::from_vec(&[bs, p_len], prompt_ids),
        &valid_from,
        &mut scratch.arena.ar_prefill,
    )?;
    let leases: Vec<KvLease> =
        (0..bs).map(|_| pool.alloc()).collect::<Result<_>>()?;
    for (lane, lease) in leases.iter().enumerate() {
        pool.write_prefill(
            lease,
            lane,
            bs,
            &scratch.arena.ar_prefill.k.data,
            &scratch.arena.ar_prefill.v.data,
        )?;
    }
    for s in seqs.iter_mut() {
        s.model_calls += 1;
    }
    let lrefs: Vec<&KvLease> = leases.iter().collect();

    let mut cur: Vec<i32> = scratch.arena.ar_prefill.tok.data.clone();
    // reused every step: one [bs] token buffer
    scratch.arena.tok.reuse(&[bs]);
    let mut done = vec![false; bs];
    for i in 0..g_len {
        for r in 0..bs {
            if !done[r] {
                seqs[r].gen[i] = cur[r];
                seqs[r].note_finalized();
                seqs[r].steps += 1;
                if cur[r] == EOS {
                    done[r] = true;
                    seqs[r].mark_done();
                }
            }
        }
        if done.iter().all(|&d| d) || i == g_len - 1 {
            break;
        }
        scratch.arena.tok.data.copy_from_slice(&cur);
        progs.ar_step(
            bs,
            &pool.view(&lrefs),
            &valid_from,
            &scratch.arena.tok,
            &mut scratch.arena.ar_step,
        )?;
        // append the new token's KV for every lane (exact caching)
        for (lane, lease) in lrefs.iter().enumerate() {
            pool.commit_block(
                lease,
                lane,
                bs,
                1,
                &scratch.arena.ar_step.k1.data,
                &scratch.arena.ar_step.v1.data,
            )?;
            if !done[lane] {
                seqs[lane].model_calls += 1;
            }
        }
        cur.copy_from_slice(&scratch.arena.ar_step.tok.data);
    }
    drop(lrefs);
    for lease in leases {
        pool.release(lease);
    }
    Ok(seqs.into_iter().map(SequenceState::into_outcome).collect())
}

// ---------------------------------------------------------------------------
// Block-step-machine policy (resumable per-lane decode)
// ---------------------------------------------------------------------------

/// Admission prefill for one lane: lease a lane, install the causal
/// prompt KV with a single-lane `ar_prefill` call (padded to the
/// smallest exported bucket by aliasing the one real prompt row, like
/// every other machine program call), and return the lease plus the
/// first-token proposal the prefill emits.
///
/// With `prefix_tag` set, a fully cached prompt whose chain also
/// carries the cached first-token proposal pins it and skips the
/// prefill call (AR prefill is the only program that returns decode
/// state beyond KV, so the proposal is cached on the chain leaf at
/// install time — a chain without one counts as a miss). Misses prefill
/// and install as usual, falling back to private pages under pinned
/// page pressure.
pub(crate) fn machine_prefill(
    progs: &Programs,
    pool: &mut KvPool,
    seq: &mut SequenceState,
    pad_to: usize,
    prefix_tag: Option<u64>,
    scratch: &mut StepScratch,
) -> Result<(KvLease, i32)> {
    let lease = pool.alloc()?;
    if let Some(tag) = prefix_tag {
        if let Some(pin) =
            pool.prefix_acquire_full(tag, &seq.prompt_ids, true)
        {
            let tok = pin.ar_tok.expect("hit required a cached first token");
            pool.attach_chain(&lease, pin);
            return Ok((lease, tok));
        }
    }
    let (pid, vf) = machine::padded_prompt(seq, pad_to);
    if let Err(e) =
        progs.ar_prefill(pad_to, &pid, &vf, &mut scratch.arena.ar_prefill)
    {
        // hand the lane back: a failed admission must not leak it
        pool.release(lease);
        return Err(e);
    }
    let pre = &scratch.arena.ar_prefill;
    seq.model_calls += 1;
    if let Some(tag) = prefix_tag {
        if let Ok(pin) = pool.prefix_install(
            tag,
            &seq.prompt_ids,
            0,
            pad_to,
            &pre.k.data,
            &pre.v.data,
            Some(pre.tok.data[0]),
        ) {
            let tok = pre.tok.data[0];
            pool.attach_chain(&lease, pin);
            return Ok((lease, tok));
        }
    }
    if let Err(e) = pool.write_prefill(&lease, 0, pad_to, &pre.k.data, &pre.v.data)
    {
        pool.release(lease);
        return Err(e);
    }
    Ok((lease, pre.tok.data[0]))
}

/// Advance one cohort by up to `blk` token positions starting at gen
/// index `pos0` — the greedy loop of [`decode`] cut at block
/// boundaries so lanes can retire and admissions can join. Each
/// iteration writes the pending proposal, then runs one `ar_step`
/// (which also commits that token's KV for every cohort lane, done or
/// not — exact caching, same as the closed-batch engine). `cur` holds
/// each lane's pending proposal and is written back for the next block.
/// All per-call buffers come from the caller's [`StepScratch`]: a warm
/// step allocates nothing (bucket padding of KV lanes happens inside
/// `KvPool::view_padded`, aliasing the last real lane's pages).
#[allow(clippy::too_many_arguments)]
pub(crate) fn machine_step(
    progs: &Programs,
    geom: &Geometry,
    pool: &mut KvPool,
    seqs: &mut [&mut SequenceState],
    cur: &mut [i32],
    leases: &[&KvLease],
    pos0: usize,
    blk: usize,
    pad_to: usize,
    scratch: &mut StepScratch,
) -> Result<()> {
    let n = seqs.len();
    debug_assert_eq!(n, leases.len(), "cohort seqs/leases out of sync");
    let g_len = geom.gen_len;
    scratch.arena.valid_from.reuse(&[pad_to]);
    for r in 0..pad_to {
        scratch.arena.valid_from.data[r] = seqs[r.min(n - 1)].valid_from;
    }
    scratch.arena.tok.reuse(&[pad_to]);
    for t in 0..blk {
        let i = pos0 + t;
        for r in 0..n {
            if !seqs[r].done {
                seqs[r].gen[i] = cur[r];
                seqs[r].note_finalized();
                seqs[r].steps += 1;
                if cur[r] == EOS {
                    seqs[r].mark_done();
                }
            }
        }
        if (0..n).all(|r| seqs[r].done) || i == g_len - 1 {
            break;
        }
        for r in 0..pad_to {
            scratch.arena.tok.data[r] = cur[r.min(n - 1)];
        }
        progs.ar_step(
            pad_to,
            &pool.view_padded(leases, pad_to),
            &scratch.arena.valid_from,
            &scratch.arena.tok,
            &mut scratch.arena.ar_step,
        )?;
        // append the new token's KV for every real lane (exact caching)
        for (lane, lease) in leases.iter().enumerate() {
            pool.commit_block(
                lease,
                lane,
                pad_to,
                1,
                &scratch.arena.ar_step.k1.data,
                &scratch.arena.ar_step.v1.data,
            )?;
            if !seqs[lane].done {
                seqs[lane].model_calls += 1;
            }
        }
        cur[..n].copy_from_slice(&scratch.arena.ar_step.tok.data[..n]);
    }
    Ok(())
}
