//! Speculative decoding extension (paper Appendix C): CDLM drafts,
//! an equal-size AR model verifies.
//!
//! Per block:
//!   1. the CDLM student drafts the whole B-token block with its own
//!      exact cache (few refinement steps — that is why a *consistency*
//!      drafter is viable where a naive DLM drafter is not);
//!   2. the AR verifier runs ONE parallel `ar_verify` pass (causal
//!      teacher-forcing over the drafted tokens against the AR cache);
//!   3. standard greedy acceptance: the longest draft prefix that
//!      matches the verifier's own greedy choices is accepted, plus the
//!      verifier's correction token at the first mismatch (so every
//!      verify pass emits >= 1 token);
//!   4. accepted tokens' AR KV is committed from the verify pass
//!      (positions beyond the accepted prefix are recomputed when they
//!      are re-drafted — the cache stays exact).
//!
//! Both cache sets (drafter + verifier) lease lanes from one pool;
//! every program call borrows a zero-copy `KvView` of the relevant
//! lease set. The drafter's and verifier's block outputs must be live
//! at the same time (the commit step reads both), so this engine keeps
//! two [`BlockStepOut`] scratch structs — the two-arena case the
//! [`crate::runtime::StepArena`] docs call out — both reused across
//! every draft/verify/commit call.
//!
//! The output equals AR greedy decoding exactly (same tokens), but with
//! fewer verifier passes when the drafter agrees — the acceptance rate
//! is the figure of merit (reported in `DecodeOutcome::steps` as
//! verify passes vs tokens).
//!
//! `DecodeOutcome::ttft` here dates from the first *drafted* token,
//! which the verifier may later roll back — it can lead the first
//! surviving token by up to one draft/verify round. Speculative
//! decoding is not router-served, so no serving metric consumes this;
//! tighten to acceptance time if that changes.

use anyhow::Result;

use super::{DecodeOpts, DecodeOutcome};
use crate::coordinator::kv_cache::{KvLease, KvPool};
use crate::coordinator::sequence::SequenceState;
use crate::runtime::programs::{ArPrefillOut, BlockStepOut, PrefillOut};
use crate::runtime::{Geometry, Programs, TensorI32};
use crate::tokenizer::MASK;

/// Decode with CDLM drafts + AR verification. `draft_progs` runs the
/// student weights, `verify_progs` the AR weights.
#[allow(clippy::too_many_arguments)]
pub fn decode(
    draft_progs: &Programs,
    verify_progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    prompts: &[&[i32]],
    pool: &mut KvPool,
) -> Result<Vec<DecodeOutcome>> {
    let bs = prompts.len();
    let (p_len, g_len) = (geom.prompt_len, geom.gen_len);
    let blk = opts.block_size;
    let num_blocks = g_len / blk;

    let mut seqs: Vec<SequenceState> = prompts
        .iter()
        .map(|p| SequenceState::new(geom, p))
        .collect();
    let valid_from =
        TensorI32::from_vec(&[bs], seqs.iter().map(|s| s.valid_from).collect());

    let mut prompt_ids = vec![0i32; bs * p_len];
    for (r, s) in seqs.iter().enumerate() {
        prompt_ids[r * p_len..(r + 1) * p_len].copy_from_slice(&s.prompt_ids);
    }
    let pid_t = TensorI32::from_vec(&[bs, p_len], prompt_ids);

    // two cache sets: drafter (student) + verifier (AR)
    let mut d_pre = PrefillOut::default();
    draft_progs.student_prefill(bs, &pid_t, &valid_from, &mut d_pre)?;
    let mut v_pre = ArPrefillOut::default();
    verify_progs.ar_prefill(bs, &pid_t, &valid_from, &mut v_pre)?;
    let d_leases: Vec<KvLease> =
        (0..bs).map(|_| pool.alloc()).collect::<Result<_>>()?;
    let v_leases: Vec<KvLease> =
        (0..bs).map(|_| pool.alloc()).collect::<Result<_>>()?;
    for lane in 0..bs {
        pool.write_prefill(
            &d_leases[lane],
            lane,
            bs,
            &d_pre.k.data,
            &d_pre.v.data,
        )?;
        pool.write_prefill(
            &v_leases[lane],
            lane,
            bs,
            &v_pre.k.data,
            &v_pre.v.data,
        )?;
        seqs[lane].model_calls += 2;
    }
    let d_refs: Vec<&KvLease> = d_leases.iter().collect();
    let v_refs: Vec<&KvLease> = v_leases.iter().collect();

    // verifier's next-token proposal entering the current block
    let mut next_tok: Vec<i32> = v_pre.tok.data.clone();
    // reused [bs, B] block-id buffer for every draft/verify/commit call,
    // plus the two live block outputs (drafter + verifier)
    let mut blk_t = TensorI32::from_vec(&[bs, blk], vec![MASK; bs * blk]);
    let mut d_out = BlockStepOut::default();
    let mut v_out = BlockStepOut::default();

    for b in 0..num_blocks {
        let lo = b * blk;
        if seqs.iter().all(|s| s.done) {
            break;
        }
        // ---- 1. draft the full block with the CDLM student
        loop {
            let any = (0..bs).any(|r| {
                !seqs[r].done && !seqs[r].block_fully_finalized(lo, blk)
            });
            if !any {
                break;
            }
            for (r, s) in seqs.iter().enumerate() {
                blk_t.data[r * blk..(r + 1) * blk]
                    .copy_from_slice(&s.gen[lo..lo + blk]);
            }
            draft_progs.student_block_step(
                bs,
                blk,
                &pool.view(&d_refs),
                &valid_from,
                &blk_t,
                (p_len + lo) as i32,
                &mut d_out,
            )?;
            for r in 0..bs {
                if seqs[r].done {
                    continue;
                }
                if !seqs[r].block_fully_finalized(lo, blk) {
                    let base = r * blk;
                    seqs[r].finalize_threshold(
                        lo,
                        &d_out.tok.data[base..base + blk],
                        &d_out.conf.data[base..base + blk],
                        opts.tau_conf,
                    );
                }
                seqs[r].steps += 1;
                seqs[r].model_calls += 1;
            }
        }
        // force the first draft position to the verifier's proposal
        // (it is already decided by AR greedy semantics)
        for (r, s) in seqs.iter_mut().enumerate() {
            if !s.done {
                s.gen[lo] = next_tok[r];
                s.note_finalized();
            }
        }

        // ---- 2. one parallel verify pass over the drafted block
        for (r, s) in seqs.iter().enumerate() {
            blk_t.data[r * blk..(r + 1) * blk]
                .copy_from_slice(&s.gen[lo..lo + blk]);
        }
        verify_progs.ar_verify(
            bs,
            blk,
            &pool.view(&v_refs),
            &valid_from,
            &blk_t,
            (p_len + lo) as i32,
            &mut v_out,
        )?;
        // ---- 3. greedy acceptance per lane
        for r in 0..bs {
            if seqs[r].done {
                continue;
            }
            seqs[r].model_calls += 1;
            let base = r * blk;
            // v_out.tok[i] = AR's greedy continuation AFTER draft token i
            let mut accepted = 1usize; // position lo holds AR's own token
            while accepted < blk {
                let ar_choice = v_out.tok.data[base + accepted - 1];
                if seqs[r].gen[lo + accepted] == ar_choice {
                    accepted += 1;
                } else {
                    // correction: overwrite with the verifier's token
                    seqs[r].gen[lo + accepted] = ar_choice;
                    accepted += 1;
                    break;
                }
            }
            // roll back any draft tokens beyond the accepted prefix
            for i in accepted..blk {
                seqs[r].gen[lo + i] = MASK;
            }
            next_tok[r] = v_out.tok.data[base + accepted - 1];
        }
        // a block is only committed when fully accepted by every live
        // lane; otherwise the partial tail is re-drafted — for the toy
        // geometry we keep lanes in lockstep by re-running the block if
        // any lane has masked positions left
        let all_full = (0..bs)
            .all(|r| seqs[r].done || seqs[r].block_fully_finalized(lo, blk));
        if !all_full {
            // redraft remaining masked positions in the same block:
            // loop back without advancing (bounded: each verify pass
            // accepts >= 1 token per lane)
            continue_redraft(
                draft_progs,
                verify_progs,
                geom,
                opts,
                &mut seqs,
                &valid_from,
                pool,
                (&d_refs, &v_refs),
                lo,
                &mut next_tok,
                &mut blk_t,
                &mut d_out,
                &mut v_out,
            )?;
        }
        // ---- 4. early stop + commit both caches from final tokens
        for s in seqs.iter_mut() {
            if !s.done && s.eos_in(lo, blk) {
                s.mark_done();
            }
        }
        if seqs.iter().all(|s| s.done) || b + 1 == num_blocks {
            break;
        }
        for (r, s) in seqs.iter().enumerate() {
            blk_t.data[r * blk..(r + 1) * blk]
                .copy_from_slice(&s.gen[lo..lo + blk]);
        }
        draft_progs.student_block_step(
            bs,
            blk,
            &pool.view(&d_refs),
            &valid_from,
            &blk_t,
            (p_len + lo) as i32,
            &mut d_out,
        )?;
        verify_progs.ar_verify(
            bs,
            blk,
            &pool.view(&v_refs),
            &valid_from,
            &blk_t,
            (p_len + lo) as i32,
            &mut v_out,
        )?;
        // every lane commits — done lanes too, so their pages keep
        // covering the lockstep cache_len later views span; the
        // accounting stays gated on live lanes
        for lane in 0..bs {
            pool.commit_block(&d_leases[lane], lane, bs, blk,
                              &d_out.k_blk.data, &d_out.v_blk.data)?;
            pool.commit_block(&v_leases[lane], lane, bs, blk,
                              &v_out.k_blk.data, &v_out.v_blk.data)?;
            if !seqs[lane].done {
                seqs[lane].model_calls += 2;
                next_tok[lane] = v_out.tok.data[lane * blk + blk - 1];
            }
        }
    }
    drop(d_refs);
    drop(v_refs);
    for lease in d_leases.into_iter().chain(v_leases) {
        pool.release(lease);
    }
    Ok(seqs.into_iter().map(SequenceState::into_outcome).collect())
}

/// Re-draft + re-verify the unfinished tail of a block until every live
/// lane has it fully finalized. Bounded: each verify pass accepts at
/// least one token per lane. Reads both cache sets through fresh views
/// per call (`leases` is the (draft, verify) lease-set pair) and reuses
/// the caller's block-id buffer and block outputs.
#[allow(clippy::too_many_arguments)]
fn continue_redraft(
    draft_progs: &Programs,
    verify_progs: &Programs,
    geom: &Geometry,
    opts: &DecodeOpts,
    seqs: &mut [SequenceState],
    valid_from: &TensorI32,
    pool: &KvPool,
    leases: (&[&KvLease], &[&KvLease]),
    lo: usize,
    next_tok: &mut [i32],
    blk_t: &mut TensorI32,
    d_out: &mut BlockStepOut,
    v_out: &mut BlockStepOut,
) -> Result<()> {
    let (d_refs, v_refs) = leases;
    let bs = seqs.len();
    let blk = geom.block_size;
    let p_len = geom.prompt_len;
    // acceptance membership must be captured before drafting fills the
    // block, so one small index buffer survives (reused across passes)
    let mut unfinished: Vec<usize> = Vec::with_capacity(bs);
    let mut guard = 0;
    loop {
        guard += 1;
        anyhow::ensure!(guard <= blk + 1, "speculative redraft diverged");
        unfinished.clear();
        unfinished.extend((0..bs).filter(|&r| {
            !seqs[r].done && !seqs[r].block_fully_finalized(lo, blk)
        }));
        if unfinished.is_empty() {
            return Ok(());
        }
        // draft masked tail
        loop {
            let any = (0..bs).any(|r| {
                !seqs[r].done && !seqs[r].block_fully_finalized(lo, blk)
            });
            if !any {
                break;
            }
            for (r, s) in seqs.iter().enumerate() {
                blk_t.data[r * blk..(r + 1) * blk]
                    .copy_from_slice(&s.gen[lo..lo + blk]);
            }
            draft_progs.student_block_step(
                bs,
                blk,
                &pool.view(d_refs),
                valid_from,
                blk_t,
                (p_len + lo) as i32,
                d_out,
            )?;
            for r in 0..bs {
                if seqs[r].done || seqs[r].block_fully_finalized(lo, blk) {
                    continue;
                }
                let base = r * blk;
                seqs[r].finalize_threshold(
                    lo,
                    &d_out.tok.data[base..base + blk],
                    &d_out.conf.data[base..base + blk],
                    opts.tau_conf,
                );
                seqs[r].steps += 1;
                seqs[r].model_calls += 1;
            }
        }
        // verify
        for (r, s) in seqs.iter().enumerate() {
            blk_t.data[r * blk..(r + 1) * blk]
                .copy_from_slice(&s.gen[lo..lo + blk]);
        }
        verify_progs.ar_verify(
            bs,
            blk,
            &pool.view(v_refs),
            valid_from,
            blk_t,
            (p_len + lo) as i32,
            v_out,
        )?;
        for &r in &unfinished {
            seqs[r].model_calls += 1;
            let base = r * blk;
            let mut accepted = 1usize;
            while accepted < blk {
                let ar_choice = v_out.tok.data[base + accepted - 1];
                if seqs[r].gen[lo + accepted] == ar_choice {
                    accepted += 1;
                } else {
                    seqs[r].gen[lo + accepted] = ar_choice;
                    accepted += 1;
                    break;
                }
            }
            for i in accepted..blk {
                seqs[r].gen[lo + i] = MASK;
            }
            next_tok[r] = v_out.tok.data[base + accepted - 1];
        }
    }
}

#[cfg(test)]
mod tests {
    // Acceptance-rule unit semantics (pure logic, no runtime): the
    // accepted prefix is AR-greedy-consistent by construction.
    #[test]
    fn acceptance_is_greedy_prefix() {
        // draft:       [a, b, c, d]  (a fixed = AR proposal)
        // AR greedy:   after a -> b, after b -> X (mismatch at c)
        // result: accept a, b, then correction X; tail re-masked
        let draft = [10, 11, 12, 13];
        let ar_next = [11, 99, 0, 0]; // verifier tok per position
        let mut gen = draft;
        let mut accepted = 1;
        while accepted < 4 {
            let choice = ar_next[accepted - 1];
            if gen[accepted] == choice {
                accepted += 1;
            } else {
                gen[accepted] = choice;
                accepted += 1;
                break;
            }
        }
        assert_eq!(accepted, 3);
        assert_eq!(gen[..3], [10, 11, 99]);
    }

    #[test]
    fn fully_matching_draft_accepts_whole_block() {
        let draft = [10, 11, 12, 13];
        let ar_next = [11, 12, 13, 7];
        let mut accepted = 1;
        while accepted < 4 {
            if draft[accepted] == ar_next[accepted - 1] {
                accepted += 1;
            } else {
                break;
            }
        }
        assert_eq!(accepted, 4);
    }

}
