//! Paged block KV-cache manager: leased per-lane page tables +
//! ref-counted shared-prefix chains.
//!
//! Exact block-level caching is the paper's second pillar (§4.3): the
//! prompt KV is written at prefill, each completed block's KV is
//! committed once, and nothing is ever recomputed. Block-wise causal
//! attention also makes the prompt KV *position-causal* — the cache for
//! positions `[0, p)` depends only on the tokens at `[0, p)` — which is
//! what makes cross-request reuse legal: two requests whose prompts
//! share a block-aligned token prefix can share the cached KV for it
//! verbatim.
//!
//! Since the paged refactor a lane no longer owns one contiguous
//! `[L, H, S, dh]` slot. The pool's pair of contiguous K/V slabs is
//! carved into three fixed-size page regions:
//!
//! * **prompt pages** — `[L, H, P, dh]` regions holding one private
//!   prompt prefill each, allocated at the lane's first write (lanes
//!   that admit against a shared prefix chain never take one);
//! * **tail pages** — `[L, H, B, dh]` block-granular regions holding
//!   generated-block KV, allocated on demand exactly when a commit
//!   first crosses a block boundary. Decode concurrency is therefore
//!   bounded by *pages touched*, not by a contiguous slot count — an
//!   over-subscribed pool ([`KvPool::with_page_budgets`]) holds more
//!   live lanes than whole-sequence slots would ever fit;
//! * **prefix pages** — block-granular regions indexed by a token-id
//!   trie ([`ChainNode`]) and shared across lanes with refcounts
//!   (unchanged from the shared-prefix refactor): pin on admit, unpin
//!   on retire, leaf-first LRU eviction that never touches a pinned
//!   node.
//!
//! Every lane is owned through an opaque RAII [`KvLease`]: allocation
//! returns the lease, all writes and views require it, and giving it
//! back ([`KvPool::release`]) — or merely dropping it — frees the
//! lane's pages and unpins its chain. Double-free and view-after-free
//! are unrepresentable: there is no second lease to misuse.
//!
//! On top of paging the pool supports **preemption**: at a block
//! boundary [`KvPool::suspend`] consumes a lane's lease, spills its
//! allocated pages into a host-side cold-tier byte arena
//! ([`SuspendedKv`]), and frees the pages for other lanes — keeping
//! the prefix chain pinned so eviction cannot reclaim it under the
//! parked request. [`KvPool::resume`] reallocates pages, copies the
//! bytes back, and returns a fresh lease; decode continues
//! byte-identically because the slab content, segment geometry, and
//! `cache_len` are restored exactly.
//!
//! Engines never copy the cache out: [`KvPool::view`] lends a
//! zero-copy [`KvView`] whose per-lane segment runs stitch shared
//! prefix pages, the prompt page, and the tail pages together; commits
//! append in place per lane. Segment runs are cached per lane, so a
//! view over ≤ [`INLINE_LANES`] lanes allocates nothing. Device
//! backends that need the batch-major layout materialize it behind the
//! seam via `KvView::to_batch_major`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::runtime::{Geometry, KvDims, KvSeg, KvView, INLINE_LANES};
use crate::util::kernels;

/// Pool identity counter backing [`KvLease`]'s foreign-lease guard.
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

/// Owning handle for one allocated lane: the capability every write
/// and view requires. Releasing it ([`KvPool::release`]) frees the
/// lane's pages and unpins its chain immediately; merely dropping it
/// parks the lane on the pool's reaper list, which the next
/// [`KvPool::alloc`] drains — so a leaked lease can delay a free but
/// can never leak pages, and a freed lane can never be written or
/// viewed again (the lease is gone).
#[derive(Debug)]
pub struct KvLease {
    lane: usize,
    pool_id: u64,
    /// Cleared when the pool consumes the lease (release / suspend):
    /// a disarmed drop must not push the lane to the reaper.
    armed: bool,
    reaper: Arc<Mutex<Vec<usize>>>,
}

impl Drop for KvLease {
    fn drop(&mut self) {
        if self.armed {
            if let Ok(mut r) = self.reaper.lock() {
                r.push(self.lane);
            }
        }
    }
}

/// A suspended lane's cold-tier state: little-endian f32 bytes of every
/// allocated page (prompt page first, then tail pages, K before V per
/// page), plus the geometry needed to rebuild the lane exactly. The
/// prefix chain stays **pinned** while parked — [`KvPool::resume`]
/// reattaches it without re-incrementing refs, and a parked request
/// that aborts must hand its state to [`KvPool::discard_suspended`] so
/// the pins drop.
#[derive(Debug)]
pub struct SuspendedKv {
    bytes: Vec<u8>,
    cache_len: usize,
    chain: Vec<usize>,
    needs_prompt_page: bool,
    n_tail: usize,
}

impl SuspendedKv {
    /// Cold-tier footprint in bytes.
    pub fn spilled_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// Valid-prefix length the lane resumes at.
    pub fn cache_len(&self) -> usize {
        self.cache_len
    }
}

/// A pinned prefix chain: the trie path (root-first) whose pages hold
/// one full prompt's KV. Produced by [`KvPool::prefix_acquire_full`] /
/// [`KvPool::prefix_install`] with every node's refcount already
/// incremented; hand it to [`KvPool::attach_chain`] so the owning
/// lease's release unpins it.
#[derive(Debug)]
pub struct ChainPin {
    nodes: Vec<usize>,
    /// First-token proposal cached at full-prompt depth (AR prefill
    /// emits one; DLM prefills leave it empty).
    pub ar_tok: Option<i32>,
}

/// Prefix-sharing granularity for a geometry: the block size when it
/// divides the prompt cleanly, else the whole prompt as one block (no
/// sub-prompt sharing, but the machinery still works).
fn page_len_of(geom: &Geometry) -> usize {
    if geom.block_size > 0 && geom.prompt_len % geom.block_size == 0 {
        geom.block_size
    } else {
        geom.prompt_len.max(1)
    }
}

/// Positions per decode-tail page: the block size (commits are
/// block-granular, so pages fill exactly), or the whole gen region when
/// the geometry has no blocks.
fn tail_len_of(geom: &Geometry) -> usize {
    if geom.block_size > 0 {
        geom.block_size
    } else {
        (geom.seq_len - geom.prompt_len).max(1)
    }
}

/// Stable FNV-1a hash of the longest block-aligned prompt prefix — the
/// replica dispatcher's affinity key. Two prompts that would share a
/// prefix-trie chain (identical up to the last full block) hash alike,
/// so `hash % replicas` steers shared-prompt traffic to the one shard
/// whose trie already holds the warm pages. Tokens past the final block
/// boundary are ignored: they can never be shared (the trie is paged at
/// block granularity), so they must not split warm traffic.
pub fn prefix_affinity_hash(prompt_ids: &[i32], block_size: usize) -> u64 {
    let aligned = if block_size > 0 {
        prompt_ids.len() - prompt_ids.len() % block_size
    } else {
        prompt_ids.len()
    };
    let mut h: u64 = 0xcbf29ce484222325;
    for t in &prompt_ids[..aligned] {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// One block of cached prompt KV in the trie: `tokens` is the block's
/// token ids, `page` its `[L, H, B, dh]` region, `refs` the number of
/// live lanes pinning it.
#[derive(Debug)]
struct ChainNode {
    tag: u64,
    tokens: Vec<i32>,
    parent: Option<usize>,
    children: Vec<usize>,
    page: usize,
    refs: usize,
    tick: u64,
    ar_tok: Option<i32>,
}

/// Paged slab pool: leased lanes over on-demand prompt/tail pages plus
/// the shared-prefix page store and its trie index.
pub struct KvPool {
    dims: KvDims,
    prompt_len: usize,
    /// Positions per prefix page (the prefix-sharing granularity).
    page_len: usize,
    /// Prefix pages covering one full prompt.
    prompt_pages: usize,
    /// Positions per decode-tail page.
    tail_len: usize,
    /// Tail pages covering one full gen region.
    tail_pages_full: usize,
    k: Vec<f32>, // [prompt pages | tail pages | prefix pages]
    v: Vec<f32>,
    // ---- lanes (leased, one owner each)
    cache_lens: Vec<usize>,
    lane_used: Vec<bool>,
    lane_free: Vec<usize>,
    /// Per-lane attached chain (trie node path); empty = no shared
    /// prefix.
    chains: Vec<Vec<usize>>,
    /// Per-lane private prompt page (chained lanes never hold one).
    prompt_page_of: Vec<Option<usize>>,
    /// Per-lane tail pages in position order.
    tail_pages_of: Vec<Vec<usize>>,
    /// Cached per-lane segment runs, kept exactly in sync with the
    /// page tables above so views allocate nothing.
    seg_runs: Vec<Vec<KvSeg>>,
    /// Dropped-but-unreleased leases, reaped at the next alloc.
    reaper: Arc<Mutex<Vec<usize>>>,
    pool_id: u64,
    // ---- page free lists
    prompt_page_elems: usize,
    tail_page_elems: usize,
    prompt_free: Vec<usize>,
    tail_free: Vec<usize>,
    prompt_budget: usize,
    tail_budget: usize,
    // ---- prefix pages (shared, ref-counted)
    page_elems: usize,
    /// Element offset where the prefix-page region starts in the slabs.
    page_region: usize,
    /// Element offset where the tail-page region starts in the slabs.
    tail_region: usize,
    page_used: Vec<bool>,
    page_free: Vec<usize>,
    // ---- trie
    nodes: Vec<Option<ChainNode>>,
    node_free: Vec<usize>,
    roots: HashMap<u64, Vec<usize>>,
    lru_tick: u64,
    // ---- counters
    pub peak_in_use: usize,
    /// Lifetime alloc count. With mid-batch lane recycling (continuous
    /// batching retires a lane and hands it to the next admission) this
    /// exceeds `capacity` on a busy pool — aggregated across pools as
    /// `kv_total_allocs` on `/healthz`, an admission-churn signal.
    pub total_allocs: u64,
    /// Full-prompt chain hits: admissions that skipped prefill
    /// entirely.
    pub prefix_hits: u64,
    /// Block-granular reuse: cached blocks found at admission,
    /// including partial (copy-on-write) matches.
    pub prefix_hit_blocks: u64,
    /// Chain blocks reclaimed by the LRU evictor under page pressure.
    pub prefix_evictions: u64,
    /// Lanes suspended to the cold tier ([`KvPool::suspend`]).
    pub preempts: u64,
    /// Lanes brought back from the cold tier ([`KvPool::resume`]).
    pub resumes: u64,
    /// Lifetime bytes spilled to the cold tier.
    pub spilled_bytes: u64,
    /// Armed by [`KvPool::inject_alloc_failures`] (fault injection):
    /// while nonzero, `alloc` fails and decrements it. Zero in
    /// production — only a `FaultPlan` ever arms it.
    forced_alloc_failures: u64,
}

impl KvPool {
    /// A fully provisioned pool with `capacity` lanes and **no**
    /// prefix pages: the layout every closed-batch path uses (those
    /// engines always prefill into private pages, keeping the
    /// trace-pinned baseline accounting cold by construction). Fully
    /// provisioned means every lane can hold its whole sequence, so
    /// on-demand page allocation can never fail on these paths. The
    /// block-step machine builds its pool with
    /// [`KvPool::with_prefix_pages`] instead; the preempt bench
    /// over-subscribes with [`KvPool::with_page_budgets`].
    pub fn new(geom: &Geometry, capacity: usize) -> Self {
        Self::with_prefix_pages(geom, capacity, 0)
    }

    /// The machine's default prefix-page budget for a pool of
    /// `capacity` lanes: two prompts' worth of pages per lane — a full
    /// complement of live chains plus as much again retained as warm
    /// cache before the LRU evictor starts reclaiming.
    pub fn default_page_budget(geom: &Geometry, capacity: usize) -> usize {
        2 * capacity * (geom.prompt_len / page_len_of(geom))
    }

    /// A fully provisioned pool with an explicit prefix-page budget
    /// (tests exercise eviction pressure through this constructor).
    pub fn with_prefix_pages(
        geom: &Geometry,
        capacity: usize,
        page_capacity: usize,
    ) -> Self {
        let tail_pages_full = (geom.seq_len - geom.prompt_len)
            .max(1)
            .div_ceil(tail_len_of(geom));
        Self::with_page_budgets(
            geom,
            capacity,
            capacity,
            capacity * tail_pages_full,
            page_capacity,
        )
    }

    /// A pool with explicit lane/page budgets. `prompt_budget` and
    /// `tail_budget` may **under-provision** `lanes` (fewer pages than
    /// every lane's full sequence needs): writes then fail with a typed
    /// error when the free lists run dry, and the caller is expected to
    /// suspend lanes to make progress — the preempt bench and
    /// preemption tests build their pressure cookers through this
    /// constructor.
    pub fn with_page_budgets(
        geom: &Geometry,
        lanes: usize,
        prompt_budget: usize,
        tail_budget: usize,
        page_capacity: usize,
    ) -> Self {
        let dims = KvDims::of(geom);
        let page_len = page_len_of(geom);
        let prompt_pages = geom.prompt_len / page_len;
        let tail_len = tail_len_of(geom);
        let tail_pages_full =
            (geom.seq_len - geom.prompt_len).max(1).div_ceil(tail_len);
        let row = dims.n_layers * dims.n_heads * dims.d_head;
        let prompt_page_elems = row * geom.prompt_len;
        let tail_page_elems = row * tail_len;
        let page_elems = row * page_len;
        let tail_region = prompt_budget * prompt_page_elems;
        let page_region = tail_region + tail_budget * tail_page_elems;
        let total = page_region + page_capacity * page_elems;
        Self {
            dims,
            prompt_len: geom.prompt_len,
            page_len,
            prompt_pages,
            tail_len,
            tail_pages_full,
            k: vec![0.0; total],
            v: vec![0.0; total],
            cache_lens: vec![0; lanes],
            lane_used: vec![false; lanes],
            lane_free: (0..lanes).rev().collect(),
            chains: (0..lanes).map(|_| Vec::new()).collect(),
            prompt_page_of: vec![None; lanes],
            tail_pages_of: (0..lanes).map(|_| Vec::new()).collect(),
            seg_runs: (0..lanes).map(|_| Vec::new()).collect(),
            reaper: Arc::new(Mutex::new(Vec::new())),
            pool_id: NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed),
            prompt_page_elems,
            tail_page_elems,
            prompt_free: (0..prompt_budget).rev().collect(),
            tail_free: (0..tail_budget).rev().collect(),
            prompt_budget,
            tail_budget,
            page_elems,
            page_region,
            tail_region,
            page_used: vec![false; page_capacity],
            page_free: (0..page_capacity).rev().collect(),
            nodes: Vec::new(),
            node_free: Vec::new(),
            roots: HashMap::new(),
            lru_tick: 0,
            peak_in_use: 0,
            total_allocs: 0,
            prefix_hits: 0,
            prefix_hit_blocks: 0,
            prefix_evictions: 0,
            preempts: 0,
            resumes: 0,
            spilled_bytes: 0,
            forced_alloc_failures: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.lane_used.len()
    }

    pub fn in_use(&self) -> usize {
        self.lane_used.len() - self.lane_free.len()
    }

    /// Full per-lane KV footprint (prompt page + a whole gen region of
    /// tail pages, K and V).
    pub fn bytes_per_lane(&self) -> usize {
        2 * (self.prompt_page_elems
            + self.tail_pages_full * self.tail_page_elems)
            * std::mem::size_of::<f32>()
    }

    /// Positions per prefix page (the block-aligned sharing
    /// granularity).
    pub fn page_len(&self) -> usize {
        self.page_len
    }

    /// Pages that make up one full prompt chain.
    pub fn prompt_pages(&self) -> usize {
        self.prompt_pages
    }

    /// Positions per decode-tail page.
    pub fn tail_len(&self) -> usize {
        self.tail_len
    }

    /// Tail pages covering one full gen region.
    pub fn tail_pages_full(&self) -> usize {
        self.tail_pages_full
    }

    pub fn prompt_page_budget(&self) -> usize {
        self.prompt_budget
    }

    pub fn tail_page_budget(&self) -> usize {
        self.tail_budget
    }

    /// Tail pages currently on the free list (the preemption
    /// watermark signal).
    pub fn tail_pages_free(&self) -> usize {
        self.tail_free.len()
    }

    pub fn prompt_pages_free(&self) -> usize {
        self.prompt_free.len()
    }

    /// Prefix pages currently resident (pinned or retained) — surfaced
    /// as `kv_shared_slots` on `/healthz`.
    pub fn prefix_resident_pages(&self) -> usize {
        self.page_used.len() - self.page_free.len()
    }

    pub fn prefix_page_capacity(&self) -> usize {
        self.page_used.len()
    }

    /// Fault injection: fail the next `n` allocations with a typed
    /// error, as if the pool were exhausted. Exercises the admission
    /// failure path (`Aborted{"admission failed: ..."}`) without
    /// needing a genuinely full pool.
    pub fn inject_alloc_failures(&mut self, n: u64) {
        self.forced_alloc_failures += n;
    }

    #[inline]
    fn check(&self, lease: &KvLease) {
        assert_eq!(
            lease.pool_id, self.pool_id,
            "foreign KvLease: lease belongs to another pool"
        );
        debug_assert!(self.lane_used[lease.lane], "lease names a free lane");
    }

    fn make_lease(&self, lane: usize) -> KvLease {
        KvLease {
            lane,
            pool_id: self.pool_id,
            armed: true,
            reaper: Arc::clone(&self.reaper),
        }
    }

    /// Free lanes whose leases were dropped without an explicit
    /// [`KvPool::release`]. Normal paths release explicitly; the
    /// reaper is the safety net that turns a leaked lease into a
    /// delayed free instead of a leaked lane.
    fn reap_dropped(&mut self) {
        let reaper = Arc::clone(&self.reaper);
        let mut dropped = reaper.lock().expect("reaper lock");
        for lane in dropped.drain(..) {
            if self.lane_used[lane] {
                self.free_lane(lane);
            }
        }
    }

    pub fn alloc(&mut self) -> Result<KvLease> {
        self.reap_dropped();
        if self.forced_alloc_failures > 0 {
            self.forced_alloc_failures -= 1;
            anyhow::bail!("KV allocation failed (injected fault)");
        }
        let lane = self
            .lane_free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("KV pool exhausted"))?;
        debug_assert!(!self.lane_used[lane]);
        debug_assert!(self.chains[lane].is_empty(), "freed lane kept a chain");
        debug_assert!(self.prompt_page_of[lane].is_none());
        debug_assert!(self.tail_pages_of[lane].is_empty());
        self.lane_used[lane] = true;
        self.cache_lens[lane] = 0;
        self.seg_runs[lane].clear();
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        self.total_allocs += 1;
        Ok(self.make_lease(lane))
    }

    /// Give a lane back: pages return to their free lists and an
    /// attached prefix chain's refcounts drop by one (the chain's
    /// pages stay resident as warm cache until the LRU evictor needs
    /// them). Consuming the lease is what makes double-free
    /// unrepresentable.
    pub fn release(&mut self, mut lease: KvLease) {
        self.check(&lease);
        lease.armed = false;
        let lane = lease.lane;
        drop(lease);
        self.free_lane(lane);
    }

    fn free_lane(&mut self, lane: usize) {
        debug_assert!(self.lane_used[lane], "free of a free lane");
        // unpin the chain in place (no Vec is dropped: lane state keeps
        // its capacity across recycles, for the allocation-free hotpath)
        for i in 0..self.chains[lane].len() {
            let n = self.chains[lane][i];
            let node = self.nodes[n].as_mut().expect("chain node resident");
            debug_assert!(node.refs > 0, "unpin of an unpinned chain node");
            node.refs -= 1;
        }
        self.chains[lane].clear();
        if let Some(pg) = self.prompt_page_of[lane].take() {
            self.prompt_free.push(pg);
        }
        while let Some(pg) = self.tail_pages_of[lane].pop() {
            self.tail_free.push(pg);
        }
        self.seg_runs[lane].clear();
        self.cache_lens[lane] = 0;
        self.lane_used[lane] = false;
        // zeroing is unnecessary for correctness (cache_len gates reads)
        self.lane_free.push(lane);
    }

    pub fn cache_len_of(&self, lease: &KvLease) -> usize {
        self.check(lease);
        self.cache_lens[lease.lane]
    }

    #[inline]
    fn prompt_base(&self, page: usize) -> usize {
        page * self.prompt_page_elems
    }

    #[inline]
    fn tail_base(&self, page: usize) -> usize {
        self.tail_region + page * self.tail_page_elems
    }

    #[inline]
    fn page_base(&self, page: usize) -> usize {
        self.page_region + page * self.page_elems
    }

    /// Positions the lane's allocated pages cover (contiguous from 0).
    #[inline]
    fn covered(&self, lane: usize) -> usize {
        self.seg_runs[lane].last().map(|s| s.start + s.len).unwrap_or(0)
    }

    /// Allocate pages on demand until the lane covers `[0, upto)`.
    /// Partial progress is kept on failure (the lane stays consistent;
    /// its pages free at release), so a failed write is safe to retry
    /// after a suspend frees pages.
    fn ensure_coverage(&mut self, lane: usize, upto: usize) -> Result<()> {
        debug_assert!(upto <= self.dims.seq_len, "coverage beyond sequence");
        if self.chains[lane].is_empty()
            && self.prompt_page_of[lane].is_none()
            && upto > 0
        {
            let Some(pg) = self.prompt_free.pop() else {
                anyhow::bail!(
                    "KV pool out of prompt pages ({} budgeted)",
                    self.prompt_budget
                );
            };
            self.prompt_page_of[lane] = Some(pg);
            debug_assert!(self.seg_runs[lane].is_empty());
            self.seg_runs[lane].push(KvSeg {
                start: 0,
                len: self.prompt_len,
                base: self.prompt_base(pg),
                region_len: self.prompt_len,
                offset: 0,
            });
        }
        while self.covered(lane) < upto {
            let Some(pg) = self.tail_free.pop() else {
                anyhow::bail!(
                    "KV pool out of tail pages ({} budgeted)",
                    self.tail_budget
                );
            };
            let start =
                self.prompt_len + self.tail_pages_of[lane].len() * self.tail_len;
            let len = self.tail_len.min(self.dims.seq_len - start);
            self.tail_pages_of[lane].push(pg);
            self.seg_runs[lane].push(KvSeg {
                start,
                len,
                base: self.tail_base(pg),
                region_len: self.tail_len,
                offset: 0,
            });
        }
        Ok(())
    }

    /// Scatter a batch-major `[L, bs, H, span_len, dh]` source span
    /// covering absolute positions `[first_pos, first_pos + span_len)`
    /// of `src_lane` into the lane's pages. Each overlapping segment
    /// takes one contiguous `run * dh` copy per (layer, head).
    fn write_span(
        &mut self,
        lane: usize,
        src_lane: usize,
        bs: usize,
        first_pos: usize,
        span_len: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let g = self.dims;
        let (l_n, h_n, d) = (g.n_layers, g.n_heads, g.d_head);
        debug_assert!(
            k.len() >= l_n * bs * h_n * span_len * d
                && v.len() >= l_n * bs * h_n * span_len * d,
            "KV source must be [L, bs={bs}, H, {span_len}, dh]"
        );
        let end = first_pos + span_len;
        for si in 0..self.seg_runs[lane].len() {
            let seg = self.seg_runs[lane][si];
            let s0 = seg.start.max(first_pos);
            let s1 = (seg.start + seg.len).min(end);
            if s0 >= s1 {
                continue;
            }
            // head rows have uniform strides on both sides within a
            // layer: one 2-D SIMD kernel copy per (layer, slab)
            let run = (s1 - s0) * d;
            let src_stride = span_len * d;
            let dst_stride = seg.region_len * d;
            for l in 0..l_n {
                let src = ((l * bs + src_lane) * h_n * span_len
                    + (s0 - first_pos))
                    * d;
                let dst = seg.base
                    + (l * h_n * seg.region_len
                        + seg.offset
                        + (s0 - seg.start))
                        * d;
                kernels::copy_2d(
                    &mut self.k,
                    dst,
                    dst_stride,
                    k,
                    src,
                    src_stride,
                    h_n,
                    run,
                );
                kernels::copy_2d(
                    &mut self.v,
                    dst,
                    dst_stride,
                    v,
                    src,
                    src_stride,
                    h_n,
                    run,
                );
            }
        }
    }

    /// Install prefill output for one lane. `k`/`v` are batch-major
    /// [L, bs, H, P, dh] slices from the prefill program; the prompt
    /// page is allocated on demand and is the only region written.
    pub fn write_prefill(
        &mut self,
        lease: &KvLease,
        src_lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        self.check(lease);
        let lane = lease.lane;
        debug_assert!(
            self.chains[lane].is_empty(),
            "write_prefill into a chained lane"
        );
        let p = self.prompt_len;
        let g = self.dims;
        assert_eq!(
            k.len(),
            g.n_layers * bs * g.n_heads * p * g.d_head,
            "prefill KV must be [L, bs={bs}, H, P={p}, dh]"
        );
        self.ensure_coverage(lane, p)?;
        self.write_span(lane, src_lane, bs, 0, p, k, v);
        self.cache_lens[lane] = p;
        Ok(())
    }

    /// Commit a finalized block's KV for one lane. `k_blk`/`v_blk` are
    /// [L, bs, H, B, dh]; the block appends in place at the lane's
    /// current cache_len, which advances by `blk` (exact append-only
    /// caching). A tail page is allocated exactly when the commit
    /// crosses into uncovered positions; under page pressure that
    /// allocation fails with a typed error and the caller may suspend
    /// a lane and retry.
    pub fn commit_block(
        &mut self,
        lease: &KvLease,
        src_lane: usize,
        bs: usize,
        blk: usize,
        k_blk: &[f32],
        v_blk: &[f32],
    ) -> Result<()> {
        self.check(lease);
        let lane = lease.lane;
        let pos = self.cache_lens[lane];
        let s_n = self.dims.seq_len;
        assert!(pos + blk <= s_n, "cache overflow: {pos} + {blk} > {s_n}");
        debug_assert!(
            self.chains[lane].is_empty() || pos >= self.prompt_len,
            "commit into the shared prefix of a chained lane"
        );
        self.ensure_coverage(lane, pos + blk)?;
        self.write_span(lane, src_lane, bs, pos, blk, k_blk, v_blk);
        self.cache_lens[lane] = pos + blk;
        Ok(())
    }

    /// Direct write of full-sequence KV (approximate-cache baselines):
    /// overwrite the lane's pages with the stale full-sequence stacks
    /// [L, bs, H, S, dh] and mark the whole sequence resident.
    pub fn write_full(
        &mut self,
        lease: &KvLease,
        src_lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        self.check(lease);
        let lane = lease.lane;
        debug_assert!(
            self.chains[lane].is_empty(),
            "write_full into a chained lane"
        );
        let s_n = self.dims.seq_len;
        self.ensure_coverage(lane, s_n)?;
        self.write_span(lane, src_lane, bs, 0, s_n, k, v);
        self.cache_lens[lane] = s_n;
        Ok(())
    }

    /// Borrow a zero-copy view of the leased lanes' caches. No cache
    /// data moves: each lane is its cached segment run over the slabs —
    /// pinned prefix pages (if a chain is attached), the private prompt
    /// page, then tail pages. The lockstep valid-prefix length is the
    /// lanes' shared `cache_len` (debug-asserted equal). Batches up to
    /// [`INLINE_LANES`] lanes build the view with **zero** heap
    /// allocations.
    pub fn view(&self, leases: &[&KvLease]) -> KvView<'_> {
        self.view_padded(leases, leases.len())
    }

    /// [`KvPool::view`] widened to `pad_to` lanes: rows past the real
    /// lanes alias the last real lane's segments (programs are compiled
    /// per bucket width; padded rows are never read back).
    pub fn view_padded(&self, leases: &[&KvLease], pad_to: usize) -> KvView<'_> {
        assert!(!leases.is_empty(), "view of an empty cohort");
        debug_assert!(pad_to >= leases.len(), "pad narrower than cohort");
        let cache_len = self.cache_lens[leases[0].lane];
        #[cfg(debug_assertions)]
        for l in leases {
            self.check(l);
            debug_assert_eq!(
                self.cache_lens[l.lane], cache_len,
                "cohort lanes out of lockstep"
            );
        }
        if pad_to <= INLINE_LANES {
            let mut segs: [&[KvSeg]; INLINE_LANES] = [&[]; INLINE_LANES];
            for (r, slot) in segs.iter_mut().enumerate().take(pad_to) {
                let lane = leases[r.min(leases.len() - 1)].lane;
                *slot = &self.seg_runs[lane];
            }
            return KvView::inline(
                &self.k,
                &self.v,
                &segs[..pad_to],
                self.dims,
                cache_len,
            );
        }
        let lanes: Vec<Vec<KvSeg>> = (0..pad_to)
            .map(|r| self.seg_runs[leases[r.min(leases.len() - 1)].lane].clone())
            .collect();
        KvView::segmented(&self.k, &self.v, lanes, self.dims, cache_len)
    }

    // -----------------------------------------------------------------
    // Preemption: suspend / resume through the cold tier
    // -----------------------------------------------------------------

    fn spill_region(out: &mut Vec<u8>, slab: &[f32], base: usize, n: usize) {
        // widening scatter to the cold tier: one bulk byte move
        kernels::spill_f32_le(out, &slab[base..base + n]);
    }

    fn unspill_region(
        bytes: &[u8],
        cursor: &mut usize,
        slab: &mut [f32],
        base: usize,
        n: usize,
    ) {
        // widening gather from the cold tier: one bulk byte move
        kernels::unspill_f32_le(
            &bytes[*cursor..*cursor + 4 * n],
            &mut slab[base..base + n],
        );
        *cursor += 4 * n;
    }

    /// Suspend a lane: consume its lease, spill every allocated page
    /// to a cold-tier byte arena, and free the lane + pages for other
    /// requests. The prefix chain is carried in the suspended state
    /// **still pinned** — parking must not let the evictor reclaim the
    /// prompt KV the lane will resume against.
    pub fn suspend(&mut self, mut lease: KvLease) -> SuspendedKv {
        self.check(&lease);
        lease.armed = false;
        let lane = lease.lane;
        drop(lease);
        let cache_len = self.cache_lens[lane];
        let chain = std::mem::take(&mut self.chains[lane]);
        let needs_prompt_page = self.prompt_page_of[lane].is_some();
        let mut bytes = Vec::new();
        if let Some(pg) = self.prompt_page_of[lane].take() {
            let b = self.prompt_base(pg);
            Self::spill_region(&mut bytes, &self.k, b, self.prompt_page_elems);
            Self::spill_region(&mut bytes, &self.v, b, self.prompt_page_elems);
            self.prompt_free.push(pg);
        }
        let n_tail = self.tail_pages_of[lane].len();
        for i in 0..n_tail {
            let b = self.tail_base(self.tail_pages_of[lane][i]);
            Self::spill_region(&mut bytes, &self.k, b, self.tail_page_elems);
            Self::spill_region(&mut bytes, &self.v, b, self.tail_page_elems);
        }
        while let Some(pg) = self.tail_pages_of[lane].pop() {
            self.tail_free.push(pg);
        }
        self.seg_runs[lane].clear();
        self.cache_lens[lane] = 0;
        self.lane_used[lane] = false;
        self.lane_free.push(lane);
        self.preempts += 1;
        self.spilled_bytes += bytes.len() as u64;
        SuspendedKv { bytes, cache_len, chain, needs_prompt_page, n_tail }
    }

    /// Whether [`KvPool::resume`] would succeed right now: a free lane
    /// plus enough free pages to rebuild the suspended lane exactly.
    pub fn can_resume(&self, s: &SuspendedKv) -> bool {
        !self.lane_free.is_empty()
            && (!s.needs_prompt_page || !self.prompt_free.is_empty())
            && self.tail_free.len() >= s.n_tail
    }

    /// Bring a suspended lane back: reallocate its pages, copy the
    /// cold-tier bytes into them, rebuild the segment run, and
    /// reattach the still-pinned chain (no refcount change). The
    /// restored lane is byte-identical to the suspended one, so decode
    /// continues exactly where it stopped. Check-then-commit: under
    /// pressure the state is handed back untouched for a later retry.
    pub fn resume(&mut self, s: SuspendedKv) -> Result<KvLease, SuspendedKv> {
        self.reap_dropped();
        if !self.can_resume(&s) {
            return Err(s);
        }
        let lane = self.lane_free.pop().expect("can_resume checked a lane");
        self.lane_used[lane] = true;
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        self.seg_runs[lane].clear();
        for (i, &n) in s.chain.iter().enumerate() {
            let page =
                self.nodes[n].as_ref().expect("chain node resident").page;
            self.seg_runs[lane].push(KvSeg {
                start: i * self.page_len,
                len: self.page_len,
                base: self.page_base(page),
                region_len: self.page_len,
                offset: 0,
            });
        }
        self.chains[lane] = s.chain;
        let mut cursor = 0usize;
        if s.needs_prompt_page {
            let pg = self.prompt_free.pop().expect("can_resume checked");
            self.prompt_page_of[lane] = Some(pg);
            let b = self.prompt_base(pg);
            Self::unspill_region(
                &s.bytes,
                &mut cursor,
                &mut self.k,
                b,
                self.prompt_page_elems,
            );
            Self::unspill_region(
                &s.bytes,
                &mut cursor,
                &mut self.v,
                b,
                self.prompt_page_elems,
            );
            self.seg_runs[lane].push(KvSeg {
                start: 0,
                len: self.prompt_len,
                base: b,
                region_len: self.prompt_len,
                offset: 0,
            });
        }
        for t in 0..s.n_tail {
            let pg = self.tail_free.pop().expect("can_resume checked");
            let b = self.tail_base(pg);
            Self::unspill_region(
                &s.bytes,
                &mut cursor,
                &mut self.k,
                b,
                self.tail_page_elems,
            );
            Self::unspill_region(
                &s.bytes,
                &mut cursor,
                &mut self.v,
                b,
                self.tail_page_elems,
            );
            let start = self.prompt_len + t * self.tail_len;
            self.tail_pages_of[lane].push(pg);
            self.seg_runs[lane].push(KvSeg {
                start,
                len: self.tail_len.min(self.dims.seq_len - start),
                base: b,
                region_len: self.tail_len,
                offset: 0,
            });
        }
        debug_assert_eq!(cursor, s.bytes.len(), "cold-tier size mismatch");
        self.cache_lens[lane] = s.cache_len;
        self.resumes += 1;
        Ok(self.make_lease(lane))
    }

    /// Abandon a suspended lane without resuming it (the parked
    /// request was cancelled or timed out): drop the chain pins the
    /// suspension carried.
    pub fn discard_suspended(&mut self, s: SuspendedKv) {
        for &n in &s.chain {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            debug_assert!(node.refs > 0, "discard of an unpinned chain node");
            node.refs -= 1;
        }
    }

    /// Leak check: with no live lanes, every page must be back on its
    /// free list, every lane's page table empty, and every resident
    /// prefix chain unpinned. Tests call this after churn (admission
    /// failures, aborts between admit and first commit, preempt/resume
    /// cycles) to prove nothing leaked.
    pub fn assert_no_leaks(&self) {
        assert_eq!(self.in_use(), 0, "live lanes at leak check");
        for lane in 0..self.lane_used.len() {
            assert!(self.chains[lane].is_empty(), "lane {lane} kept a chain");
            assert!(
                self.prompt_page_of[lane].is_none(),
                "lane {lane} kept a prompt page"
            );
            assert!(
                self.tail_pages_of[lane].is_empty(),
                "lane {lane} kept tail pages"
            );
        }
        assert_eq!(
            self.prompt_free.len(),
            self.prompt_budget,
            "prompt pages leaked"
        );
        assert_eq!(self.tail_free.len(), self.tail_budget, "tail pages leaked");
        for node in self.nodes.iter().flatten() {
            assert_eq!(node.refs, 0, "pinned chain node at leak check");
        }
    }

    // -----------------------------------------------------------------
    // Shared-prefix chains
    // -----------------------------------------------------------------

    /// Walk the trie for `prompt` under `tag` and return the resident
    /// node path for its longest block-aligned prefix (no pinning).
    fn match_prefix(&self, tag: u64, prompt: &[i32]) -> Vec<usize> {
        let mut path = Vec::new();
        let mut kids: &[usize] =
            self.roots.get(&tag).map(Vec::as_slice).unwrap_or(&[]);
        for blk in prompt.chunks(self.page_len) {
            let found = kids.iter().copied().find(|&n| {
                self.nodes[n]
                    .as_ref()
                    .expect("indexed chain node resident")
                    .tokens
                    == blk
            });
            let Some(next) = found else { break };
            path.push(next);
            kids = &self.nodes[next]
                .as_ref()
                .expect("indexed chain node resident")
                .children;
        }
        path
    }

    /// Pin the full-prompt chain for `prompt` if every block is
    /// resident: the warm-hit path that lets admission skip prefill
    /// entirely. With `need_ar_tok`, a chain lacking a cached
    /// first-token proposal reports as a miss (nothing is pinned).
    pub fn prefix_acquire_full(
        &mut self,
        tag: u64,
        prompt: &[i32],
        need_ar_tok: bool,
    ) -> Option<ChainPin> {
        debug_assert_eq!(prompt.len(), self.prompt_len);
        let path = self.match_prefix(tag, prompt);
        if path.len() < self.prompt_pages {
            return None;
        }
        let leaf = *path.last().expect("prompt has at least one block");
        let ar_tok =
            self.nodes[leaf].as_ref().expect("chain node resident").ar_tok;
        if need_ar_tok && ar_tok.is_none() {
            return None;
        }
        self.lru_tick += 1;
        let tick = self.lru_tick;
        for &n in &path {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            node.refs += 1;
            node.tick = tick;
        }
        self.prefix_hits += 1;
        self.prefix_hit_blocks += path.len() as u64;
        Some(ChainPin { nodes: path, ar_tok })
    }

    /// Install (and pin) the full-prompt chain for `prompt` from a
    /// prefill output: resident blocks are reused (copy-on-write — the
    /// trie branches at the first divergent block and nothing shared is
    /// overwritten), missing blocks get fresh pages written from the
    /// batch-major `[L, bs, H, P, dh]` prefill K/V. Fails without side
    /// effects when the page budget cannot cover the uncached tail even
    /// after LRU eviction; callers then fall back to a private-page
    /// prefill.
    #[allow(clippy::too_many_arguments)]
    pub fn prefix_install(
        &mut self,
        tag: u64,
        prompt: &[i32],
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
        ar_tok: Option<i32>,
    ) -> Result<ChainPin> {
        debug_assert_eq!(prompt.len(), self.prompt_len);
        let matched = self.match_prefix(tag, prompt);
        // pin the matched prefix first so eviction (below) can't
        // reclaim it while we make room for the tail
        self.lru_tick += 1;
        let tick = self.lru_tick;
        for &n in &matched {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            node.refs += 1;
            node.tick = tick;
        }
        let needed = self.prompt_pages - matched.len();
        if !self.ensure_pages(needed) {
            for &n in &matched {
                let node =
                    self.nodes[n].as_mut().expect("chain node resident");
                node.refs -= 1;
            }
            anyhow::bail!(
                "prefix cache full: {needed} pages unavailable \
                 (all resident chains pinned)"
            );
        }
        self.prefix_hit_blocks += matched.len() as u64;
        let mut path = matched;
        for bi in path.len()..self.prompt_pages {
            let page = self
                .page_free
                .pop()
                .expect("ensure_pages reserved the tail");
            debug_assert!(!self.page_used[page]);
            self.page_used[page] = true;
            self.write_page(page, lane, bs, bi, k, v);
            let tokens =
                prompt[bi * self.page_len..(bi + 1) * self.page_len].to_vec();
            let node = ChainNode {
                tag,
                tokens,
                parent: path.last().copied(),
                children: Vec::new(),
                page,
                refs: 1,
                tick,
                ar_tok: None,
            };
            let idx = match self.node_free.pop() {
                Some(i) => {
                    self.nodes[i] = Some(node);
                    i
                }
                None => {
                    self.nodes.push(Some(node));
                    self.nodes.len() - 1
                }
            };
            match path.last() {
                Some(&p) => self.nodes[p]
                    .as_mut()
                    .expect("chain node resident")
                    .children
                    .push(idx),
                None => self.roots.entry(tag).or_default().push(idx),
            }
            path.push(idx);
        }
        let leaf = *path.last().expect("prompt has at least one block");
        if ar_tok.is_some() {
            self.nodes[leaf]
                .as_mut()
                .expect("chain node resident")
                .ar_tok = ar_tok;
        }
        let ar_tok =
            self.nodes[leaf].as_ref().expect("chain node resident").ar_tok;
        Ok(ChainPin { nodes: path, ar_tok })
    }

    /// Attach a pinned chain to a leased lane: the lane now reads its
    /// prompt positions from the shared pages (it never takes a private
    /// prompt page) and releasing the lease will unpin the chain when
    /// the lane retires.
    pub fn attach_chain(&mut self, lease: &KvLease, pin: ChainPin) {
        self.check(lease);
        let lane = lease.lane;
        assert!(self.chains[lane].is_empty(), "lane already has a chain");
        assert!(
            self.prompt_page_of[lane].is_none()
                && self.tail_pages_of[lane].is_empty(),
            "attach_chain to a lane that already wrote pages"
        );
        self.seg_runs[lane].clear();
        for (i, &n) in pin.nodes.iter().enumerate() {
            let page =
                self.nodes[n].as_ref().expect("chain node resident").page;
            self.seg_runs[lane].push(KvSeg {
                start: i * self.page_len,
                len: self.page_len,
                base: self.page_base(page),
                region_len: self.page_len,
                offset: 0,
            });
        }
        self.chains[lane] = pin.nodes;
        self.cache_lens[lane] = self.prompt_len;
    }

    /// Release a pin without attaching it to a lane (admission error
    /// paths).
    pub fn release_pin(&mut self, pin: ChainPin) {
        for n in pin.nodes {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            debug_assert!(node.refs > 0, "release of an unpinned chain node");
            node.refs -= 1;
        }
    }

    /// Diagnostic/test accessor: `(resident blocks, min refcount along
    /// the resident path)` for a prompt's longest cached prefix.
    pub fn prefix_chain_info(
        &self,
        tag: u64,
        prompt: &[i32],
    ) -> Option<(usize, usize)> {
        let path = self.match_prefix(tag, prompt);
        if path.is_empty() {
            return None;
        }
        let min_refs = path
            .iter()
            .map(|&n| {
                self.nodes[n].as_ref().expect("chain node resident").refs
            })
            .min()
            .expect("non-empty path");
        Some((path.len(), min_refs))
    }

    /// Make at least `needed` pages available on the free list,
    /// evicting LRU unpinned chain leaves if necessary. Returns false
    /// (with eviction partially done — evicted chains were reclaimable
    /// by definition) when pressure cannot be relieved.
    fn ensure_pages(&mut self, needed: usize) -> bool {
        while self.page_free.len() < needed {
            if !self.evict_one() {
                return false;
            }
        }
        true
    }

    /// Evict the least-recently-used unpinned chain leaf. Interior
    /// nodes become leaves once their children go, so repeated calls
    /// reclaim whole chains back-to-front; pinned nodes (refs > 0) are
    /// never candidates.
    fn evict_one(&mut self) -> bool {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
            .filter(|(_, n)| n.refs == 0 && n.children.is_empty())
            .min_by_key(|(_, n)| n.tick)
            .map(|(i, _)| i);
        let Some(i) = victim else { return false };
        let node = self.nodes[i].take().expect("victim resident");
        match node.parent {
            Some(p) => {
                let kids = &mut self.nodes[p]
                    .as_mut()
                    .expect("parent of resident node resident")
                    .children;
                kids.retain(|&c| c != i);
            }
            None => {
                if let Some(kids) = self.roots.get_mut(&node.tag) {
                    kids.retain(|&c| c != i);
                }
            }
        }
        assert!(self.page_used[node.page], "double free of KV page");
        self.page_used[node.page] = false;
        self.page_free.push(node.page);
        self.node_free.push(i);
        self.prefix_evictions += 1;
        true
    }

    /// Write prompt block `bi` of one lane's batch-major
    /// `[L, bs, H, P, dh]` prefill output into a prefix page.
    fn write_page(
        &mut self,
        page: usize,
        lane: usize,
        bs: usize,
        bi: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let g = self.dims;
        let (l_n, h_n, d) = (g.n_layers, g.n_heads, g.d_head);
        let p = self.prompt_len;
        let pl = self.page_len;
        debug_assert_eq!(
            k.len(),
            l_n * bs * h_n * p * d,
            "prefill KV must be [L, bs={bs}, H, P={p}, dh]"
        );
        let base = self.page_base(page);
        // head rows stride p*d in the source and pl*d in the page: one
        // 2-D SIMD kernel copy per (layer, slab)
        for l in 0..l_n {
            let src = ((l * bs + lane) * h_n * p + bi * pl) * d;
            let dst = base + l * h_n * pl * d;
            kernels::copy_2d(
                &mut self.k,
                dst,
                pl * d,
                k,
                src,
                p * d,
                h_n,
                pl * d,
            );
            kernels::copy_2d(
                &mut self.v,
                dst,
                pl * d,
                v,
                src,
                p * d,
                h_n,
                pl * d,
            );
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn affinity_hash_is_block_aligned_and_stable() {
        let a = [1, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(
            prefix_affinity_hash(&a, 4),
            prefix_affinity_hash(&a, 4),
            "deterministic"
        );
        // a difference past the last full block boundary is invisible
        let ragged = [1, 2, 3, 4, 5, 6, 7];
        let mut ragged_tail = ragged;
        ragged_tail[6] = 99; // index 6 is past the 4-aligned boundary
        assert_eq!(
            prefix_affinity_hash(&ragged, 4),
            prefix_affinity_hash(&ragged_tail, 4),
            "trailing partial block must not split affinity"
        );
        // a difference inside the aligned prefix changes the hash
        let mut c = a;
        c[0] = 99;
        assert_ne!(prefix_affinity_hash(&a, 4), prefix_affinity_hash(&c, 4));
        // block_size 0 degrades to hashing the whole prompt
        assert_ne!(
            prefix_affinity_hash(&a, 0),
            prefix_affinity_hash(&a[..7], 0)
        );
    }

    fn geom() -> Geometry {
        Geometry {
            vocab_size: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            prompt_len: 4,
            gen_len: 4,
            block_size: 2,
            seq_len: 8,
            pad: 0,
            mask: 1,
            bos: 2,
            eos: 3,
        }
    }

    /// Distinct batch-major [L, bs=1, H, P, dh] prefill stacks.
    fn prefill_kv(g: &Geometry, salt: f32) -> (Vec<f32>, Vec<f32>) {
        let n = g.n_layers * g.n_heads * g.prompt_len * g.d_head;
        let k: Vec<f32> = (0..n).map(|i| salt + i as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        (k, v)
    }

    #[test]
    fn alloc_release_cycle() {
        let mut p = KvPool::new(&geom(), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert!(p.alloc().is_err(), "capacity enforced");
        p.release(a);
        let c = p.alloc().unwrap();
        assert_eq!(p.in_use(), 2);
        p.release(b);
        p.release(c);
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.peak_in_use, 2);
        p.assert_no_leaks();
    }

    #[test]
    fn dropped_lease_is_reaped_at_next_alloc() {
        let g = geom();
        let mut p = KvPool::new(&g, 1);
        let (k, v) = prefill_kv(&g, 0.0);
        let a = p.alloc().unwrap();
        p.write_prefill(&a, 0, 1, &k, &v).unwrap();
        drop(a); // leaked, not released
        assert_eq!(p.in_use(), 1, "reap is lazy");
        // the reaper frees the lane (and its pages) before allocating
        let b = p.alloc().unwrap();
        assert_eq!(p.in_use(), 1);
        assert_eq!(p.cache_len_of(&b), 0, "recycled lane starts fresh");
        p.release(b);
        p.assert_no_leaks();
    }

    #[test]
    fn dropped_lease_unpins_chain_on_reap() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 1, 2);
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin =
            pool.prefix_install(9, &[5, 6, 7, 8], 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&a, pin);
        drop(a);
        let b = pool.alloc().unwrap(); // reaps a, unpinning the chain
        assert_eq!(
            pool.prefix_chain_info(9, &[5, 6, 7, 8]),
            Some((2, 0)),
            "chain unpinned exactly once"
        );
        pool.release(b);
        pool.assert_no_leaks();
    }

    #[test]
    #[should_panic(expected = "foreign KvLease")]
    fn leases_are_pool_scoped() {
        let g = geom();
        let mut p1 = KvPool::new(&g, 1);
        let mut p2 = KvPool::new(&g, 1);
        let a = p1.alloc().unwrap();
        p2.release(a);
    }

    #[test]
    fn prefill_commit_view_roundtrip() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let lease = pool.alloc().unwrap();
        let (l_n, h_n, d, p, blk) = (2usize, 2usize, 4usize, 4usize, 2usize);
        let bs = 1;
        // distinct values per (l, h, pos, d)
        let kp: Vec<f32> =
            (0..l_n * bs * h_n * p * d).map(|i| i as f32).collect();
        let vp: Vec<f32> = kp.iter().map(|x| x + 0.5).collect();
        pool.write_prefill(&lease, 0, bs, &kp, &vp).unwrap();
        assert_eq!(pool.cache_len_of(&lease), p);

        let kb: Vec<f32> = (0..l_n * bs * h_n * blk * d)
            .map(|i| 1000.0 + i as f32)
            .collect();
        let vb: Vec<f32> = kb.iter().map(|x| x + 0.5).collect();
        pool.commit_block(&lease, 0, bs, blk, &kb, &vb).unwrap();
        assert_eq!(pool.cache_len_of(&lease), p + blk);

        let view = pool.view(&[&lease]);
        assert_eq!(view.cache_len(), p + blk);
        // prompt l=0, h=0, pos=0..4 is the front of the prefill input
        for pos in 0..p {
            for f in 0..d {
                assert_eq!(view.k_at(0, 0, 0, pos, f), (pos * d + f) as f32);
                assert_eq!(
                    view.v_at(0, 0, 0, pos, f),
                    (pos * d + f) as f32 + 0.5
                );
            }
        }
        // committed block lands at pos = p.. for l=0, h=0
        for i in 0..blk {
            for f in 0..d {
                assert_eq!(
                    view.k_at(0, 0, 0, p + i, f),
                    1000.0 + (i * d + f) as f32
                );
            }
        }
        pool.release(lease);
        pool.assert_no_leaks();
    }

    #[test]
    fn view_respects_lane_order() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let n = 2 * 2 * 4 * 4; // [L, bs=1, H, P, dh]
        pool.write_prefill(&a, 0, 1, &vec![1.0; n], &vec![1.0; n]).unwrap();
        pool.write_prefill(&b, 0, 1, &vec![2.0; n], &vec![2.0; n]).unwrap();
        let view = pool.view(&[&b, &a]);
        assert_eq!(view.bs(), 2);
        assert_eq!(view.cache_len(), 4);
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 2.0, "lane 0 is lease b");
        assert_eq!(view.k_at(1, 0, 0, 0, 0), 1.0, "lane 1 is lease a");
        // batch-major materialization places lane rows correctly
        let (bk, _) = view.to_batch_major();
        let row = 2 * 8 * 4; // [H, S, dh]
        assert_eq!(bk.data[0], 2.0);
        assert_eq!(bk.data[row], 1.0);
    }

    #[test]
    fn padded_view_aliases_last_real_lane() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let a = pool.alloc().unwrap();
        let n = 2 * 2 * 4 * 4;
        pool.write_prefill(&a, 0, 1, &vec![7.0; n], &vec![7.0; n]).unwrap();
        let view = pool.view_padded(&[&a], 4);
        assert_eq!(view.bs(), 4);
        for lane in 0..4 {
            assert_eq!(view.k_at(lane, 0, 0, 0, 0), 7.0);
        }
        pool.release(a);
        pool.assert_no_leaks();
    }

    #[test]
    fn property_pool_never_leaks_or_double_allocs() {
        check("kv-pool-invariants", 50, |r| {
            let mut pool = KvPool::new(&geom(), 4);
            let mut held: Vec<KvLease> = Vec::new();
            for _ in 0..100 {
                if r.below(2) == 0 && !held.is_empty() {
                    let i = r.index(held.len());
                    pool.release(held.swap_remove(i));
                } else if pool.in_use() < pool.capacity() {
                    let lease = pool.alloc().unwrap();
                    if held.iter().any(|h| h.lane == lease.lane) {
                        return false; // double-alloc!
                    }
                    held.push(lease);
                }
                if pool.in_use() != held.len() {
                    return false;
                }
            }
            for lease in held {
                pool.release(lease);
            }
            pool.assert_no_leaks();
            true
        });
    }

    #[test]
    fn mid_batch_recycle_resets_lane_state() {
        // continuous batching: a retired lane is freed while the pool
        // is live and handed to the next admission with a clean
        // cache_len, leaving sibling lanes untouched
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let keep = pool.alloc().unwrap();
        let retire = pool.alloc().unwrap();
        let n = 2 * 2 * 4 * 4; // [L, bs=1, H, P, dh]
        pool.write_prefill(&keep, 0, 1, &vec![7.0; n], &vec![7.0; n]).unwrap();
        pool.write_prefill(&retire, 0, 1, &vec![9.0; n], &vec![9.0; n])
            .unwrap();
        pool.release(retire);
        let admitted = pool.alloc().unwrap();
        assert_eq!(
            pool.cache_len_of(&admitted),
            0,
            "recycled lane starts fresh"
        );
        assert_eq!(pool.cache_len_of(&keep), 4, "sibling lane unaffected");
        assert_eq!(pool.total_allocs, 3, "lifetime allocs count recycling");
        let view = pool.view(&[&keep]);
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 7.0);
    }

    #[test]
    fn write_full_marks_whole_sequence() {
        let g = geom();
        let mut pool = KvPool::new(&g, 1);
        let lease = pool.alloc().unwrap();
        let n = 2 * 2 * 8 * 4;
        pool.write_full(&lease, 0, 1, &vec![3.0; n], &vec![3.0; n]).unwrap();
        assert_eq!(pool.cache_len_of(&lease), g.seq_len);
        let view = pool.view(&[&lease]);
        assert_eq!(view.k_at(0, 1, 1, 7, 3), 3.0);
    }

    // -----------------------------------------------------------------
    // Paged tails: on-demand allocation + over-subscription
    // -----------------------------------------------------------------

    #[test]
    fn tail_pages_allocate_on_demand_at_block_boundaries() {
        let g = geom(); // p=4, gen=4, blk=2 -> 2 tail pages per lane
        let mut pool = KvPool::new(&g, 1);
        assert_eq!(pool.tail_pages_full(), 2);
        let lease = pool.alloc().unwrap();
        assert_eq!(pool.tail_pages_free(), 2, "nothing allocated yet");
        let (k, v) = prefill_kv(&g, 0.0);
        pool.write_prefill(&lease, 0, 1, &k, &v).unwrap();
        assert_eq!(pool.prompt_pages_free(), 0, "prompt page taken");
        assert_eq!(pool.tail_pages_free(), 2, "prefill takes no tail page");
        let nb = 2 * 2 * 2 * 4; // [L, 1, H, blk=2, dh]
        pool.commit_block(&lease, 0, 1, 2, &vec![1.0; nb], &vec![1.0; nb])
            .unwrap();
        assert_eq!(pool.tail_pages_free(), 1, "first block takes one page");
        pool.commit_block(&lease, 0, 1, 2, &vec![2.0; nb], &vec![2.0; nb])
            .unwrap();
        assert_eq!(pool.tail_pages_free(), 0);
        pool.release(lease);
        assert_eq!(pool.tail_pages_free(), 2, "release returns pages");
        pool.assert_no_leaks();
    }

    #[test]
    fn oversubscribed_pool_holds_more_lanes_than_contiguous_slots() {
        let g = geom();
        // memory for 2 whole sequences, but 4 lanes: a contiguous
        // one-owner layout caps at 2 live lanes; paging admits 4 as
        // long as they stay in their first block
        let mut pool = KvPool::with_page_budgets(&g, 4, 4, 4, 0);
        let (k, v) = prefill_kv(&g, 0.0);
        let leases: Vec<KvLease> = (0..4)
            .map(|_| {
                let l = pool.alloc().unwrap();
                pool.write_prefill(&l, 0, 1, &k, &v).unwrap();
                l
            })
            .collect();
        assert_eq!(pool.in_use(), 4, "4 live lanes on 2 sequences' memory");
        let nb = 2 * 2 * 2 * 4;
        for l in &leases {
            pool.commit_block(l, 0, 1, 2, &vec![1.0; nb], &vec![1.0; nb])
                .unwrap();
        }
        // the 5th block commit in the cohort would need a 5th tail page
        let err = pool
            .commit_block(&leases[0], 0, 1, 2, &vec![2.0; nb], &vec![2.0; nb])
            .unwrap_err();
        assert!(
            err.to_string().contains("out of tail pages"),
            "typed pressure error, got: {err}"
        );
        for l in leases {
            pool.release(l);
        }
        pool.assert_no_leaks();
    }

    #[test]
    fn failed_page_alloc_keeps_lane_consistent_and_retryable() {
        let g = geom();
        let mut pool = KvPool::with_page_budgets(&g, 2, 2, 1, 0);
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.write_prefill(&a, 0, 1, &k, &v).unwrap();
        pool.write_prefill(&b, 0, 1, &k, &v).unwrap();
        let nb = 2 * 2 * 2 * 4;
        pool.commit_block(&a, 0, 1, 2, &vec![1.0; nb], &vec![1.0; nb])
            .unwrap();
        // b can't get a tail page while a holds the only one
        assert!(pool
            .commit_block(&b, 0, 1, 2, &vec![2.0; nb], &vec![2.0; nb])
            .is_err());
        assert_eq!(pool.cache_len_of(&b), 4, "failed commit didn't advance");
        // releasing a frees the page; the same commit now succeeds
        pool.release(a);
        pool.commit_block(&b, 0, 1, 2, &vec![2.0; nb], &vec![2.0; nb])
            .unwrap();
        assert_eq!(pool.cache_len_of(&b), 6);
        let view = pool.view(&[&b]);
        assert_eq!(view.k_at(0, 0, 0, 4, 0), 2.0);
        pool.release(b);
        pool.assert_no_leaks();
    }

    // -----------------------------------------------------------------
    // Preemption: suspend / resume
    // -----------------------------------------------------------------

    /// Snapshot every valid element of a lane through its view.
    fn snapshot(pool: &KvPool, lease: &KvLease) -> Vec<f32> {
        let g = geom();
        let view = pool.view(&[lease]);
        let mut out = Vec::new();
        for l in 0..g.n_layers {
            for h in 0..g.n_heads {
                for pos in 0..view.cache_len() {
                    for f in 0..g.d_head {
                        out.push(view.k_at(0, l, h, pos, f));
                        out.push(view.v_at(0, l, h, pos, f));
                    }
                }
            }
        }
        out
    }

    #[test]
    fn suspend_resume_restores_bytes_exactly() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let lease = pool.alloc().unwrap();
        let (k, v) = prefill_kv(&g, 3.0);
        pool.write_prefill(&lease, 0, 1, &k, &v).unwrap();
        let nb = 2 * 2 * 2 * 4;
        let kb: Vec<f32> = (0..nb).map(|i| 500.0 + i as f32).collect();
        pool.commit_block(&lease, 0, 1, 2, &kb, &kb).unwrap();
        let before = snapshot(&pool, &lease);

        let s = pool.suspend(lease);
        assert_eq!(pool.in_use(), 0);
        assert_eq!(s.cache_len(), 6);
        assert!(s.spilled_bytes() > 0);
        assert_eq!(pool.preempts, 1);
        // cold-tier size = (prompt page + 1 tail page) * K and V * 4B
        let row = g.n_layers * g.n_heads * g.d_head;
        assert_eq!(s.spilled_bytes(), 2 * 4 * row * (g.prompt_len + 2));

        // another lane can use the freed pages while it's parked
        let other = pool.alloc().unwrap();
        pool.write_prefill(&other, 0, 1, &k, &v).unwrap();
        pool.release(other);

        let lease = pool.resume(s).unwrap();
        assert_eq!(pool.resumes, 1);
        assert_eq!(pool.cache_len_of(&lease), 6);
        assert_eq!(snapshot(&pool, &lease), before, "byte-identical resume");
        // decode continues: the next commit appends at pos 6
        let kb2: Vec<f32> = (0..nb).map(|i| 900.0 + i as f32).collect();
        pool.commit_block(&lease, 0, 1, 2, &kb2, &kb2).unwrap();
        assert_eq!(pool.cache_len_of(&lease), 8);
        pool.release(lease);
        pool.assert_no_leaks();
    }

    #[test]
    fn suspend_keeps_chain_pinned_and_resume_reattaches() {
        let g = geom();
        // page budget: exactly one prompt's worth, so eviction pressure
        // would reclaim the chain if parking ever unpinned it
        let mut pool = KvPool::with_prefix_pages(&g, 2, 2);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&a, pin);
        let nb = 2 * 2 * 2 * 4;
        pool.commit_block(&a, 0, 1, 2, &vec![4.0; nb], &vec![4.0; nb])
            .unwrap();
        let before = snapshot(&pool, &a);

        let s = pool.suspend(a);
        assert_eq!(
            pool.prefix_chain_info(9, &prompt),
            Some((2, 1)),
            "parked lane keeps its chain pinned"
        );
        // under pressure a competing install must fail, not evict it
        let b = pool.alloc().unwrap();
        assert!(pool
            .prefix_install(9, &[10, 11, 12, 13], 0, 1, &k, &v, None)
            .is_err());
        pool.release(b);

        let a = pool.resume(s).unwrap();
        assert_eq!(
            pool.prefix_chain_info(9, &prompt),
            Some((2, 1)),
            "resume reattaches without double-pinning"
        );
        assert_eq!(snapshot(&pool, &a), before);
        pool.release(a);
        assert_eq!(pool.prefix_chain_info(9, &prompt), Some((2, 0)));
        pool.assert_no_leaks();
    }

    #[test]
    fn resume_under_pressure_hands_state_back() {
        let g = geom();
        let mut pool = KvPool::with_page_budgets(&g, 2, 1, 2, 0);
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        pool.write_prefill(&a, 0, 1, &k, &v).unwrap();
        let s = pool.suspend(a);
        // the only prompt page is taken by a new lane
        let b = pool.alloc().unwrap();
        pool.write_prefill(&b, 0, 1, &k, &v).unwrap();
        assert!(!pool.can_resume(&s));
        let s = match pool.resume(s) {
            Err(s) => s,
            Ok(_) => panic!("resume must fail under page pressure"),
        };
        pool.release(b);
        let a = pool.resume(s).unwrap();
        assert_eq!(pool.cache_len_of(&a), 4);
        pool.release(a);
        pool.assert_no_leaks();
    }

    #[test]
    fn discard_suspended_unpins_chain() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 1, 2);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&a, pin);
        let s = pool.suspend(a);
        pool.discard_suspended(s);
        assert_eq!(
            pool.prefix_chain_info(9, &prompt),
            Some((2, 0)),
            "aborted parked request dropped its pins"
        );
        pool.assert_no_leaks();
    }

    // -----------------------------------------------------------------
    // Shared-prefix chains
    // -----------------------------------------------------------------

    #[test]
    fn install_then_full_hit_reads_identical_kv() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);

        // cold: install writes 2 pages and pins the chain on lane a
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&a, pin);
        assert_eq!(pool.cache_len_of(&a), g.prompt_len);
        assert_eq!(pool.prefix_resident_pages(), 2);
        assert_eq!(pool.prefix_hits, 0);

        // warm: a second lane full-hits and shares the same pages
        let b = pool.alloc().unwrap();
        let pin = pool.prefix_acquire_full(9, &prompt, false).unwrap();
        pool.attach_chain(&b, pin);
        assert_eq!(pool.prefix_hits, 1);
        assert_eq!(pool.prefix_hit_blocks, 2);
        assert_eq!(pool.prefix_resident_pages(), 2, "no new pages on a hit");
        assert_eq!(pool.prefix_chain_info(9, &prompt), Some((2, 2)));

        // both lanes read the prefill content through their views
        let view = pool.view(&[&a, &b]);
        for lane in 0..2 {
            for l in 0..g.n_layers {
                for h in 0..g.n_heads {
                    for pos in 0..g.prompt_len {
                        for f in 0..g.d_head {
                            let src = (((l * g.n_heads) + h) * g.prompt_len
                                + pos)
                                * g.d_head
                                + f;
                            assert_eq!(view.k_at(lane, l, h, pos, f), k[src]);
                            assert_eq!(view.v_at(lane, l, h, pos, f), v[src]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn divergent_prompt_branches_instead_of_overwriting() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let p1 = vec![5, 6, 7, 8];
        let mut p2 = p1.clone();
        p2[2] = 9; // diverges at block 1 (page_len = 2)
        let (k1, v1) = prefill_kv(&g, 0.0);
        let (k2, v2) = prefill_kv(&g, 100.0);

        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p1, 0, 1, &k1, &v1, None).unwrap();
        pool.attach_chain(&a, pin);
        let b = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p2, 0, 1, &k2, &v2, None).unwrap();
        pool.attach_chain(&b, pin);

        // block 0 shared (copy-on-write: only the divergent tail is new)
        assert_eq!(pool.prefix_resident_pages(), 3);
        assert_eq!(pool.prefix_hit_blocks, 1);
        assert_eq!(pool.prefix_chain_info(9, &p1), Some((2, 1)));
        assert_eq!(pool.prefix_chain_info(9, &p2), Some((2, 1)));

        // lane a still reads p1's original block-1 KV (nothing was
        // overwritten); lane b reads its own divergent block
        let view = pool.view(&[&a, &b]);
        let src = 2 * g.d_head; // (l=0, h=0, pos=2, f=0) in [L,1,H,P,dh]
        assert_eq!(view.k_at(0, 0, 0, 2, 0), k1[src]);
        assert_eq!(view.k_at(1, 0, 0, 2, 0), k2[src]);
        // the shared block reads the first installer's content for both
        assert_eq!(view.k_at(0, 0, 0, 0, 0), k1[0]);
        assert_eq!(view.k_at(1, 0, 0, 0, 0), k1[0]);
    }

    #[test]
    fn tags_isolate_models() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(1, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&a, pin);
        assert!(pool.prefix_acquire_full(2, &prompt, false).is_none());
        assert!(pool.prefix_chain_info(2, &prompt).is_none());
    }

    #[test]
    fn retirement_unpins_and_eviction_spares_pinned_chains() {
        let g = geom();
        // page budget: exactly one prompt's worth
        let mut pool = KvPool::with_prefix_pages(&g, 2, 2);
        let p1 = vec![5, 6, 7, 8];
        let p2 = vec![10, 11, 12, 13];
        let (k, v) = prefill_kv(&g, 0.0);

        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p1, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&a, pin);

        // p1's chain is pinned: installing p2 must fail, not evict it
        let b = pool.alloc().unwrap();
        assert!(
            pool.prefix_install(9, &p2, 0, 1, &k, &v, None).is_err(),
            "eviction must never free a pinned chain"
        );
        assert_eq!(pool.prefix_evictions, 0);
        assert_eq!(pool.prefix_chain_info(9, &p1), Some((2, 1)), "p1 intact");
        // the failed install leaves no dangling pins
        pool.release(b);

        // retiring lane a unpins; the retained chain is now evictable
        pool.release(a);
        assert_eq!(pool.prefix_chain_info(9, &p1), Some((2, 0)));
        let b = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p2, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&b, pin);
        assert_eq!(pool.prefix_evictions, 2, "p1's two pages reclaimed");
        assert!(pool.prefix_chain_info(9, &p1).is_none(), "p1 evicted");
        assert_eq!(pool.prefix_chain_info(9, &p2), Some((2, 1)));
    }

    #[test]
    fn ar_tok_gates_full_hits_when_required() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&a, pin);
        // DLM chain has no cached first token: AR-style lookups miss…
        assert!(pool.prefix_acquire_full(9, &prompt, true).is_none());
        // …until an install caches one on the leaf
        let pin = pool
            .prefix_install(9, &prompt, 0, 1, &k, &v, Some(42))
            .unwrap();
        pool.release_pin(pin);
        let pin = pool.prefix_acquire_full(9, &prompt, true).unwrap();
        assert_eq!(pin.ar_tok, Some(42));
        pool.release_pin(pin);
    }

    #[test]
    fn lru_evicts_coldest_chain_first() {
        let g = geom();
        // room for two prompts' worth of pages
        let mut pool = KvPool::with_prefix_pages(&g, 1, 4);
        let (k, v) = prefill_kv(&g, 0.0);
        let p1 = vec![5, 6, 7, 8];
        let p2 = vec![10, 11, 12, 13];
        let p3 = vec![20, 21, 22, 23];
        for p in [&p1, &p2] {
            let s = pool.alloc().unwrap();
            let pin = pool.prefix_install(9, p, 0, 1, &k, &v, None).unwrap();
            pool.attach_chain(&s, pin);
            pool.release(s);
        }
        // touch p1 so p2 is the LRU chain
        let s = pool.alloc().unwrap();
        let pin = pool.prefix_acquire_full(9, &p1, false).unwrap();
        pool.attach_chain(&s, pin);
        pool.release(s);
        // p3 needs two pages: p2 (coldest, unpinned) is reclaimed
        let s = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p3, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(&s, pin);
        pool.release(s);
        assert!(pool.prefix_chain_info(9, &p1).is_some(), "warm chain kept");
        assert!(
            pool.prefix_chain_info(9, &p2).is_none(),
            "cold chain evicted"
        );
        assert!(pool.prefix_chain_info(9, &p3).is_some());
    }
}
