//! Block KV-cache manager: a slab pool of per-sequence cache slots.
//!
//! Exact block-level caching is the paper's second pillar (§4.3): the
//! prompt KV is written at prefill, each completed block's KV is
//! committed once, and nothing is ever recomputed. The pool hands out
//! fixed-size slots ([L, H, S, dh] per sequence, f32), tracks per-slot
//! valid length, and gathers/scatters between per-sequence slots and the
//! batch-major layout ([L, bs, H, S, dh]) the AOT programs consume.

use anyhow::Result;

use crate::runtime::Geometry;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlotId(usize);

#[derive(Debug)]
struct Slot {
    k: Vec<f32>, // [L, H, S, dh]
    v: Vec<f32>,
    cache_len: usize,
    in_use: bool,
}

/// Slab pool with O(1) alloc/free.
pub struct KvPool {
    geom: Geometry,
    slots: Vec<Slot>,
    free: Vec<usize>,
    slot_elems: usize,
    pub peak_in_use: usize,
}

impl KvPool {
    pub fn new(geom: &Geometry, capacity: usize) -> Self {
        let slot_elems =
            geom.n_layers * geom.n_heads * geom.seq_len * geom.d_head;
        let slots = (0..capacity)
            .map(|_| Slot {
                k: vec![0.0; slot_elems],
                v: vec![0.0; slot_elems],
                cache_len: 0,
                in_use: false,
            })
            .collect();
        Self {
            geom: geom.clone(),
            slots,
            free: (0..capacity).rev().collect(),
            slot_elems,
            peak_in_use: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn in_use(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn bytes_per_slot(&self) -> usize {
        2 * self.slot_elems * std::mem::size_of::<f32>()
    }

    pub fn alloc(&mut self) -> Result<SlotId> {
        let idx = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("KV pool exhausted"))?;
        let s = &mut self.slots[idx];
        debug_assert!(!s.in_use);
        s.in_use = true;
        s.cache_len = 0;
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        Ok(SlotId(idx))
    }

    pub fn free(&mut self, id: SlotId) {
        let s = &mut self.slots[id.0];
        assert!(s.in_use, "double free of KV slot {id:?}");
        s.in_use = false;
        // zeroing is unnecessary for correctness (cache_len gates reads)
        self.free.push(id.0);
    }

    pub fn cache_len(&self, id: SlotId) -> usize {
        self.slots[id.0].cache_len
    }

    /// Install prefill output for one lane. `k`/`v` are batch-major
    /// [L, bs, H, P, dh] slices from the prefill program.
    pub fn write_prefill(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let g = &self.geom;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let p = g.prompt_len;
        let slot = &mut self.slots[id.0];
        for l in 0..l_n {
            for h in 0..h_n {
                let src = (((l * bs + lane) * h_n + h) * p) * d;
                let dst = ((l * h_n + h) * s_n) * d;
                slot.k[dst..dst + p * d].copy_from_slice(&k[src..src + p * d]);
                slot.v[dst..dst + p * d].copy_from_slice(&v[src..src + p * d]);
            }
        }
        slot.cache_len = p;
    }

    /// Commit a finalized block's KV for one lane. `k_blk`/`v_blk` are
    /// [L, bs, H, B, dh]; the block lands at the slot's current
    /// cache_len, which advances by `blk` (exact append-only caching).
    pub fn commit_block(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        blk: usize,
        k_blk: &[f32],
        v_blk: &[f32],
    ) {
        let g = &self.geom;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let pos = self.slots[id.0].cache_len;
        assert!(pos + blk <= s_n, "cache overflow: {pos} + {blk} > {s_n}");
        let slot = &mut self.slots[id.0];
        for l in 0..l_n {
            for h in 0..h_n {
                let src = (((l * bs + lane) * h_n + h) * blk) * d;
                let dst = ((l * h_n + h) * s_n + pos) * d;
                slot.k[dst..dst + blk * d]
                    .copy_from_slice(&k_blk[src..src + blk * d]);
                slot.v[dst..dst + blk * d]
                    .copy_from_slice(&v_blk[src..src + blk * d]);
            }
        }
        slot.cache_len = pos + blk;
    }

    /// Gather lanes' slots into batch-major buffers [L, bs, H, S, dh].
    /// Lanes beyond `ids.len()` are left untouched (dead-lane padding).
    pub fn gather_batch(
        &self,
        ids: &[SlotId],
        bs: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let g = &self.geom;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        debug_assert_eq!(k_out.len(), l_n * bs * h_n * s_n * d);
        let row = h_n * s_n * d;
        for (lane, id) in ids.iter().enumerate() {
            let slot = &self.slots[id.0];
            for l in 0..l_n {
                let src = l * row;
                let dst = (l * bs + lane) * row;
                k_out[dst..dst + row].copy_from_slice(&slot.k[src..src + row]);
                v_out[dst..dst + row].copy_from_slice(&slot.v[src..src + row]);
            }
        }
    }

    /// Direct write of full-sequence KV (approximate-cache baselines):
    /// overwrite the slot with the stale full-sequence stacks
    /// [L, bs, H, S, dh] and mark the whole sequence resident.
    pub fn write_full(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let g = &self.geom;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let row = h_n * s_n * d;
        let slot = &mut self.slots[id.0];
        for l in 0..l_n {
            let src = (l * bs + lane) * row;
            let dst = l * row;
            slot.k[dst..dst + row].copy_from_slice(&k[src..src + row]);
            slot.v[dst..dst + row].copy_from_slice(&v[src..src + row]);
        }
        slot.cache_len = s_n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn geom() -> Geometry {
        Geometry {
            vocab_size: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            prompt_len: 4,
            gen_len: 4,
            block_size: 2,
            seq_len: 8,
            pad: 0,
            mask: 1,
            bos: 2,
            eos: 3,
        }
    }

    #[test]
    fn alloc_free_cycle() {
        let mut p = KvPool::new(&geom(), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert!(p.alloc().is_err(), "capacity enforced");
        p.free(a);
        let c = p.alloc().unwrap();
        assert_eq!(p.in_use(), 2);
        p.free(b);
        p.free(c);
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.peak_in_use, 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = KvPool::new(&geom(), 1);
        let a = p.alloc().unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    fn prefill_commit_gather_roundtrip() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let id = pool.alloc().unwrap();
        let (l_n, h_n, d, p, s, blk) = (2, 2, 4, 4, 8, 2);
        let bs = 1;
        // distinct values per (l, h, pos, d)
        let kp: Vec<f32> = (0..l_n * bs * h_n * p * d).map(|i| i as f32).collect();
        let vp: Vec<f32> = kp.iter().map(|x| x + 0.5).collect();
        pool.write_prefill(id, 0, bs, &kp, &vp);
        assert_eq!(pool.cache_len(id), p);

        let kb: Vec<f32> =
            (0..l_n * bs * h_n * blk * d).map(|i| 1000.0 + i as f32).collect();
        let vb: Vec<f32> = kb.iter().map(|x| x + 0.5).collect();
        pool.commit_block(id, 0, bs, blk, &kb, &vb);
        assert_eq!(pool.cache_len(id), p + blk);

        let mut k_out = vec![-1.0; l_n * bs * h_n * s * d];
        let mut v_out = vec![-1.0; l_n * bs * h_n * s * d];
        pool.gather_batch(&[id], bs, &mut k_out, &mut v_out);
        // prompt row l=0,h=0,pos=0..4 lands at the front
        assert_eq!(&k_out[..p * d], &kp[..p * d]);
        // committed block lands at pos=4.. for l=0,h=0
        let blk_at = p * d;
        assert_eq!(&k_out[blk_at..blk_at + blk * d], &kb[..blk * d]);
    }

    #[test]
    fn gather_respects_lane_offsets() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let n = 2 * 1 * 2 * 4 * 4;
        pool.write_prefill(a, 0, 1, &vec![1.0; n], &vec![1.0; n]);
        pool.write_prefill(b, 0, 1, &vec![2.0; n], &vec![2.0; n]);
        let bs = 2;
        let total = 2 * bs * 2 * 8 * 4;
        let mut k_out = vec![0.0; total];
        let mut v_out = vec![0.0; total];
        pool.gather_batch(&[a, b], bs, &mut k_out, &mut v_out);
        // lane 0 row l=0: ones in the prompt region
        assert_eq!(k_out[0], 1.0);
        // lane 1 row l=0 starts at offset h*s*d (row stride)
        let row = 2 * 8 * 4;
        assert_eq!(k_out[row], 2.0);
    }

    #[test]
    fn property_pool_never_leaks_or_double_allocs() {
        check("kv-pool-invariants", 50, |r| {
            let mut pool = KvPool::new(&geom(), 4);
            let mut held: Vec<SlotId> = Vec::new();
            for _ in 0..100 {
                if r.below(2) == 0 && !held.is_empty() {
                    let i = r.index(held.len());
                    pool.free(held.swap_remove(i));
                } else if pool.in_use() < pool.capacity() {
                    let id = pool.alloc().unwrap();
                    if held.contains(&id) {
                        return false; // double-alloc!
                    }
                    held.push(id);
                }
                if pool.in_use() != held.len() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn write_full_marks_whole_sequence() {
        let g = geom();
        let mut pool = KvPool::new(&g, 1);
        let id = pool.alloc().unwrap();
        let n = 2 * 1 * 2 * 8 * 4;
        pool.write_full(id, 0, 1, &vec![3.0; n], &vec![3.0; n]);
        assert_eq!(pool.cache_len(id), g.seq_len);
    }
}
