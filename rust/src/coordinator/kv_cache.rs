//! Block KV-cache manager: lane-major contiguous slabs of per-sequence
//! cache slots.
//!
//! Exact block-level caching is the paper's second pillar (§4.3): the
//! prompt KV is written at prefill, each completed block's KV is
//! committed once, and nothing is ever recomputed. The pool owns two
//! contiguous slabs (K and V); slot `i` is the `[L, H, S, dh]` region at
//! offset `i * slot_elems`, handed out with O(1) alloc/free. Engines
//! never copy the cache out: [`KvPool::view`] lends a zero-copy
//! [`KvView`] (per-lane slot bases over the slabs, `cache_len`-bounded)
//! that flows through the backend seam, and commits append in place per
//! lane. The batch-major `[L, bs, H, S, dh]` staging copies the old
//! `gather_batch` produced are gone from the decode loop; device
//! backends that still need that layout materialize it behind the seam
//! via `KvView::to_batch_major`.

use anyhow::Result;

use crate::runtime::{Geometry, KvDims, KvView};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlotId(usize);

/// Slab pool with O(1) alloc/free.
pub struct KvPool {
    dims: KvDims,
    prompt_len: usize,
    k: Vec<f32>, // [capacity] x [L, H, S, dh], lane-major slots
    v: Vec<f32>,
    cache_lens: Vec<usize>,
    used: Vec<bool>,
    free: Vec<usize>,
    slot_elems: usize,
    pub peak_in_use: usize,
    /// Lifetime alloc count. With mid-batch slot recycling (continuous
    /// batching retires a lane and hands its slot to the next
    /// admission) this exceeds `capacity` on a busy pool — aggregated
    /// across pools as `kv_total_allocs` on `/healthz`, an
    /// admission-churn signal.
    pub total_allocs: u64,
}

impl KvPool {
    pub fn new(geom: &Geometry, capacity: usize) -> Self {
        let dims = KvDims::of(geom);
        let slot_elems = dims.slot_elems();
        Self {
            dims,
            prompt_len: geom.prompt_len,
            k: vec![0.0; capacity * slot_elems],
            v: vec![0.0; capacity * slot_elems],
            cache_lens: vec![0; capacity],
            used: vec![false; capacity],
            free: (0..capacity).rev().collect(),
            slot_elems,
            peak_in_use: 0,
            total_allocs: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.used.len()
    }

    pub fn in_use(&self) -> usize {
        self.used.len() - self.free.len()
    }

    pub fn bytes_per_slot(&self) -> usize {
        2 * self.slot_elems * std::mem::size_of::<f32>()
    }

    pub fn alloc(&mut self) -> Result<SlotId> {
        let idx = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("KV pool exhausted"))?;
        debug_assert!(!self.used[idx]);
        self.used[idx] = true;
        self.cache_lens[idx] = 0;
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        self.total_allocs += 1;
        Ok(SlotId(idx))
    }

    pub fn free(&mut self, id: SlotId) {
        assert!(self.used[id.0], "double free of KV slot {id:?}");
        self.used[id.0] = false;
        // zeroing is unnecessary for correctness (cache_len gates reads)
        self.free.push(id.0);
    }

    pub fn cache_len(&self, id: SlotId) -> usize {
        self.cache_lens[id.0]
    }

    #[inline]
    fn base(&self, id: SlotId) -> usize {
        id.0 * self.slot_elems
    }

    /// Borrow a zero-copy view of `ids`' slots with the given lockstep
    /// valid-prefix length. No cache data moves: the view is the slab
    /// borrows plus one base offset per lane.
    pub fn view(&self, ids: &[SlotId], cache_len: usize) -> KvView<'_> {
        let bases = ids.iter().map(|&id| self.base(id)).collect();
        KvView::new(&self.k, &self.v, bases, self.dims, cache_len)
    }

    /// Install prefill output for one lane. `k`/`v` are batch-major
    /// [L, bs, H, P, dh] slices from the prefill program; the prompt
    /// region of the slot is the only part written.
    pub fn write_prefill(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let g = self.dims;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let p = self.prompt_len;
        assert_eq!(
            k.len(),
            l_n * bs * h_n * p * d,
            "prefill KV must be [L, bs={bs}, H, P={p}, dh]"
        );
        let base = self.base(id);
        for l in 0..l_n {
            for h in 0..h_n {
                let src = (((l * bs + lane) * h_n + h) * p) * d;
                let dst = base + ((l * h_n + h) * s_n) * d;
                self.k[dst..dst + p * d].copy_from_slice(&k[src..src + p * d]);
                self.v[dst..dst + p * d].copy_from_slice(&v[src..src + p * d]);
            }
        }
        self.cache_lens[id.0] = p;
    }

    /// Commit a finalized block's KV for one lane. `k_blk`/`v_blk` are
    /// [L, bs, H, B, dh]; the block appends in place at the slot's
    /// current cache_len, which advances by `blk` (exact append-only
    /// caching — no other slab region is touched).
    pub fn commit_block(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        blk: usize,
        k_blk: &[f32],
        v_blk: &[f32],
    ) {
        let g = self.dims;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let pos = self.cache_lens[id.0];
        assert!(pos + blk <= s_n, "cache overflow: {pos} + {blk} > {s_n}");
        let base = self.base(id);
        for l in 0..l_n {
            for h in 0..h_n {
                let src = (((l * bs + lane) * h_n + h) * blk) * d;
                let dst = base + ((l * h_n + h) * s_n + pos) * d;
                self.k[dst..dst + blk * d]
                    .copy_from_slice(&k_blk[src..src + blk * d]);
                self.v[dst..dst + blk * d]
                    .copy_from_slice(&v_blk[src..src + blk * d]);
            }
        }
        self.cache_lens[id.0] = pos + blk;
    }

    /// Direct write of full-sequence KV (approximate-cache baselines):
    /// overwrite the slot with the stale full-sequence stacks
    /// [L, bs, H, S, dh] and mark the whole sequence resident.
    pub fn write_full(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let g = self.dims;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let row = h_n * s_n * d;
        let base = self.base(id);
        for l in 0..l_n {
            let src = (l * bs + lane) * row;
            let dst = base + l * row;
            self.k[dst..dst + row].copy_from_slice(&k[src..src + row]);
            self.v[dst..dst + row].copy_from_slice(&v[src..src + row]);
        }
        self.cache_lens[id.0] = s_n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn geom() -> Geometry {
        Geometry {
            vocab_size: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            prompt_len: 4,
            gen_len: 4,
            block_size: 2,
            seq_len: 8,
            pad: 0,
            mask: 1,
            bos: 2,
            eos: 3,
        }
    }

    #[test]
    fn alloc_free_cycle() {
        let mut p = KvPool::new(&geom(), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert!(p.alloc().is_err(), "capacity enforced");
        p.free(a);
        let c = p.alloc().unwrap();
        assert_eq!(p.in_use(), 2);
        p.free(b);
        p.free(c);
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.peak_in_use, 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = KvPool::new(&geom(), 1);
        let a = p.alloc().unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    fn prefill_commit_view_roundtrip() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let id = pool.alloc().unwrap();
        let (l_n, h_n, d, p, blk) = (2usize, 2usize, 4usize, 4usize, 2usize);
        let bs = 1;
        // distinct values per (l, h, pos, d)
        let kp: Vec<f32> = (0..l_n * bs * h_n * p * d).map(|i| i as f32).collect();
        let vp: Vec<f32> = kp.iter().map(|x| x + 0.5).collect();
        pool.write_prefill(id, 0, bs, &kp, &vp);
        assert_eq!(pool.cache_len(id), p);

        let kb: Vec<f32> =
            (0..l_n * bs * h_n * blk * d).map(|i| 1000.0 + i as f32).collect();
        let vb: Vec<f32> = kb.iter().map(|x| x + 0.5).collect();
        pool.commit_block(id, 0, bs, blk, &kb, &vb);
        assert_eq!(pool.cache_len(id), p + blk);

        let view = pool.view(&[id], p + blk);
        // prompt l=0, h=0, pos=0..4 is the front of the prefill input
        for pos in 0..p {
            for f in 0..d {
                assert_eq!(view.k_at(0, 0, 0, pos, f), (pos * d + f) as f32);
                assert_eq!(view.v_at(0, 0, 0, pos, f), (pos * d + f) as f32 + 0.5);
            }
        }
        // committed block lands at pos = p.. for l=0, h=0
        for i in 0..blk {
            for f in 0..d {
                assert_eq!(
                    view.k_at(0, 0, 0, p + i, f),
                    1000.0 + (i * d + f) as f32
                );
            }
        }
    }

    #[test]
    fn view_respects_lane_order() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let n = 2 * 2 * 4 * 4; // [L, bs=1, H, P, dh]
        pool.write_prefill(a, 0, 1, &vec![1.0; n], &vec![1.0; n]);
        pool.write_prefill(b, 0, 1, &vec![2.0; n], &vec![2.0; n]);
        let view = pool.view(&[b, a], 4);
        assert_eq!(view.bs(), 2);
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 2.0, "lane 0 is slot b");
        assert_eq!(view.k_at(1, 0, 0, 0, 0), 1.0, "lane 1 is slot a");
        // batch-major materialization places lane rows correctly
        let (bk, _) = view.to_batch_major();
        let row = 2 * 8 * 4; // [H, S, dh]
        assert_eq!(bk.data[0], 2.0);
        assert_eq!(bk.data[row], 1.0);
    }

    #[test]
    fn property_pool_never_leaks_or_double_allocs() {
        check("kv-pool-invariants", 50, |r| {
            let mut pool = KvPool::new(&geom(), 4);
            let mut held: Vec<SlotId> = Vec::new();
            for _ in 0..100 {
                if r.below(2) == 0 && !held.is_empty() {
                    let i = r.index(held.len());
                    pool.free(held.swap_remove(i));
                } else if pool.in_use() < pool.capacity() {
                    let id = pool.alloc().unwrap();
                    if held.contains(&id) {
                        return false; // double-alloc!
                    }
                    held.push(id);
                }
                if pool.in_use() != held.len() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn mid_batch_recycle_resets_slot_state() {
        // continuous batching: a retired lane's slot is freed while the
        // pool is live and handed to the next admission with a clean
        // cache_len, leaving sibling slots untouched
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let keep = pool.alloc().unwrap();
        let retire = pool.alloc().unwrap();
        let n = 2 * 2 * 4 * 4; // [L, bs=1, H, P, dh]
        pool.write_prefill(keep, 0, 1, &vec![7.0; n], &vec![7.0; n]);
        pool.write_prefill(retire, 0, 1, &vec![9.0; n], &vec![9.0; n]);
        pool.free(retire);
        let admitted = pool.alloc().unwrap();
        assert_eq!(pool.cache_len(admitted), 0, "recycled slot starts fresh");
        assert_eq!(pool.cache_len(keep), 4, "sibling lane unaffected");
        assert_eq!(pool.total_allocs, 3, "lifetime allocs count recycling");
        let view = pool.view(&[keep], 4);
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 7.0);
    }

    #[test]
    fn write_full_marks_whole_sequence() {
        let g = geom();
        let mut pool = KvPool::new(&g, 1);
        let id = pool.alloc().unwrap();
        let n = 2 * 2 * 8 * 4;
        pool.write_full(id, 0, 1, &vec![3.0; n], &vec![3.0; n]);
        assert_eq!(pool.cache_len(id), g.seq_len);
        let view = pool.view(&[id], g.seq_len);
        assert_eq!(view.k_at(0, 1, 1, 7, 3), 3.0);
    }
}
