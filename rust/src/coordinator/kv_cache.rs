//! Block KV-cache manager: lane slots + ref-counted shared-prefix
//! chains.
//!
//! Exact block-level caching is the paper's second pillar (§4.3): the
//! prompt KV is written at prefill, each completed block's KV is
//! committed once, and nothing is ever recomputed. Block-wise causal
//! attention also makes the prompt KV *position-causal* — the cache for
//! positions `[0, p)` depends only on the tokens at `[0, p)` — which is
//! what makes cross-request reuse legal: two requests whose prompts
//! share a block-aligned token prefix can share the cached KV for it
//! verbatim.
//!
//! The pool therefore owns two kinds of storage inside one pair of
//! contiguous K/V slabs:
//!
//! * **lane slots** — the classic one-owner `[L, H, S, dh]` regions
//!   with O(1) alloc/free; every decode engine commits generated-block
//!   KV here, and engines that never share (the closed-batch baselines,
//!   the approximate-cache teachers) keep their whole cache here;
//! * **prefix pages** — block-granular `[L, H, B, dh]` regions indexed
//!   by a token-id trie ([`ChainNode`]) and shared across lanes with
//!   refcounts. A lane that admits with a cached prompt pins its chain
//!   (one refcount per node); retirement unpins; unpinned chains stay
//!   resident as a warm cache until an LRU evictor reclaims them under
//!   page pressure. Eviction is leaf-first and never touches a pinned
//!   node, so a live lane's prefix can never be freed under it (the
//!   pinned-chain guarantee `tests/prefix_cache.rs` pins).
//!
//! Divergence is copy-on-write by construction: a prompt that shares
//! `k` blocks and then differs branches the trie at block `k` — the
//! divergent tail gets fresh pages and the shared prefix is never
//! overwritten.
//!
//! Engines never copy the cache out: [`KvPool::view`] lends a zero-copy
//! [`KvView`] whose per-lane segment runs stitch shared pages and the
//! private slot together; commits append in place per lane. Device
//! backends that need the batch-major layout materialize it behind the
//! seam via `KvView::to_batch_major`.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::{Geometry, KvDims, KvSeg, KvView, INLINE_LANES};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlotId(usize);

/// A pinned prefix chain: the trie path (root-first) whose pages hold
/// one full prompt's KV. Produced by [`KvPool::prefix_acquire_full`] /
/// [`KvPool::prefix_install`] with every node's refcount already
/// incremented; hand it to [`KvPool::attach_chain`] so the owning
/// slot's retirement unpins it.
#[derive(Debug)]
pub struct ChainPin {
    nodes: Vec<usize>,
    /// First-token proposal cached at full-prompt depth (AR prefill
    /// emits one; DLM prefills leave it empty).
    pub ar_tok: Option<i32>,
}

/// Prefix-sharing granularity for a geometry: the block size when it
/// divides the prompt cleanly, else the whole prompt as one block (no
/// sub-prompt sharing, but the machinery still works).
fn page_len_of(geom: &Geometry) -> usize {
    if geom.block_size > 0 && geom.prompt_len % geom.block_size == 0 {
        geom.block_size
    } else {
        geom.prompt_len.max(1)
    }
}

/// Stable FNV-1a hash of the longest block-aligned prompt prefix — the
/// replica dispatcher's affinity key. Two prompts that would share a
/// prefix-trie chain (identical up to the last full block) hash alike,
/// so `hash % replicas` steers shared-prompt traffic to the one shard
/// whose trie already holds the warm pages. Tokens past the final block
/// boundary are ignored: they can never be shared (the trie is paged at
/// block granularity), so they must not split warm traffic.
pub fn prefix_affinity_hash(prompt_ids: &[i32], block_size: usize) -> u64 {
    let aligned = if block_size > 0 {
        prompt_ids.len() - prompt_ids.len() % block_size
    } else {
        prompt_ids.len()
    };
    let mut h: u64 = 0xcbf29ce484222325;
    for t in &prompt_ids[..aligned] {
        for b in t.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// One block of cached prompt KV in the trie: `tokens` is the block's
/// token ids, `page` its `[L, H, B, dh]` region, `refs` the number of
/// live lanes pinning it.
#[derive(Debug)]
struct ChainNode {
    tag: u64,
    tokens: Vec<i32>,
    parent: Option<usize>,
    children: Vec<usize>,
    page: usize,
    refs: usize,
    tick: u64,
    ar_tok: Option<i32>,
}

/// Slab pool with O(1) slot alloc/free plus the shared-prefix page
/// store and its trie index.
pub struct KvPool {
    dims: KvDims,
    prompt_len: usize,
    /// Positions per prefix page (the prefix-sharing granularity):
    /// the geometry block size when it divides the prompt, else the
    /// whole prompt as a single block.
    page_len: usize,
    /// Pages covering one full prompt.
    prompt_pages: usize,
    k: Vec<f32>, // [slots | pages], lane-major regions
    v: Vec<f32>,
    // ---- lane slots (one owner each)
    cache_lens: Vec<usize>,
    used: Vec<bool>,
    free: Vec<usize>,
    slot_elems: usize,
    /// Per-slot attached chain (trie node path); empty = private slot
    /// only.
    chains: Vec<Vec<usize>>,
    // ---- prefix pages (shared, ref-counted)
    page_elems: usize,
    /// Element offset where the page region starts in the slabs.
    page_region: usize,
    page_used: Vec<bool>,
    page_free: Vec<usize>,
    // ---- trie
    nodes: Vec<Option<ChainNode>>,
    node_free: Vec<usize>,
    roots: HashMap<u64, Vec<usize>>,
    lru_tick: u64,
    // ---- counters
    pub peak_in_use: usize,
    /// Lifetime alloc count. With mid-batch slot recycling (continuous
    /// batching retires a lane and hands its slot to the next
    /// admission) this exceeds `capacity` on a busy pool — aggregated
    /// across pools as `kv_total_allocs` on `/healthz`, an
    /// admission-churn signal.
    pub total_allocs: u64,
    /// Full-prompt chain hits: admissions that skipped prefill
    /// entirely.
    pub prefix_hits: u64,
    /// Block-granular reuse: cached blocks found at admission,
    /// including partial (copy-on-write) matches.
    pub prefix_hit_blocks: u64,
    /// Chain blocks reclaimed by the LRU evictor under page pressure.
    pub prefix_evictions: u64,
    /// Armed by [`KvPool::inject_alloc_failures`] (fault injection):
    /// while nonzero, `alloc` fails and decrements it. Zero in
    /// production — only a `FaultPlan` ever arms it.
    forced_alloc_failures: u64,
}

impl KvPool {
    /// A pool with `capacity` lane slots and **no** prefix pages: the
    /// layout every closed-batch path uses (those engines always
    /// prefill into private slots, keeping the trace-pinned baseline
    /// accounting cold by construction). The block-step machine builds
    /// its pool with [`KvPool::with_prefix_pages`] instead.
    pub fn new(geom: &Geometry, capacity: usize) -> Self {
        Self::with_prefix_pages(geom, capacity, 0)
    }

    /// The machine's default prefix-page budget for a pool of
    /// `capacity` lanes: two prompts' worth of pages per lane — a full
    /// complement of live chains plus as much again retained as warm
    /// cache before the LRU evictor starts reclaiming.
    pub fn default_page_budget(geom: &Geometry, capacity: usize) -> usize {
        2 * capacity * (geom.prompt_len / page_len_of(geom))
    }

    /// A pool with an explicit prefix-page budget (tests exercise
    /// eviction pressure through this constructor).
    pub fn with_prefix_pages(
        geom: &Geometry,
        capacity: usize,
        page_capacity: usize,
    ) -> Self {
        let dims = KvDims::of(geom);
        let slot_elems = dims.slot_elems();
        let page_len = page_len_of(geom);
        let prompt_pages = geom.prompt_len / page_len;
        let page_elems =
            dims.n_layers * dims.n_heads * page_len * dims.d_head;
        let page_region = capacity * slot_elems;
        let total = page_region + page_capacity * page_elems;
        Self {
            dims,
            prompt_len: geom.prompt_len,
            page_len,
            prompt_pages,
            k: vec![0.0; total],
            v: vec![0.0; total],
            cache_lens: vec![0; capacity],
            used: vec![false; capacity],
            free: (0..capacity).rev().collect(),
            slot_elems,
            chains: (0..capacity).map(|_| Vec::new()).collect(),
            page_elems,
            page_region,
            page_used: vec![false; page_capacity],
            page_free: (0..page_capacity).rev().collect(),
            nodes: Vec::new(),
            node_free: Vec::new(),
            roots: HashMap::new(),
            lru_tick: 0,
            peak_in_use: 0,
            total_allocs: 0,
            prefix_hits: 0,
            prefix_hit_blocks: 0,
            prefix_evictions: 0,
            forced_alloc_failures: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.used.len()
    }

    pub fn in_use(&self) -> usize {
        self.used.len() - self.free.len()
    }

    pub fn bytes_per_slot(&self) -> usize {
        2 * self.slot_elems * std::mem::size_of::<f32>()
    }

    /// Positions per prefix page (the block-aligned sharing
    /// granularity).
    pub fn page_len(&self) -> usize {
        self.page_len
    }

    /// Pages that make up one full prompt chain.
    pub fn prompt_pages(&self) -> usize {
        self.prompt_pages
    }

    /// Prefix pages currently resident (pinned or retained) — surfaced
    /// as `kv_shared_slots` on `/healthz`.
    pub fn prefix_resident_pages(&self) -> usize {
        self.page_used.len() - self.page_free.len()
    }

    pub fn prefix_page_capacity(&self) -> usize {
        self.page_used.len()
    }

    /// Fault injection: fail the next `n` allocations with a typed
    /// error, as if the pool were exhausted. Exercises the admission
    /// failure path (`Aborted{"admission failed: ..."}`) without
    /// needing a genuinely full pool.
    pub fn inject_alloc_failures(&mut self, n: u64) {
        self.forced_alloc_failures += n;
    }

    pub fn alloc(&mut self) -> Result<SlotId> {
        if self.forced_alloc_failures > 0 {
            self.forced_alloc_failures -= 1;
            anyhow::bail!("KV allocation failed (injected fault)");
        }
        let idx = self
            .free
            .pop()
            .ok_or_else(|| anyhow::anyhow!("KV pool exhausted"))?;
        debug_assert!(!self.used[idx]);
        debug_assert!(self.chains[idx].is_empty(), "freed slot kept a chain");
        self.used[idx] = true;
        self.cache_lens[idx] = 0;
        self.peak_in_use = self.peak_in_use.max(self.in_use());
        self.total_allocs += 1;
        Ok(SlotId(idx))
    }

    /// Free a slot. If a prefix chain is attached its refcounts drop by
    /// one; the chain's pages stay resident as warm cache until the LRU
    /// evictor needs them.
    pub fn free(&mut self, id: SlotId) {
        assert!(self.used[id.0], "double free of KV slot {id:?}");
        let path = std::mem::take(&mut self.chains[id.0]);
        for n in path {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            debug_assert!(node.refs > 0, "unpin of an unpinned chain node");
            node.refs -= 1;
        }
        self.used[id.0] = false;
        // zeroing is unnecessary for correctness (cache_len gates reads)
        self.free.push(id.0);
    }

    pub fn cache_len(&self, id: SlotId) -> usize {
        self.cache_lens[id.0]
    }

    #[inline]
    fn base(&self, id: SlotId) -> usize {
        id.0 * self.slot_elems
    }

    #[inline]
    fn page_base(&self, page: usize) -> usize {
        self.page_region + page * self.page_elems
    }

    /// Borrow a zero-copy view of `ids`' caches with the given lockstep
    /// valid-prefix length. No cache data moves: each lane is a segment
    /// run over the slabs — its pinned prefix pages (if a chain is
    /// attached) followed by its private slot. An all-plain batch of up
    /// to [`INLINE_LANES`] lanes (every closed-batch engine and the
    /// block-step machine's cohorts) builds its view with **zero** heap
    /// allocations: the bases live on the stack and the view stores them
    /// inline. Chained lanes (prefix cache) still build per-lane segment
    /// runs — that path allocates and is documented as off the hotpath
    /// allocation gate.
    pub fn view(&self, ids: &[SlotId], cache_len: usize) -> KvView<'_> {
        if ids.iter().all(|&id| self.chains[id.0].is_empty()) {
            if ids.len() <= INLINE_LANES {
                let mut bases = [0usize; INLINE_LANES];
                for (b, &id) in bases.iter_mut().zip(ids) {
                    *b = self.base(id);
                }
                return KvView::new(
                    &self.k,
                    &self.v,
                    &bases[..ids.len()],
                    self.dims,
                    cache_len,
                );
            }
            let bases: Vec<usize> =
                ids.iter().map(|&id| self.base(id)).collect();
            return KvView::new(&self.k, &self.v, &bases, self.dims, cache_len);
        }
        let lanes = ids.iter().map(|&id| self.lane_segs(id)).collect();
        KvView::segmented(&self.k, &self.v, lanes, self.dims, cache_len)
    }

    fn lane_segs(&self, id: SlotId) -> Vec<KvSeg> {
        let path = &self.chains[id.0];
        if path.is_empty() {
            return vec![KvSeg::full_slot(self.base(id), self.dims.seq_len)];
        }
        let mut segs = Vec::with_capacity(path.len() + 1);
        for (i, &n) in path.iter().enumerate() {
            let page =
                self.nodes[n].as_ref().expect("chain node resident").page;
            segs.push(KvSeg {
                start: i * self.page_len,
                len: self.page_len,
                base: self.page_base(page),
                region_len: self.page_len,
                offset: 0,
            });
        }
        // generated positions live in the lane's own slot at their
        // natural offsets
        segs.push(KvSeg {
            start: self.prompt_len,
            len: self.dims.seq_len - self.prompt_len,
            base: self.base(id),
            region_len: self.dims.seq_len,
            offset: self.prompt_len,
        });
        segs
    }

    /// Install prefill output for one lane. `k`/`v` are batch-major
    /// [L, bs, H, P, dh] slices from the prefill program; the prompt
    /// region of the slot is the only part written.
    pub fn write_prefill(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) {
        debug_assert!(
            self.chains[id.0].is_empty(),
            "write_prefill into a chained slot"
        );
        let g = self.dims;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let p = self.prompt_len;
        assert_eq!(
            k.len(),
            l_n * bs * h_n * p * d,
            "prefill KV must be [L, bs={bs}, H, P={p}, dh]"
        );
        // precomputed stride walk: the src head-stride equals the span
        // (heads are adjacent in [L, bs, H, P, dh]), so only the dst
        // pointer needs a wider step; no index math in the inner loop
        let span = p * d;
        let src_l = bs * h_n * span;
        let dst_h = s_n * d;
        let dst_l = h_n * dst_h;
        let mut src_row = lane * h_n * span;
        let mut dst_row = self.base(id);
        for _l in 0..l_n {
            let mut src = src_row;
            let mut dst = dst_row;
            for _h in 0..h_n {
                self.k[dst..dst + span].copy_from_slice(&k[src..src + span]);
                self.v[dst..dst + span].copy_from_slice(&v[src..src + span]);
                src += span;
                dst += dst_h;
            }
            src_row += src_l;
            dst_row += dst_l;
        }
        self.cache_lens[id.0] = p;
    }

    /// Commit a finalized block's KV for one lane. `k_blk`/`v_blk` are
    /// [L, bs, H, B, dh]; the block appends in place at the slot's
    /// current cache_len, which advances by `blk` (exact append-only
    /// caching — no other slab region is touched).
    pub fn commit_block(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        blk: usize,
        k_blk: &[f32],
        v_blk: &[f32],
    ) {
        let g = self.dims;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let pos = self.cache_lens[id.0];
        assert!(pos + blk <= s_n, "cache overflow: {pos} + {blk} > {s_n}");
        debug_assert!(
            self.chains[id.0].is_empty() || pos >= self.prompt_len,
            "commit into the shared prefix of a chained slot"
        );
        // same stride walk as write_prefill: src heads are adjacent
        // blk*d spans, dst heads step by a full sequence row
        let span = blk * d;
        let src_l = bs * h_n * span;
        let dst_h = s_n * d;
        let dst_l = h_n * dst_h;
        let mut src_row = lane * h_n * span;
        let mut dst_row = self.base(id) + pos * d;
        for _l in 0..l_n {
            let mut src = src_row;
            let mut dst = dst_row;
            for _h in 0..h_n {
                self.k[dst..dst + span]
                    .copy_from_slice(&k_blk[src..src + span]);
                self.v[dst..dst + span]
                    .copy_from_slice(&v_blk[src..src + span]);
                src += span;
                dst += dst_h;
            }
            src_row += src_l;
            dst_row += dst_l;
        }
        self.cache_lens[id.0] = pos + blk;
    }

    /// Direct write of full-sequence KV (approximate-cache baselines):
    /// overwrite the slot with the stale full-sequence stacks
    /// [L, bs, H, S, dh] and mark the whole sequence resident.
    pub fn write_full(
        &mut self,
        id: SlotId,
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
    ) {
        debug_assert!(
            self.chains[id.0].is_empty(),
            "write_full into a chained slot"
        );
        let g = self.dims;
        let (l_n, h_n, s_n, d) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let row = h_n * s_n * d;
        let base = self.base(id);
        if bs == 1 {
            // a single-lane [L, 1, H, S, dh] stack is layout-identical
            // to the slot's [L, H, S, dh]: one slot-sized memcpy
            let n = l_n * row;
            self.k[base..base + n].copy_from_slice(&k[..n]);
            self.v[base..base + n].copy_from_slice(&v[..n]);
        } else {
            let src_l = bs * row;
            let mut src = lane * row;
            let mut dst = base;
            for _l in 0..l_n {
                self.k[dst..dst + row].copy_from_slice(&k[src..src + row]);
                self.v[dst..dst + row].copy_from_slice(&v[src..src + row]);
                src += src_l;
                dst += row;
            }
        }
        self.cache_lens[id.0] = s_n;
    }

    // -----------------------------------------------------------------
    // Shared-prefix chains
    // -----------------------------------------------------------------

    /// Walk the trie for `prompt` under `tag` and return the resident
    /// node path for its longest block-aligned prefix (no pinning).
    fn match_prefix(&self, tag: u64, prompt: &[i32]) -> Vec<usize> {
        let mut path = Vec::new();
        let mut kids: &[usize] =
            self.roots.get(&tag).map(Vec::as_slice).unwrap_or(&[]);
        for blk in prompt.chunks(self.page_len) {
            let found = kids.iter().copied().find(|&n| {
                self.nodes[n]
                    .as_ref()
                    .expect("indexed chain node resident")
                    .tokens
                    == blk
            });
            let Some(next) = found else { break };
            path.push(next);
            kids = &self.nodes[next]
                .as_ref()
                .expect("indexed chain node resident")
                .children;
        }
        path
    }

    /// Pin the full-prompt chain for `prompt` if every block is
    /// resident: the warm-hit path that lets admission skip prefill
    /// entirely. With `need_ar_tok`, a chain lacking a cached
    /// first-token proposal reports as a miss (nothing is pinned).
    pub fn prefix_acquire_full(
        &mut self,
        tag: u64,
        prompt: &[i32],
        need_ar_tok: bool,
    ) -> Option<ChainPin> {
        debug_assert_eq!(prompt.len(), self.prompt_len);
        let path = self.match_prefix(tag, prompt);
        if path.len() < self.prompt_pages {
            return None;
        }
        let leaf = *path.last().expect("prompt has at least one block");
        let ar_tok =
            self.nodes[leaf].as_ref().expect("chain node resident").ar_tok;
        if need_ar_tok && ar_tok.is_none() {
            return None;
        }
        self.lru_tick += 1;
        let tick = self.lru_tick;
        for &n in &path {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            node.refs += 1;
            node.tick = tick;
        }
        self.prefix_hits += 1;
        self.prefix_hit_blocks += path.len() as u64;
        Some(ChainPin { nodes: path, ar_tok })
    }

    /// Install (and pin) the full-prompt chain for `prompt` from a
    /// prefill output: resident blocks are reused (copy-on-write — the
    /// trie branches at the first divergent block and nothing shared is
    /// overwritten), missing blocks get fresh pages written from the
    /// batch-major `[L, bs, H, P, dh]` prefill K/V. Fails without side
    /// effects when the page budget cannot cover the uncached tail even
    /// after LRU eviction; callers then fall back to a private-slot
    /// prefill.
    #[allow(clippy::too_many_arguments)]
    pub fn prefix_install(
        &mut self,
        tag: u64,
        prompt: &[i32],
        lane: usize,
        bs: usize,
        k: &[f32],
        v: &[f32],
        ar_tok: Option<i32>,
    ) -> Result<ChainPin> {
        debug_assert_eq!(prompt.len(), self.prompt_len);
        let matched = self.match_prefix(tag, prompt);
        // pin the matched prefix first so eviction (below) can't
        // reclaim it while we make room for the tail
        self.lru_tick += 1;
        let tick = self.lru_tick;
        for &n in &matched {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            node.refs += 1;
            node.tick = tick;
        }
        let needed = self.prompt_pages - matched.len();
        if !self.ensure_pages(needed) {
            for &n in &matched {
                let node =
                    self.nodes[n].as_mut().expect("chain node resident");
                node.refs -= 1;
            }
            anyhow::bail!(
                "prefix cache full: {needed} pages unavailable \
                 (all resident chains pinned)"
            );
        }
        self.prefix_hit_blocks += matched.len() as u64;
        let mut path = matched;
        for bi in path.len()..self.prompt_pages {
            let page = self
                .page_free
                .pop()
                .expect("ensure_pages reserved the tail");
            debug_assert!(!self.page_used[page]);
            self.page_used[page] = true;
            self.write_page(page, lane, bs, bi, k, v);
            let tokens =
                prompt[bi * self.page_len..(bi + 1) * self.page_len].to_vec();
            let node = ChainNode {
                tag,
                tokens,
                parent: path.last().copied(),
                children: Vec::new(),
                page,
                refs: 1,
                tick,
                ar_tok: None,
            };
            let idx = match self.node_free.pop() {
                Some(i) => {
                    self.nodes[i] = Some(node);
                    i
                }
                None => {
                    self.nodes.push(Some(node));
                    self.nodes.len() - 1
                }
            };
            match path.last() {
                Some(&p) => self.nodes[p]
                    .as_mut()
                    .expect("chain node resident")
                    .children
                    .push(idx),
                None => self.roots.entry(tag).or_default().push(idx),
            }
            path.push(idx);
        }
        let leaf = *path.last().expect("prompt has at least one block");
        if ar_tok.is_some() {
            self.nodes[leaf]
                .as_mut()
                .expect("chain node resident")
                .ar_tok = ar_tok;
        }
        let ar_tok =
            self.nodes[leaf].as_ref().expect("chain node resident").ar_tok;
        Ok(ChainPin { nodes: path, ar_tok })
    }

    /// Attach a pinned chain to a live slot: the slot now reads its
    /// prompt positions from the shared pages (its prompt region is
    /// never written) and [`KvPool::free`] will unpin the chain when
    /// the lane retires.
    pub fn attach_chain(&mut self, id: SlotId, pin: ChainPin) {
        assert!(self.used[id.0], "attach_chain to a free slot");
        assert!(self.chains[id.0].is_empty(), "slot already has a chain");
        self.chains[id.0] = pin.nodes;
        self.cache_lens[id.0] = self.prompt_len;
    }

    /// Release a pin without attaching it to a slot (admission error
    /// paths).
    pub fn release_pin(&mut self, pin: ChainPin) {
        for n in pin.nodes {
            let node = self.nodes[n].as_mut().expect("chain node resident");
            debug_assert!(node.refs > 0, "release of an unpinned chain node");
            node.refs -= 1;
        }
    }

    /// Diagnostic/test accessor: `(resident blocks, min refcount along
    /// the resident path)` for a prompt's longest cached prefix.
    pub fn prefix_chain_info(
        &self,
        tag: u64,
        prompt: &[i32],
    ) -> Option<(usize, usize)> {
        let path = self.match_prefix(tag, prompt);
        if path.is_empty() {
            return None;
        }
        let min_refs = path
            .iter()
            .map(|&n| {
                self.nodes[n].as_ref().expect("chain node resident").refs
            })
            .min()
            .expect("non-empty path");
        Some((path.len(), min_refs))
    }

    /// Make at least `needed` pages available on the free list,
    /// evicting LRU unpinned chain leaves if necessary. Returns false
    /// (with eviction partially done — evicted chains were reclaimable
    /// by definition) when pressure cannot be relieved.
    fn ensure_pages(&mut self, needed: usize) -> bool {
        while self.page_free.len() < needed {
            if !self.evict_one() {
                return false;
            }
        }
        true
    }

    /// Evict the least-recently-used unpinned chain leaf. Interior
    /// nodes become leaves once their children go, so repeated calls
    /// reclaim whole chains back-to-front; pinned nodes (refs > 0) are
    /// never candidates.
    fn evict_one(&mut self) -> bool {
        let victim = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
            .filter(|(_, n)| n.refs == 0 && n.children.is_empty())
            .min_by_key(|(_, n)| n.tick)
            .map(|(i, _)| i);
        let Some(i) = victim else { return false };
        let node = self.nodes[i].take().expect("victim resident");
        match node.parent {
            Some(p) => {
                let kids = &mut self.nodes[p]
                    .as_mut()
                    .expect("parent of resident node resident")
                    .children;
                kids.retain(|&c| c != i);
            }
            None => {
                if let Some(kids) = self.roots.get_mut(&node.tag) {
                    kids.retain(|&c| c != i);
                }
            }
        }
        assert!(self.page_used[node.page], "double free of KV page");
        self.page_used[node.page] = false;
        self.page_free.push(node.page);
        self.node_free.push(i);
        self.prefix_evictions += 1;
        true
    }

    /// Write prompt block `bi` of one lane's batch-major
    /// `[L, bs, H, P, dh]` prefill output into a page.
    fn write_page(
        &mut self,
        page: usize,
        lane: usize,
        bs: usize,
        bi: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let g = self.dims;
        let (l_n, h_n, d) = (g.n_layers, g.n_heads, g.d_head);
        let p = self.prompt_len;
        let pl = self.page_len;
        debug_assert_eq!(
            k.len(),
            l_n * bs * h_n * p * d,
            "prefill KV must be [L, bs={bs}, H, P={p}, dh]"
        );
        let base = self.page_base(page);
        for l in 0..l_n {
            for h in 0..h_n {
                let src = (((l * bs + lane) * h_n + h) * p + bi * pl) * d;
                let dst = base + (l * h_n + h) * pl * d;
                self.k[dst..dst + pl * d]
                    .copy_from_slice(&k[src..src + pl * d]);
                self.v[dst..dst + pl * d]
                    .copy_from_slice(&v[src..src + pl * d]);
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn affinity_hash_is_block_aligned_and_stable() {
        let a = [1, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(
            prefix_affinity_hash(&a, 4),
            prefix_affinity_hash(&a, 4),
            "deterministic"
        );
        // a difference past the last full block boundary is invisible
        let ragged = [1, 2, 3, 4, 5, 6, 7];
        let mut ragged_tail = ragged;
        ragged_tail[6] = 99; // index 6 is past the 4-aligned boundary
        assert_eq!(
            prefix_affinity_hash(&ragged, 4),
            prefix_affinity_hash(&ragged_tail, 4),
            "trailing partial block must not split affinity"
        );
        // a difference inside the aligned prefix changes the hash
        let mut c = a;
        c[0] = 99;
        assert_ne!(prefix_affinity_hash(&a, 4), prefix_affinity_hash(&c, 4));
        // block_size 0 degrades to hashing the whole prompt
        assert_ne!(
            prefix_affinity_hash(&a, 0),
            prefix_affinity_hash(&a[..7], 0)
        );
    }

    fn geom() -> Geometry {
        Geometry {
            vocab_size: 64,
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            prompt_len: 4,
            gen_len: 4,
            block_size: 2,
            seq_len: 8,
            pad: 0,
            mask: 1,
            bos: 2,
            eos: 3,
        }
    }

    /// Distinct batch-major [L, bs=1, H, P, dh] prefill stacks.
    fn prefill_kv(g: &Geometry, salt: f32) -> (Vec<f32>, Vec<f32>) {
        let n = g.n_layers * g.n_heads * g.prompt_len * g.d_head;
        let k: Vec<f32> = (0..n).map(|i| salt + i as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        (k, v)
    }

    #[test]
    fn alloc_free_cycle() {
        let mut p = KvPool::new(&geom(), 2);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert!(p.alloc().is_err(), "capacity enforced");
        p.free(a);
        let c = p.alloc().unwrap();
        assert_eq!(p.in_use(), 2);
        p.free(b);
        p.free(c);
        assert_eq!(p.in_use(), 0);
        assert_eq!(p.peak_in_use, 2);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = KvPool::new(&geom(), 1);
        let a = p.alloc().unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_of_chained_slot_panics() {
        // the double-free guard must keep firing for chained slots: a
        // second free would otherwise unpin the chain twice
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 1, 2);
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin =
            pool.prefix_install(9, &[5, 6, 7, 8], 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(a, pin);
        pool.free(a);
        pool.free(a);
    }

    #[test]
    fn prefill_commit_view_roundtrip() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let id = pool.alloc().unwrap();
        let (l_n, h_n, d, p, blk) = (2usize, 2usize, 4usize, 4usize, 2usize);
        let bs = 1;
        // distinct values per (l, h, pos, d)
        let kp: Vec<f32> = (0..l_n * bs * h_n * p * d).map(|i| i as f32).collect();
        let vp: Vec<f32> = kp.iter().map(|x| x + 0.5).collect();
        pool.write_prefill(id, 0, bs, &kp, &vp);
        assert_eq!(pool.cache_len(id), p);

        let kb: Vec<f32> =
            (0..l_n * bs * h_n * blk * d).map(|i| 1000.0 + i as f32).collect();
        let vb: Vec<f32> = kb.iter().map(|x| x + 0.5).collect();
        pool.commit_block(id, 0, bs, blk, &kb, &vb);
        assert_eq!(pool.cache_len(id), p + blk);

        let view = pool.view(&[id], p + blk);
        // prompt l=0, h=0, pos=0..4 is the front of the prefill input
        for pos in 0..p {
            for f in 0..d {
                assert_eq!(view.k_at(0, 0, 0, pos, f), (pos * d + f) as f32);
                assert_eq!(view.v_at(0, 0, 0, pos, f), (pos * d + f) as f32 + 0.5);
            }
        }
        // committed block lands at pos = p.. for l=0, h=0
        for i in 0..blk {
            for f in 0..d {
                assert_eq!(
                    view.k_at(0, 0, 0, p + i, f),
                    1000.0 + (i * d + f) as f32
                );
            }
        }
    }

    #[test]
    fn view_respects_lane_order() {
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let n = 2 * 2 * 4 * 4; // [L, bs=1, H, P, dh]
        pool.write_prefill(a, 0, 1, &vec![1.0; n], &vec![1.0; n]);
        pool.write_prefill(b, 0, 1, &vec![2.0; n], &vec![2.0; n]);
        let view = pool.view(&[b, a], 4);
        assert_eq!(view.bs(), 2);
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 2.0, "lane 0 is slot b");
        assert_eq!(view.k_at(1, 0, 0, 0, 0), 1.0, "lane 1 is slot a");
        // batch-major materialization places lane rows correctly
        let (bk, _) = view.to_batch_major();
        let row = 2 * 8 * 4; // [H, S, dh]
        assert_eq!(bk.data[0], 2.0);
        assert_eq!(bk.data[row], 1.0);
    }

    #[test]
    fn property_pool_never_leaks_or_double_allocs() {
        check("kv-pool-invariants", 50, |r| {
            let mut pool = KvPool::new(&geom(), 4);
            let mut held: Vec<SlotId> = Vec::new();
            for _ in 0..100 {
                if r.below(2) == 0 && !held.is_empty() {
                    let i = r.index(held.len());
                    pool.free(held.swap_remove(i));
                } else if pool.in_use() < pool.capacity() {
                    let id = pool.alloc().unwrap();
                    if held.contains(&id) {
                        return false; // double-alloc!
                    }
                    held.push(id);
                }
                if pool.in_use() != held.len() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn mid_batch_recycle_resets_slot_state() {
        // continuous batching: a retired lane's slot is freed while the
        // pool is live and handed to the next admission with a clean
        // cache_len, leaving sibling slots untouched
        let g = geom();
        let mut pool = KvPool::new(&g, 2);
        let keep = pool.alloc().unwrap();
        let retire = pool.alloc().unwrap();
        let n = 2 * 2 * 4 * 4; // [L, bs=1, H, P, dh]
        pool.write_prefill(keep, 0, 1, &vec![7.0; n], &vec![7.0; n]);
        pool.write_prefill(retire, 0, 1, &vec![9.0; n], &vec![9.0; n]);
        pool.free(retire);
        let admitted = pool.alloc().unwrap();
        assert_eq!(pool.cache_len(admitted), 0, "recycled slot starts fresh");
        assert_eq!(pool.cache_len(keep), 4, "sibling lane unaffected");
        assert_eq!(pool.total_allocs, 3, "lifetime allocs count recycling");
        let view = pool.view(&[keep], 4);
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 7.0);
    }

    #[test]
    fn write_full_marks_whole_sequence() {
        let g = geom();
        let mut pool = KvPool::new(&g, 1);
        let id = pool.alloc().unwrap();
        let n = 2 * 2 * 8 * 4;
        pool.write_full(id, 0, 1, &vec![3.0; n], &vec![3.0; n]);
        assert_eq!(pool.cache_len(id), g.seq_len);
        let view = pool.view(&[id], g.seq_len);
        assert_eq!(view.k_at(0, 1, 1, 7, 3), 3.0);
    }

    // -----------------------------------------------------------------
    // Shared-prefix chains
    // -----------------------------------------------------------------

    #[test]
    fn install_then_full_hit_reads_identical_kv() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);

        // cold: install writes 2 pages and pins the chain on slot a
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(a, pin);
        assert_eq!(pool.cache_len(a), g.prompt_len);
        assert_eq!(pool.prefix_resident_pages(), 2);
        assert_eq!(pool.prefix_hits, 0);

        // warm: a second lane full-hits and shares the same pages
        let b = pool.alloc().unwrap();
        let pin = pool.prefix_acquire_full(9, &prompt, false).unwrap();
        pool.attach_chain(b, pin);
        assert_eq!(pool.prefix_hits, 1);
        assert_eq!(pool.prefix_hit_blocks, 2);
        assert_eq!(pool.prefix_resident_pages(), 2, "no new pages on a hit");
        assert_eq!(pool.prefix_chain_info(9, &prompt), Some((2, 2)));

        // both lanes read the prefill content through their views
        let view = pool.view(&[a, b], g.prompt_len);
        for lane in 0..2 {
            for l in 0..g.n_layers {
                for h in 0..g.n_heads {
                    for pos in 0..g.prompt_len {
                        for f in 0..g.d_head {
                            let src = (((l * g.n_heads) + h) * g.prompt_len
                                + pos)
                                * g.d_head
                                + f;
                            assert_eq!(view.k_at(lane, l, h, pos, f), k[src]);
                            assert_eq!(view.v_at(lane, l, h, pos, f), v[src]);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn divergent_prompt_branches_instead_of_overwriting() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let p1 = vec![5, 6, 7, 8];
        let mut p2 = p1.clone();
        p2[2] = 9; // diverges at block 1 (page_len = 2)
        let (k1, v1) = prefill_kv(&g, 0.0);
        let (k2, v2) = prefill_kv(&g, 100.0);

        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p1, 0, 1, &k1, &v1, None).unwrap();
        pool.attach_chain(a, pin);
        let b = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p2, 0, 1, &k2, &v2, None).unwrap();
        pool.attach_chain(b, pin);

        // block 0 shared (copy-on-write: only the divergent tail is new)
        assert_eq!(pool.prefix_resident_pages(), 3);
        assert_eq!(pool.prefix_hit_blocks, 1);
        assert_eq!(pool.prefix_chain_info(9, &p1), Some((2, 1)));
        assert_eq!(pool.prefix_chain_info(9, &p2), Some((2, 1)));

        // lane a still reads p1's original block-1 KV (nothing was
        // overwritten); lane b reads its own divergent block
        let view = pool.view(&[a, b], g.prompt_len);
        let src = 2 * g.d_head; // (l=0, h=0, pos=2, f=0) in [L,1,H,P,dh]
        assert_eq!(view.k_at(0, 0, 0, 2, 0), k1[src]);
        assert_eq!(view.k_at(1, 0, 0, 2, 0), k2[src]);
        // the shared block reads the first installer's content for both
        assert_eq!(view.k_at(0, 0, 0, 0, 0), k1[0]);
        assert_eq!(view.k_at(1, 0, 0, 0, 0), k1[0]);
    }

    #[test]
    fn tags_isolate_models() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(1, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(a, pin);
        assert!(pool.prefix_acquire_full(2, &prompt, false).is_none());
        assert!(pool.prefix_chain_info(2, &prompt).is_none());
    }

    #[test]
    fn retirement_unpins_and_eviction_spares_pinned_chains() {
        let g = geom();
        // page budget: exactly one prompt's worth
        let mut pool = KvPool::with_prefix_pages(&g, 2, 2);
        let p1 = vec![5, 6, 7, 8];
        let p2 = vec![10, 11, 12, 13];
        let (k, v) = prefill_kv(&g, 0.0);

        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p1, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(a, pin);

        // p1's chain is pinned: installing p2 must fail, not evict it
        let b = pool.alloc().unwrap();
        assert!(
            pool.prefix_install(9, &p2, 0, 1, &k, &v, None).is_err(),
            "eviction must never free a pinned chain"
        );
        assert_eq!(pool.prefix_evictions, 0);
        assert_eq!(pool.prefix_chain_info(9, &p1), Some((2, 1)), "p1 intact");
        // the failed install leaves no dangling pins
        pool.free(b);

        // retiring lane a unpins; the retained chain is now evictable
        pool.free(a);
        assert_eq!(pool.prefix_chain_info(9, &p1), Some((2, 0)));
        let b = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p2, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(b, pin);
        assert_eq!(pool.prefix_evictions, 2, "p1's two pages reclaimed");
        assert!(pool.prefix_chain_info(9, &p1).is_none(), "p1 evicted");
        assert_eq!(pool.prefix_chain_info(9, &p2), Some((2, 1)));
    }

    #[test]
    fn ar_tok_gates_full_hits_when_required() {
        let g = geom();
        let mut pool = KvPool::with_prefix_pages(&g, 2, 8);
        let prompt = vec![5, 6, 7, 8];
        let (k, v) = prefill_kv(&g, 0.0);
        let a = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &prompt, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(a, pin);
        // DLM chain has no cached first token: AR-style lookups miss…
        assert!(pool.prefix_acquire_full(9, &prompt, true).is_none());
        // …until an install caches one on the leaf
        let pin = pool
            .prefix_install(9, &prompt, 0, 1, &k, &v, Some(42))
            .unwrap();
        pool.release_pin(pin);
        let pin = pool.prefix_acquire_full(9, &prompt, true).unwrap();
        assert_eq!(pin.ar_tok, Some(42));
        pool.release_pin(pin);
    }

    #[test]
    fn lru_evicts_coldest_chain_first() {
        let g = geom();
        // room for two prompts' worth of pages
        let mut pool = KvPool::with_prefix_pages(&g, 1, 4);
        let (k, v) = prefill_kv(&g, 0.0);
        let p1 = vec![5, 6, 7, 8];
        let p2 = vec![10, 11, 12, 13];
        let p3 = vec![20, 21, 22, 23];
        for p in [&p1, &p2] {
            let s = pool.alloc().unwrap();
            let pin = pool.prefix_install(9, p, 0, 1, &k, &v, None).unwrap();
            pool.attach_chain(s, pin);
            pool.free(s);
        }
        // touch p1 so p2 is the LRU chain
        let s = pool.alloc().unwrap();
        let pin = pool.prefix_acquire_full(9, &p1, false).unwrap();
        pool.attach_chain(s, pin);
        pool.free(s);
        // p3 needs two pages: p2 (coldest, unpinned) is reclaimed
        let s = pool.alloc().unwrap();
        let pin = pool.prefix_install(9, &p3, 0, 1, &k, &v, None).unwrap();
        pool.attach_chain(s, pin);
        pool.free(s);
        assert!(pool.prefix_chain_info(9, &p1).is_some(), "warm chain kept");
        assert!(pool.prefix_chain_info(9, &p2).is_none(), "cold chain evicted");
        assert!(pool.prefix_chain_info(9, &p3).is_some());
    }
}
