//! Request router + serving core.
//!
//! All backend state (runtime, weights, KV pool, metrics) lives in one
//! `ServingCore` owned by the decode-worker thread; HTTP handler
//! threads and the CLI talk to it purely through channels.
//!
//! The worker runs **continuous batching** by default: queued requests
//! open a resumable block-step batch ([`ActiveBatch`] over
//! `methods::machine::BatchState`) immediately, every live batch
//! advances one block per loop iteration, lanes that finalize `<eos>`
//! are retired and answered mid-batch (their KV slot recycles on the
//! spot), and compatible queued requests are admitted into freed lanes
//! at block boundaries via a bucket-1 prefill — iteration-level
//! scheduling instead of request-level. The classic closed-batch path
//! (dynamic batcher windows + run-to-completion groups, the PR 2
//! behavior) remains reachable with `RouterConfig::continuous = false`
//! and serves as the serving-bench baseline.
//!
//! Per-request tau never leaks across requests: the continuous machine
//! carries tau per lane, and the closed-batch path folds the override
//! into the batching [`GroupKey`] so mixed-tau requests never share a
//! lockstep group.
//!
//! **The lane-event pipeline.** A request is no longer a one-shot
//! `(ticket -> outcome)` round trip: `Router::submit` returns a
//! [`ResponseHandle`] over a per-request [`LaneEvent`] channel —
//! `Admitted` when the lane enters a batch, one `Committed` per
//! finalized block (incrementally detokenized delta), and exactly one
//! terminal `Finished`/`Aborted`. The same handle carries control the
//! other way: an explicit [`ResponseHandle::cancel`], a per-request
//! deadline, or a `max_new_tokens` budget retires the lane at the next
//! block boundary, freeing its KV slot and unpinning its prefix chain
//! immediately so queued work can take the lane; dropping the handle
//! (a disconnected client) is detected on the next `Committed` send
//! and cancels the same way. Expired requests are refused *before*
//! admission (`DynamicBatcher::take_for`) so a dead client never costs
//! a prefill. `/healthz` counts both: `aborted_queued` /
//! `aborted_inflight`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{DynamicBatcher, GroupKey, Pending};
use super::kv_cache::KvPool;
use super::methods::machine::{BatchState, CommitRun};
use super::methods::{DecodeOpts, DecodeOutcome, Method};
use super::metrics::{AbortRecord, MetricsAggregator, RequestRecord};
use super::scheduler::{ActiveBatch, Engine};
use crate::runtime::{Geometry, ModelWeights, Runtime};
use crate::tokenizer::{StreamDecoder, Tokenizer};
use crate::util::json::{self, Json};
use crate::util::threadpool;

// ---------------------------------------------------------------------------
// ServingCore: single-threaded owner of all backend state
// ---------------------------------------------------------------------------

pub struct ServingCore {
    pub rt: Arc<Runtime>,
    pub tokenizer: Tokenizer,
    weights: HashMap<String, Arc<ModelWeights>>,
    pub pool: KvPool,
    pub metrics: HashMap<String, MetricsAggregator>,
}

impl ServingCore {
    pub fn load(artifacts: &Path, pool_capacity: usize) -> Result<Self> {
        let rt = Runtime::load(artifacts)?;
        let tokenizer = Tokenizer::new();
        // cross-language vocab pin: a real artifacts directory MUST
        // carry a matching vocab.json (a missing one is a broken
        // export, not a skip); only the built-in reference manifest
        // uses the compiled-in vocab directly.
        if artifacts.join("manifest.json").exists() {
            tokenizer.verify_against(&json::load(&artifacts.join("vocab.json"))?)?;
        }
        let pool = KvPool::new(&rt.manifest.geometry, pool_capacity);
        Ok(Self {
            rt: Arc::new(rt),
            tokenizer,
            weights: HashMap::new(),
            pool,
            metrics: HashMap::new(),
        })
    }

    pub fn geometry(&self) -> &Geometry {
        &self.rt.manifest.geometry
    }

    /// Load (once) and share a model's weights. The `Arc` lets
    /// long-lived block-step machines hold the weights while the core
    /// keeps loading others.
    fn ensure_weights(&mut self, model: &str) -> Result<Arc<ModelWeights>> {
        if !self.weights.contains_key(model) {
            let w = ModelWeights::load(&self.rt.manifest, model)?;
            // §Perf: backends with a host/device split make the
            // weights device-resident for the model's lifetime here;
            // the reference backend treats this as a no-op
            w.upload(&self.rt)?;
            self.weights.insert(model.to_string(), Arc::new(w));
        }
        Ok(self.weights[model].clone())
    }

    /// Open a resumable block-step batch for one group key.
    pub fn open_batch(
        &mut self,
        key: &GroupKey,
        opts: DecodeOpts,
        capacity: usize,
    ) -> Result<BatchState> {
        let model = key.method.weights_for(&key.backbone);
        let weights = self.ensure_weights(&model)?;
        BatchState::new(self.rt.clone(), weights, key.method, opts, capacity)
    }

    /// Decode one lockstep group to completion (benches/examples call
    /// this directly; the closed-batch worker calls it from its
    /// thread).
    pub fn decode_group(
        &mut self,
        key: &GroupKey,
        prompts: &[Vec<i32>],
        opts: &DecodeOpts,
    ) -> Result<Vec<DecodeOutcome>> {
        let model = key.method.weights_for(&key.backbone);
        let weights = self.ensure_weights(&model)?;
        let engine = Engine::new(&self.rt, &weights);
        let outcomes = engine.decode(key.method, opts, prompts, &mut self.pool)?;
        self.record_group(key, &outcomes);
        Ok(outcomes)
    }

    /// Fold one outcome into the per-(backbone, method) metrics.
    fn record_outcome(&mut self, key: &GroupKey, o: &DecodeOutcome) {
        let agg = self
            .metrics
            .entry(format!("{}/{}", key.backbone, key.method.name()))
            .or_default();
        agg.record(&RequestRecord {
            latency: o.latency,
            steps: o.steps,
            model_calls: o.model_calls,
            gen_len: o.gen_len,
            correct: None,
        });
    }

    /// Fold a cancelled lane's wasted work into the per-(backbone,
    /// method) metrics (kept out of the §A.3 per-sample averages).
    fn record_abort(&mut self, key: &GroupKey, r: &AbortRecord) {
        self.metrics
            .entry(format!("{}/{}", key.backbone, key.method.name()))
            .or_default()
            .record_abort(r);
    }

    /// Fold a group's outcomes into the per-(backbone, method) metrics.
    fn record_group(&mut self, key: &GroupKey, outcomes: &[DecodeOutcome]) {
        for o in outcomes {
            self.record_outcome(key, o);
        }
    }

    pub fn metrics_json(&self) -> Json {
        Json::Obj(
            self.metrics
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Router: channel front-end + decode worker thread
// ---------------------------------------------------------------------------

pub struct GenerateRequest {
    pub backbone: String,
    pub method: Method,
    pub prompt_ids: Vec<i32>,
    pub tau_conf: Option<f32>,
    /// Wall-clock budget measured from submission. An expired request
    /// is refused before it costs anything — at admission on the
    /// continuous path, at group dispatch on the closed-batch path —
    /// and an admitted continuous lane is cancelled at the next block
    /// boundary.
    pub timeout: Option<Duration>,
    /// Generation budget: the lane retires with a normal `Finished`
    /// (truncated) response at the first block boundary where at least
    /// this many tokens have been *delivered* (post-`<eos>` dead
    /// refinement never charges it). Needs block-boundary cancellation,
    /// so the closed-batch worker (run-to-completion groups) ignores
    /// it.
    pub max_new_tokens: Option<usize>,
}

impl GenerateRequest {
    pub fn new(
        backbone: impl Into<String>,
        method: Method,
        prompt_ids: Vec<i32>,
    ) -> Self {
        Self {
            backbone: backbone.into(),
            method,
            prompt_ids,
            tau_conf: None,
            timeout: None,
            max_new_tokens: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub gen_ids: Vec<i32>,
    pub text: String,
    pub steps: u64,
    pub model_calls: u64,
    /// Decode time (§A.3: starts when the lane enters a batch).
    pub latency: Duration,
    /// Time from arrival to the first revealed token (queueing
    /// included).
    pub ttft: Duration,
    /// Time from arrival to the full response (queueing included).
    pub ttlt: Duration,
    pub gen_len: usize,
}

/// One hop of a request's life, streamed over its per-request channel.
/// The sequence is always `Admitted?` · `Committed*` · exactly one
/// terminal (`Finished` | `Aborted`); a request that never reaches a
/// lane (queue rejection at submit is an `Err` from `submit` itself;
/// queued-deadline expiry, shutdown, load-failure) goes straight to
/// `Aborted`.
#[derive(Debug, Clone)]
pub enum LaneEvent {
    /// The request entered a batch lane (admission prefill done).
    Admitted,
    /// One block's worth of tokens finalized. `text` is the
    /// incrementally detokenized delta: concatenating every `text` of a
    /// request reproduces the terminal response's `text` byte-for-byte
    /// (`tests/streaming.rs` pins this for all six methods). `tokens`
    /// counts the tokens this delta delivers (specials and anything
    /// at/after the stream's first `<eos>` excluded — dead post-`<eos>`
    /// refinement charges nothing); `block` is the 0-based ordinal of
    /// the event within its request.
    Committed { block: usize, text: String, tokens: usize },
    /// Terminal: the lane decoded to completion (or hit its
    /// `max_new_tokens` budget — a truncated but successful response).
    Finished(GenerateResponse),
    /// Terminal: the request was cancelled or failed. The counters
    /// carry whatever work the lane burned before retiring (zero when
    /// it never reached a lane).
    Aborted {
        reason: String,
        steps: u64,
        model_calls: u64,
        committed_tokens: usize,
    },
}

/// Client-side control half of the event pipeline: shared with the
/// worker, checked at every block boundary.
#[derive(Debug)]
pub struct RequestCtl {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
    max_new_tokens: Option<usize>,
}

impl RequestCtl {
    fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }
}

/// The caller's end of one request's event pipeline. Read events with
/// [`next_event`] (streaming) or collapse to the terminal response with
/// [`wait`] (one-shot callers). [`cancel`] — or simply dropping the
/// handle — asks the worker to retire the lane at the next block
/// boundary, freeing its KV slot and prefix-chain pin for queued work.
///
/// [`next_event`]: ResponseHandle::next_event
/// [`wait`]: ResponseHandle::wait
/// [`cancel`]: ResponseHandle::cancel
pub struct ResponseHandle {
    rx: mpsc::Receiver<LaneEvent>,
    ctl: Arc<RequestCtl>,
}

impl ResponseHandle {
    /// Next lane event; `None` once the channel closes (after the
    /// terminal event, or if the worker died).
    pub fn next_event(&self) -> Option<LaneEvent> {
        self.rx.recv().ok()
    }

    /// Drain to the terminal event: `Finished -> Ok`, `Aborted -> Err`.
    pub fn wait(&self) -> Result<GenerateResponse, String> {
        loop {
            match self.rx.recv() {
                Ok(LaneEvent::Finished(resp)) => return Ok(resp),
                Ok(LaneEvent::Aborted { reason, .. }) => return Err(reason),
                Ok(_) => continue,
                Err(_) => return Err("worker dropped the request".into()),
            }
        }
    }

    /// Request cancellation. Asynchronous: the worker retires the lane
    /// at its next block boundary and answers with a terminal
    /// `Aborted`.
    pub fn cancel(&self) {
        self.ctl.cancelled.store(true, Ordering::Relaxed);
    }
}

type EventTx = mpsc::Sender<LaneEvent>;

/// A submitted request in flight toward a worker lane.
struct Submit {
    req: GenerateRequest,
    events: EventTx,
    ctl: Arc<RequestCtl>,
    /// Stamped at `Router::submit`, so TTFT/TTLT include the time a
    /// message waits in the channel while the worker decodes.
    submitted: Instant,
}

impl Submit {
    /// Terminal abort for a request that never reached a lane.
    fn abort(&self, reason: &str) {
        let _ = self.events.send(LaneEvent::Aborted {
            reason: reason.to_string(),
            steps: 0,
            model_calls: 0,
            committed_tokens: 0,
        });
    }
}

enum RouterMsg {
    Request(Box<Submit>),
    Metrics(mpsc::Sender<Json>),
    Health(mpsc::Sender<Json>),
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub max_queue: usize,
    /// KV slot budget. The closed-batch worker sizes the shared
    /// `ServingCore` pool with it; the continuous worker additionally
    /// treats it as the total-lane bound across live block-step
    /// batches (each lane holds at most one slot in its batch's own
    /// pool), so `--pool` caps KV memory on both paths.
    pub pool_capacity: usize,
    /// Iteration-level scheduling (default). `false` restores the
    /// closed-batch worker: batching windows + run-to-completion
    /// groups, no mid-flight admission — the serving-bench baseline.
    pub continuous: bool,
    /// Upper bound on concurrently live block-step batches (bounds KV
    /// memory: each batch owns a pool of `min(max_batch, max bucket)`
    /// slots).
    pub max_active: usize,
    /// Artificial pause before each block step (tests/demos use this to
    /// widen admission windows; zero in production).
    pub step_delay: Duration,
    /// Shared-prefix KV reuse (continuous path): admissions whose full
    /// prompt is cached skip their prefill call, and drained machines
    /// are retained as warm caches until a new key needs their room.
    /// `cdlm serve --no-prefix-cache` turns it off.
    pub prefix_cache: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait: Duration::from_millis(25),
            max_queue: 256,
            pool_capacity: 64,
            continuous: true,
            max_active: 4,
            step_delay: Duration::ZERO,
            prefix_cache: true,
        }
    }
}

pub struct Router {
    tx: mpsc::Sender<RouterMsg>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub geometry: Geometry,
    pub max_queue: usize,
    queued: Arc<AtomicUsize>,
    known_models: Vec<String>,
}

impl Router {
    /// Spawn the decode worker (which loads all backend state on its
    /// own thread) and wait for it to come up.
    pub fn start(artifacts: PathBuf, cfg: RouterConfig) -> Result<Router> {
        let (tx, rx) = mpsc::channel::<RouterMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Geometry, String>>();
        let queued = Arc::new(AtomicUsize::new(0));
        let wq = queued.clone();
        let wcfg = cfg.clone();
        let wartifacts = artifacts.clone();
        // the continuous worker decodes exclusively through per-batch
        // KV pools (pool_capacity bounds their total lanes); don't
        // also allocate the shared core pool it would never touch
        let core_pool = if cfg.continuous { 0 } else { cfg.pool_capacity };
        let worker = std::thread::Builder::new()
            .name("cdlm-decode-worker".into())
            .spawn(move || {
                let mut core =
                    match ServingCore::load(&wartifacts, core_pool) {
                        Ok(c) => {
                            let _ = ready_tx
                                .send(Ok(c.rt.manifest.geometry.clone()));
                            c
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                if wcfg.continuous {
                    worker_loop_continuous(&mut core, rx, wcfg, wq);
                } else {
                    worker_loop_closed(&mut core, rx, wcfg, wq);
                }
            })?;
        let geometry = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("serving core failed to load: {e}"))?;
        // Known model list comes from the manifest; re-read it cheaply
        // here so admission can reject unknown backbones without a
        // round-trip to the worker.
        let manifest = crate::runtime::Manifest::load_or_reference(&artifacts)?;
        Ok(Router {
            tx,
            worker: Some(worker),
            geometry,
            max_queue: cfg.max_queue,
            queued,
            known_models: manifest.models.iter().map(|(k, _)| k.clone()).collect(),
        })
    }

    /// Enqueue a request; returns the handle to its event pipeline.
    pub fn submit(&self, req: GenerateRequest) -> Result<ResponseHandle> {
        anyhow::ensure!(
            req.prompt_ids.len() == self.geometry.prompt_len,
            "prompt must be padded to {} tokens (got {})",
            self.geometry.prompt_len,
            req.prompt_ids.len()
        );
        let model = req.method.weights_for(&req.backbone);
        anyhow::ensure!(
            self.known_models.contains(&model),
            "unknown backbone '{}' for method '{}'",
            req.backbone,
            req.method.name()
        );
        // reserve-then-rollback: acting on the fetch_add result keeps
        // the bound exact under concurrent submits (a load-then-add
        // here would be the same racy RMW the worker's decrement had)
        let q = self.queued.fetch_add(1, Ordering::SeqCst);
        if q >= self.max_queue {
            self.queued.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!(
                "admission rejected: queue full ({q}/{})",
                self.max_queue
            );
        }
        let now = Instant::now();
        let ctl = Arc::new(RequestCtl {
            cancelled: AtomicBool::new(false),
            deadline: req.timeout.map(|t| now + t),
            max_new_tokens: req.max_new_tokens,
        });
        let (etx, erx) = mpsc::channel();
        let sub = Submit {
            req,
            events: etx,
            ctl: ctl.clone(),
            submitted: now,
        };
        if self.tx.send(RouterMsg::Request(Box::new(sub))).is_err() {
            // the request never reached the worker: release the permit
            // so a dead worker reports as such, not as a full queue
            self.queued.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("router worker is gone");
        }
        Ok(ResponseHandle { rx: erx, ctl })
    }

    pub fn metrics(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Metrics(tx))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rx.recv()?)
    }

    pub fn health(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Health(tx))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rx.recv()?)
    }

    /// Graceful drain: every request still in the system receives a
    /// terminal event — nothing is ever answered by a silently dropped
    /// channel. The continuous worker aborts queued requests and
    /// in-flight lanes with `Aborted { reason: "shutdown" }` (a
    /// streaming socket sees it as its terminal line) and frees their
    /// KV state immediately; the closed-batch worker instead decodes
    /// its remaining queue to completion (its groups are
    /// run-to-completion, so draining by finishing is the cheaper exit
    /// there). Then the worker exits.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(RouterMsg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Continuous worker: block-step machines + mid-flight admission
// ---------------------------------------------------------------------------

/// Per-lane response ticket: the lane's event channel, its control
/// block, arrival/admission instants (TTFT/TTLT accounting), and the
/// streaming state (incremental detokenizer + committed-token count the
/// generation budget is charged against).
struct Ticket {
    events: EventTx,
    ctl: Arc<RequestCtl>,
    enqueued: Instant,
    admitted: Instant,
    detok: StreamDecoder,
    committed_tokens: usize,
    blocks_committed: usize,
    /// The event channel came back disconnected (client dropped its
    /// handle): cancel the lane at the next block boundary.
    dead: bool,
}

impl Ticket {
    /// Split a queued submit into its lane ticket and the request to
    /// admit (the admission instant is stamped here).
    fn from_submit(sub: Submit) -> (Ticket, GenerateRequest) {
        (
            Ticket {
                events: sub.events,
                ctl: sub.ctl,
                enqueued: sub.submitted,
                admitted: Instant::now(),
                detok: StreamDecoder::new(),
                committed_tokens: 0,
                blocks_committed: 0,
                dead: false,
            },
            sub.req,
        )
    }
}

/// Why a lane leaves its batch early at a block boundary.
enum Cancel {
    /// Terminal `Aborted`: the work is wasted.
    Abort(&'static str),
    /// `max_new_tokens` reached: terminal `Finished` with the
    /// truncated-but-valid partial response.
    Budget,
}

/// The block-boundary cancellation policy, in priority order.
fn cancel_of(t: &Ticket, now: Instant) -> Option<Cancel> {
    if t.dead {
        return Some(Cancel::Abort("client disconnected"));
    }
    if t.ctl.is_cancelled() {
        return Some(Cancel::Abort("cancelled by client"));
    }
    if t.ctl.deadline.is_some_and(|d| now > d) {
        return Some(Cancel::Abort("deadline exceeded"));
    }
    if t.ctl.max_new_tokens.is_some_and(|m| t.committed_tokens >= m) {
        return Some(Cancel::Budget);
    }
    None
}

/// Serving counters surfaced on `/healthz`. Live batches report their
/// own admission counts; these fold in batches that already dropped
/// (poisoned, or reclaimed after draining).
#[derive(Default)]
struct ServeStats {
    closed_total_admissions: u64,
    closed_mid_flight: u64,
    closed_kv_allocs: u64,
    closed_prefix_hits: u64,
    closed_prefix_hit_blocks: u64,
    closed_prefix_evictions: u64,
    retired_early: u64,
    /// Requests terminated while still queued (deadline already expired
    /// or cancelled before a lane/prefill was ever spent on them).
    aborted_queued: u64,
    /// Lanes cancelled mid-decode (disconnect, deadline, explicit
    /// cancel, shutdown) — their KV slots and chain pins were reclaimed
    /// at the block boundary.
    aborted_inflight: u64,
}

impl ServeStats {
    /// Fold a batch's lifetime counters in before dropping it.
    fn absorb(&mut self, st: &BatchState) {
        self.closed_total_admissions += st.total_admissions;
        self.closed_mid_flight += st.mid_flight_admissions;
        self.closed_kv_allocs += st.kv_total_allocs();
        self.closed_prefix_hits += st.prefix_hits();
        self.closed_prefix_hit_blocks += st.prefix_hit_blocks();
        self.closed_prefix_evictions += st.prefix_evictions();
    }
}

/// KV lanes a batch draws from the `pool_capacity` budget (cache-less
/// methods hold no slots).
fn kv_lanes_of(ab: &ActiveBatch<Ticket>) -> usize {
    if ab.key.method.uses_kv_cache() {
        ab.state.capacity()
    } else {
        0
    }
}

fn worker_loop_continuous(
    core: &mut ServingCore,
    rx: mpsc::Receiver<RouterMsg>,
    cfg: RouterConfig,
    queued: Arc<AtomicUsize>,
) {
    let mut batcher: DynamicBatcher<Submit> =
        DynamicBatcher::new(cfg.max_batch, cfg.max_wait);
    let mut active: Vec<ActiveBatch<Ticket>> = Vec::new();
    let mut stats = ServeStats::default();
    let mut shutdown = false;
    // lanes one new machine would hold (each lane needs at most one KV
    // slot, so total lanes bound total continuous KV memory)
    let bucket_cap = core
        .rt
        .manifest
        .buckets
        .iter()
        .copied()
        .max()
        .unwrap_or(1);
    let batch_cap = cfg.max_batch.clamp(1, bucket_cap);
    loop {
        // ---- 1. ingest channel traffic (block only when fully idle —
        // drained batches retained as warm prefix caches don't count)
        let any_live = active.iter().any(|ab| !ab.is_empty());
        let timeout = if any_live {
            Duration::ZERO
        } else if !batcher.is_empty() {
            Duration::from_millis(1)
        } else {
            Duration::from_millis(200)
        };
        let mut msgs = Vec::new();
        match rx.recv_timeout(timeout) {
            Ok(m) => msgs.push(m),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => shutdown = true,
        }
        while let Ok(m) = rx.try_recv() {
            msgs.push(m);
        }
        for m in msgs {
            match m {
                RouterMsg::Request(b) => {
                    let sub = *b;
                    // tau stays per-lane in the step machine, so
                    // overrides batch together without leaking
                    let key = GroupKey::new(
                        sub.req.backbone.clone(),
                        sub.req.method,
                    );
                    batcher.push(Pending {
                        key,
                        enqueued: sub.submitted,
                        deadline: sub.ctl.deadline,
                        payload: sub,
                    });
                }
                RouterMsg::Metrics(tx) => {
                    let _ = tx.send(core.metrics_json());
                }
                RouterMsg::Health(tx) => {
                    let _ = tx.send(health_json(
                        core, &batcher, &active, &stats,
                    ));
                }
                RouterMsg::Shutdown => shutdown = true,
            }
        }
        // ---- 1.5 graceful drain: on shutdown every queued request and
        // in-flight lane gets a terminal Aborted{"shutdown"} event
        // (instead of its channel silently dropping), KV state frees,
        // and the worker exits immediately.
        if shutdown {
            while let Some((_key, items)) = batcher.pop_any() {
                queued.fetch_sub(items.len(), Ordering::SeqCst);
                for p in items {
                    stats.aborted_queued += 1;
                    p.payload.abort("shutdown");
                }
            }
            for ab in active.iter_mut() {
                for lane in ab.ticketed_lanes() {
                    if let Some((t, o)) = ab.cancel(lane) {
                        abort_lane(
                            core, &ab.key, &t, &o, "shutdown", &mut stats,
                        );
                    }
                }
                stats.absorb(&ab.state);
            }
            return;
        }
        // ---- 1.6 reap expired queued requests every iteration: a dead
        // client's permit and terminal 504 must not wait for a free
        // lane of its key to show up (the worker wakes at least every
        // 200ms even when idle, so the delay is bounded by one wakeup)
        for p in batcher.take_expired(Instant::now()) {
            queued.fetch_sub(1, Ordering::SeqCst);
            stats.aborted_queued += 1;
            p.payload.abort("deadline expired before admission");
        }
        // ---- 2. open machines for queued keys no live batch can host.
        // A block-step batch admits later arrivals mid-flight, so there
        // is nothing to gain from holding a request back for a fuller
        // bucket: open immediately. `max_active` and `pool_capacity`
        // (total lanes ≈ total KV slots) bound continuous KV memory,
        // but a key with no live batch at all may exceed them —
        // otherwise sustained traffic on one key (whose batches never
        // drain thanks to mid-flight refills) would starve every other
        // key forever. The overflow is bounded by the number of
        // distinct queued keys (backbone × method, a dozen at most).
        for key in batcher.keys_by_age() {
            let has_room = active
                .iter()
                .any(|ab| ab.key == key && ab.free_lanes() > 0);
            if has_room {
                continue;
            }
            let key_served = active.iter().any(|ab| ab.key == key);
            // only slot-holding lanes draw on the KV budget; the
            // cache-less baselines' batches are bounded by max_active
            let new_kv_lanes =
                if key.method.uses_kv_cache() { batch_cap } else { 0 };
            let over_caps = |batches: usize, kv_lanes: usize| {
                batches >= cfg.max_active.max(1)
                    || kv_lanes + new_kv_lanes
                        > cfg.pool_capacity.max(batch_cap)
            };
            let totals = |active: &[ActiveBatch<Ticket>]| {
                (active.len(), active.iter().map(kv_lanes_of).sum::<usize>())
            };
            let (n_all, kv_all) = totals(&active);
            if over_caps(n_all, kv_all) {
                // a served key only gets a second batch if room actually
                // exists once the retained warm caches are reclaimed —
                // check BEFORE evicting, so hopeless pressure never
                // destroys other keys' warm prefix chains for nothing
                let n_live =
                    active.iter().filter(|ab| !ab.is_empty()).count();
                let kv_live: usize = active
                    .iter()
                    .filter(|ab| !ab.is_empty())
                    .map(kv_lanes_of)
                    .sum();
                if key_served && over_caps(n_live, kv_live) {
                    continue; // at capacity and this key already decodes
                }
                // reclaim the coldest drained machines (retained only as
                // warm prefix caches) until we're under the caps
                loop {
                    let (n, kv) = totals(&active);
                    if !over_caps(n, kv) {
                        break;
                    }
                    let idle = active
                        .iter()
                        .enumerate()
                        .filter(|(_, ab)| ab.is_empty())
                        .min_by_key(|(_, ab)| ab.last_active)
                        .map(|(i, _)| i);
                    let Some(i) = idle else { break };
                    let reclaimed = active.remove(i);
                    stats.absorb(&reclaimed.state);
                }
            }
            let opts = DecodeOpts::defaults(core.geometry());
            match core.open_batch(&key, opts, cfg.max_batch) {
                Ok(mut state) => {
                    state.set_prefix_cache(cfg.prefix_cache);
                    active.push(ActiveBatch::new(key, state));
                }
                Err(e) => {
                    // fail this key's queued requests (bad weights)
                    let msg = format!("decode failed: {e:#}");
                    let (fresh, expired) =
                        batcher.take_for(&key, usize::MAX, Instant::now());
                    queued.fetch_sub(
                        fresh.len() + expired.len(),
                        Ordering::SeqCst,
                    );
                    for p in expired {
                        stats.aborted_queued += 1;
                        p.payload.abort("deadline expired before admission");
                    }
                    for p in fresh {
                        p.payload.abort(&msg);
                    }
                }
            }
        }
        // ---- 3. admission: feed queued requests into free lanes at
        // the block boundary (bucket-1 prefill inside `admit`).
        // Requests whose deadline already expired — or whose client
        // already cancelled — are terminated here WITHOUT consuming a
        // lane, a prefill call, or a prefix-chain pin.
        for ab in active.iter_mut() {
            loop {
                let free = ab.free_lanes();
                if free == 0 {
                    break;
                }
                let (fresh, expired) =
                    batcher.take_for(&ab.key, free, Instant::now());
                if fresh.is_empty() && expired.is_empty() {
                    break;
                }
                queued.fetch_sub(
                    fresh.len() + expired.len(),
                    Ordering::SeqCst,
                );
                for p in expired {
                    stats.aborted_queued += 1;
                    p.payload.abort("deadline expired before admission");
                }
                for p in fresh {
                    if p.payload.ctl.is_cancelled() {
                        stats.aborted_queued += 1;
                        p.payload.abort("cancelled before admission");
                        continue;
                    }
                    let (ticket, req) = Ticket::from_submit(p.payload);
                    if ticket.events.send(LaneEvent::Admitted).is_err() {
                        // handle already dropped: the client is gone,
                        // don't spend the prefill
                        stats.aborted_queued += 1;
                        continue;
                    }
                    if let Err((t, e)) =
                        ab.admit(&req.prompt_ids, req.tau_conf, ticket)
                    {
                        let _ = t.events.send(LaneEvent::Aborted {
                            reason: format!("admission failed: {e:#}"),
                            steps: 0,
                            model_calls: 0,
                            committed_tokens: 0,
                        });
                    }
                }
            }
        }
        // ---- 4. cancellation sweep, then advance every live batch one
        // block; retire + answer finished lanes immediately. The sweep
        // runs at the block boundary — exactly where lane state is
        // consistent and a departure cannot perturb cohort mates — and
        // frees the cancelled lane's KV slot + chain pin on the spot,
        // so the admission pass above can refill it next iteration.
        for ab in active.iter_mut() {
            if ab.is_empty() {
                continue;
            }
            let now = Instant::now();
            for lane in ab.ticketed_lanes() {
                let kind = match ab.ticket_mut(lane) {
                    Some(t) => cancel_of(t, now),
                    None => None,
                };
                match kind {
                    None => {}
                    Some(Cancel::Budget) => {
                        // generation budget reached: a truncated but
                        // successful response
                        if let Some((t, o)) = ab.cancel(lane) {
                            core.record_outcome(&ab.key, &o);
                            respond_lane(core, t, o);
                        }
                    }
                    Some(Cancel::Abort(reason)) => {
                        if let Some((t, o)) = ab.cancel(lane) {
                            abort_lane(
                                core, &ab.key, &t, &o, reason, &mut stats,
                            );
                        }
                    }
                }
            }
            if ab.is_empty() {
                continue; // every lane was cancelled
            }
            if !cfg.step_delay.is_zero() {
                std::thread::sleep(cfg.step_delay);
            }
            match ab.step() {
                Ok((runs, mut finished)) => {
                    let still_live = !ab.is_empty();
                    if still_live {
                        stats.retired_early += finished.len() as u64;
                    }
                    // stream each lane's block delta — lanes that
                    // finished this cycle get their final Committed
                    // before their Finished below
                    for run in &runs {
                        if let Some(t) = ab.ticket_mut(run.lane) {
                            emit_commit(core, t, run);
                        } else if let Some((_, t, _)) = finished
                            .iter_mut()
                            .find(|(l, _, _)| *l == run.lane)
                        {
                            emit_commit(core, t, run);
                        }
                    }
                    for (_, ticket, outcome) in finished {
                        core.record_outcome(&ab.key, &outcome);
                        respond_lane(core, ticket, outcome);
                    }
                }
                Err(e) => {
                    // drain through the cancel path so every lane's
                    // Aborted event and the /metrics wasted_* counters
                    // carry the work it actually burned (the lanes are
                    // still well-formed; only the failed program call
                    // poisoned the batch)
                    let msg = format!("decode failed: {e:#}");
                    for lane in ab.ticketed_lanes() {
                        if let Some((t, o)) = ab.cancel(lane) {
                            abort_lane(
                                core, &ab.key, &t, &o, &msg, &mut stats,
                            );
                        }
                    }
                    ab.poisoned = true;
                }
            }
        }
        // ---- 5. drop poisoned batches. Drained batches are *retained*
        // — their pools hold the warm prefix chains the next burst of
        // the same key admits against — until step 2 reclaims their
        // room for a new key.
        active.retain(|ab| {
            if ab.poisoned {
                stats.absorb(&ab.state);
            }
            !ab.poisoned
        });
    }
}

/// Detokenize one committed block run into the lane's stream and send
/// the `Committed` event. A failed send means the client dropped its
/// handle — the lane is marked dead and the next boundary sweep cancels
/// it (write-failure disconnect detection, one block of slack at most).
///
/// `tokens` — and the `max_new_tokens` budget it feeds — count the
/// tokens this delta actually *delivers*: the stream decoder drops
/// specials and everything at/after the first `<eos>`, and this toy
/// tokenizer is one char per token, so the delta's char count is
/// exactly its delivered-token count. Dead post-`<eos>` refinement
/// (the teacher baselines decode every block) charges nothing.
fn emit_commit(core: &ServingCore, t: &mut Ticket, run: &CommitRun) {
    let text = core.tokenizer.decode_stream(&mut t.detok, &run.tokens);
    let revealed = text.chars().count();
    t.committed_tokens += revealed;
    let block = t.blocks_committed;
    t.blocks_committed += 1;
    let sent = t.events.send(LaneEvent::Committed {
        block,
        text,
        tokens: revealed,
    });
    if sent.is_err() {
        t.dead = true;
    }
}

/// Answer one retired lane with its terminal `Finished` event.
/// TTFT/TTLT include queueing: the lane's decode-relative first-token
/// offset is rebased onto its admission instant. (A streaming client's
/// *observed* TTFT is stamped by the HTTP layer from the first
/// `Committed` chunk actually written to the socket.)
fn respond_lane(core: &ServingCore, ticket: Ticket, o: DecodeOutcome) {
    let wait = ticket.admitted - ticket.enqueued;
    let text = core.tokenizer.decode(&o.gen, true);
    let _ = ticket.events.send(LaneEvent::Finished(GenerateResponse {
        text,
        steps: o.steps,
        model_calls: o.model_calls,
        latency: o.latency,
        ttft: wait + o.ttft,
        ttlt: Instant::now() - ticket.enqueued,
        gen_len: o.gen_len,
        gen_ids: o.gen,
    }));
}

/// Terminal `Aborted` for a cancelled in-flight lane: surfaces the
/// wasted work on the event, `/metrics` (per backbone/method) and the
/// `aborted_inflight` counter on `/healthz`.
fn abort_lane(
    core: &mut ServingCore,
    key: &GroupKey,
    ticket: &Ticket,
    o: &DecodeOutcome,
    reason: &str,
    stats: &mut ServeStats,
) {
    stats.aborted_inflight += 1;
    core.record_abort(
        key,
        &AbortRecord {
            steps: o.steps,
            model_calls: o.model_calls,
            committed_tokens: ticket.committed_tokens,
        },
    );
    let _ = ticket.events.send(LaneEvent::Aborted {
        reason: reason.to_string(),
        steps: o.steps,
        model_calls: o.model_calls,
        committed_tokens: ticket.committed_tokens,
    });
}

fn health_json(
    core: &ServingCore,
    batcher: &DynamicBatcher<Submit>,
    active: &[ActiveBatch<Ticket>],
    stats: &ServeStats,
) -> Json {
    let in_flight: usize = active.iter().map(|ab| ab.live_lanes()).sum();
    let decoding = active.iter().filter(|ab| !ab.is_empty()).count();
    let kv_in_use: usize = core.pool.in_use()
        + active.iter().map(|ab| ab.state.kv_in_use()).sum::<usize>();
    let total_admissions = stats.closed_total_admissions
        + active.iter().map(|ab| ab.state.total_admissions).sum::<u64>();
    let mid_flight = stats.closed_mid_flight
        + active
            .iter()
            .map(|ab| ab.state.mid_flight_admissions)
            .sum::<u64>();
    let kv_allocs = stats.closed_kv_allocs
        + core.pool.total_allocs
        + active.iter().map(|ab| ab.state.kv_total_allocs()).sum::<u64>();
    let prefix_hits = stats.closed_prefix_hits
        + core.pool.prefix_hits
        + active.iter().map(|ab| ab.state.prefix_hits()).sum::<u64>();
    let prefix_hit_blocks = stats.closed_prefix_hit_blocks
        + core.pool.prefix_hit_blocks
        + active.iter().map(|ab| ab.state.prefix_hit_blocks()).sum::<u64>();
    let prefix_evictions = stats.closed_prefix_evictions
        + core.pool.prefix_evictions
        + active.iter().map(|ab| ab.state.prefix_evictions()).sum::<u64>();
    // resident shared pages are live state, not a lifetime counter:
    // only pools that still exist contribute
    let kv_shared_slots = core.pool.prefix_resident_pages()
        + active.iter().map(|ab| ab.state.kv_shared_pages()).sum::<usize>();
    Json::obj(vec![
        ("status", Json::str("ok")),
        ("platform", Json::str(core.rt.platform())),
        ("compiled_programs", Json::num(core.rt.compiled_count() as f64)),
        ("kv_slots_in_use", Json::num(kv_in_use as f64)),
        ("kv_total_allocs", Json::num(kv_allocs as f64)),
        ("kv_shared_slots", Json::num(kv_shared_slots as f64)),
        ("queued", Json::num(batcher.len() as f64)),
        // active = machines with live lanes (the pre-retention meaning);
        // drained machines kept only as warm prefix caches report
        // separately so "idle server" stays distinguishable
        ("active_batches", Json::num(decoding as f64)),
        ("retained_batches", Json::num((active.len() - decoding) as f64)),
        ("in_flight_lanes", Json::num(in_flight as f64)),
        ("total_admissions", Json::num(total_admissions as f64)),
        ("mid_flight_admissions", Json::num(mid_flight as f64)),
        ("retired_early", Json::num(stats.retired_early as f64)),
        ("aborted_queued", Json::num(stats.aborted_queued as f64)),
        ("aborted_inflight", Json::num(stats.aborted_inflight as f64)),
        ("prefix_hits", Json::num(prefix_hits as f64)),
        ("prefix_hit_blocks", Json::num(prefix_hit_blocks as f64)),
        ("prefix_evictions", Json::num(prefix_evictions as f64)),
    ])
}

// ---------------------------------------------------------------------------
// Closed-batch worker (legacy): batching windows + run-to-completion
// ---------------------------------------------------------------------------

fn worker_loop_closed(
    core: &mut ServingCore,
    rx: mpsc::Receiver<RouterMsg>,
    cfg: RouterConfig,
    queued: Arc<AtomicUsize>,
) {
    let mut batcher: DynamicBatcher<Submit> =
        DynamicBatcher::new(cfg.max_batch, cfg.max_wait);
    // closed-batch admission accounting for /healthz: every request
    // dispatched into a group counts as an admission; mid-flight joins
    // and early retirement don't exist on this path, so those stay 0.
    let mut stats = ServeStats::default();
    let mut shutdown = false;
    loop {
        let timeout = if batcher.is_empty() {
            Duration::from_millis(200)
        } else {
            batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(1))
        };
        match rx.recv_timeout(timeout) {
            Ok(RouterMsg::Request(b)) => {
                let sub = *b;
                // fold the tau override into the key: a group is
                // tau-uniform, so no request decodes with another
                // request's threshold. Methods whose finalization
                // ignores tau keep one group — no batch fragmentation
                // over an override they would never read.
                let tau = if sub.req.method.uses_tau_conf() {
                    sub.req.tau_conf
                } else {
                    None
                };
                let key =
                    GroupKey::new(sub.req.backbone.clone(), sub.req.method)
                        .with_tau(tau);
                batcher.push(Pending {
                    key,
                    enqueued: sub.submitted,
                    deadline: sub.ctl.deadline,
                    payload: sub,
                });
                // fall through: maybe this filled a bucket
            }
            Ok(RouterMsg::Metrics(tx)) => {
                let _ = tx.send(core.metrics_json());
                continue;
            }
            Ok(RouterMsg::Health(tx)) => {
                let _ = tx.send(health_json(core, &batcher, &[], &stats));
                continue;
            }
            Ok(RouterMsg::Shutdown) => shutdown = true,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => shutdown = true,
        }
        // drain every ready group this wakeup, then dispatch them
        // together — independent groups decode concurrently. The closed
        // path runs groups to completion, so there is no lane to cancel
        // mid-decode (and `max_new_tokens` is documented as ignored
        // here); queued-deadline expiry IS enforced, at dispatch: an
        // expired request never costs a group slot or a decode, same
        // contract as the continuous path's `take_for`.
        let mut groups: Vec<(GroupKey, Group)> = Vec::new();
        loop {
            let item = if shutdown {
                batcher.pop_any()
            } else {
                batcher.pop_ready(Instant::now())
            };
            let Some((key, items)) = item else { break };
            // pushes and pops are balanced, so a plain decrement is
            // exact (the old `min(load)` clamp was a racy read-modify-
            // write that could leak permits under concurrent submits)
            queued.fetch_sub(items.len(), Ordering::SeqCst);
            let now = Instant::now();
            let mut live: Group = Vec::with_capacity(items.len());
            for p in items {
                if p.deadline.is_some_and(|d| now > d) {
                    stats.aborted_queued += 1;
                    p.payload.abort("deadline expired before admission");
                } else if p.payload.events.send(LaneEvent::Admitted).is_err()
                {
                    // handle already dropped: the client is gone, don't
                    // spend a group slot on a run-to-completion decode
                    stats.aborted_queued += 1;
                } else {
                    stats.closed_total_admissions += 1;
                    live.push(p);
                }
            }
            if !live.is_empty() {
                groups.push((key, live));
            }
        }
        run_groups(core, groups);
        if shutdown && batcher.is_empty() {
            return;
        }
    }
}

type Group = Vec<Pending<Submit>>;

/// Decode opts for one group. Groups are tau-uniform by construction
/// (tau is folded into the `GroupKey`), so applying the key's tau is
/// exact — no request can inherit another's override.
fn group_opts(geom: &Geometry, key: &GroupKey) -> DecodeOpts {
    let mut opts = DecodeOpts::defaults(geom);
    if let Some(t) = key.tau() {
        opts.tau_conf = t;
    }
    opts
}

/// Answer one group's requests from its decode result. The closed path
/// decodes to completion, so the event stream collapses to a single
/// whole-response `Committed` delta followed by `Finished` — the wire
/// contract (concatenated deltas == final text, one terminal event)
/// holds on both worker paths. Metrics are recorded by the caller
/// (serial path: inside `decode_group`; parallel path: explicitly,
/// after the scoped join), never here.
fn respond_group(
    core: &ServingCore,
    items: Group,
    decode_start: Instant,
    result: Result<Vec<DecodeOutcome>>,
) {
    match result {
        Ok(outcomes) => {
            for (p, o) in items.into_iter().zip(outcomes) {
                let wait = decode_start - p.enqueued;
                let text = core.tokenizer.decode(&o.gen, true);
                let _ = p.payload.events.send(LaneEvent::Committed {
                    block: 0,
                    text: text.clone(),
                    tokens: o.gen_len,
                });
                let _ =
                    p.payload.events.send(LaneEvent::Finished(
                        GenerateResponse {
                            text,
                            steps: o.steps,
                            model_calls: o.model_calls,
                            latency: o.latency,
                            ttft: wait + o.ttft,
                            ttlt: Instant::now() - p.enqueued,
                            gen_len: o.gen_len,
                            gen_ids: o.gen,
                        },
                    ));
            }
        }
        Err(e) => {
            let msg = format!("decode failed: {e:#}");
            for p in items {
                p.payload.abort(&msg);
            }
        }
    }
}

/// Run a wakeup's worth of batcher groups. A single group (the common
/// case) decodes on the worker thread against the shared pool; several
/// groups fan out on scoped threads, each with its own KV pool and slot
/// set, then respond in group order — decode traces are identical to
/// running the groups back to back.
fn run_groups(core: &mut ServingCore, groups: Vec<(GroupKey, Group)>) {
    if groups.is_empty() {
        return;
    }
    let threads = crate::coordinator::scheduler::decode_threads(&core.rt);
    // resolve every group's weights up front; any load failure drops to
    // the serial path, which reproduces the error per group
    let all_loaded = groups.iter().all(|(key, _)| {
        core.ensure_weights(&key.method.weights_for(&key.backbone)).is_ok()
    });
    if groups.len() == 1 || threads <= 1 || !all_loaded {
        for (key, items) in groups {
            let opts = group_opts(core.geometry(), &key);
            let prompts: Vec<Vec<i32>> = items
                .iter()
                .map(|p| p.payload.req.prompt_ids.clone())
                .collect();
            let t0 = Instant::now();
            let result = core.decode_group(&key, &prompts, &opts);
            respond_group(core, items, t0, result);
        }
        return;
    }
    // parallel: each group decodes on a scoped worker against a private
    // KV pool; groups share only the immutable runtime + weights map
    let geom = core.rt.manifest.geometry.clone();
    let pool_cap = groups
        .iter()
        .map(|(_, items)| items.len())
        .chain(core.rt.manifest.buckets.iter().copied())
        .max()
        .unwrap_or(4);
    let meta: Vec<(String, Method, Vec<Vec<i32>>, DecodeOpts)> = groups
        .iter()
        .map(|(key, items)| {
            (
                key.method.weights_for(&key.backbone),
                key.method,
                items
                    .iter()
                    .map(|p| p.payload.req.prompt_ids.clone())
                    .collect(),
                group_opts(&geom, key),
            )
        })
        .collect();
    let mut results: Vec<Option<Result<Vec<DecodeOutcome>>>> = Vec::new();
    results.resize_with(groups.len(), || None);
    let t0 = Instant::now();
    {
        let rt = &core.rt;
        let weights_map = &core.weights;
        let geom_ref = &geom;
        // split the thread budget between the group fan-out (here) and
        // each group's own chunk fan-out, so nesting never runs more
        // than ~`threads` CPU-bound workers in total
        let per_group = (threads / groups.len()).max(1);
        let jobs: Vec<_> = results
            .iter_mut()
            .zip(&meta)
            .map(|(slot, (model, method, prompts, opts))| {
                move || {
                    let engine = Engine::new(rt, &weights_map[model]);
                    let mut pool = KvPool::new(geom_ref, pool_cap);
                    *slot = Some(engine.decode_with_threads(
                        per_group, *method, opts, prompts, &mut pool,
                    ));
                }
            })
            .collect();
        threadpool::scoped(threads, jobs);
    }
    for ((key, items), result) in groups.into_iter().zip(results) {
        let result = result.expect("group executor dropped a group");
        if let Ok(outcomes) = &result {
            core.record_group(&key, outcomes);
        }
        respond_group(core, items, t0, result);
    }
}
