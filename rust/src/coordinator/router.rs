//! Request router + serving core.
//!
//! Backends may hold `!Send` state (the PJRT handles wrap `Rc`s over C
//! pointers), so the architecture confines the whole `ServingCore`
//! (runtime, weights, KV pool, metrics) to one decode-worker thread,
//! and the rest of the process — HTTP handler threads, the CLI — talks
//! to it purely through channels. On a single-core box one decode
//! worker is also the right degree of parallelism; the dynamic batcher,
//! not thread count, provides concurrency.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{DynamicBatcher, GroupKey, Pending};
use super::kv_cache::KvPool;
use super::methods::{DecodeOpts, DecodeOutcome, Method};
use super::metrics::{MetricsAggregator, RequestRecord};
use super::scheduler::Engine;
use crate::runtime::{Geometry, ModelWeights, Runtime};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Json};

// ---------------------------------------------------------------------------
// ServingCore: single-threaded owner of all backend state
// ---------------------------------------------------------------------------

pub struct ServingCore {
    pub rt: Runtime,
    pub tokenizer: Tokenizer,
    weights: HashMap<String, ModelWeights>,
    pub pool: KvPool,
    pub metrics: HashMap<String, MetricsAggregator>,
}

impl ServingCore {
    pub fn load(artifacts: &Path, pool_capacity: usize) -> Result<Self> {
        let rt = Runtime::load(artifacts)?;
        let tokenizer = Tokenizer::new();
        // cross-language vocab pin: a real artifacts directory MUST
        // carry a matching vocab.json (a missing one is a broken
        // export, not a skip); only the built-in reference manifest
        // uses the compiled-in vocab directly.
        if artifacts.join("manifest.json").exists() {
            tokenizer.verify_against(&json::load(&artifacts.join("vocab.json"))?)?;
        }
        let pool = KvPool::new(&rt.manifest.geometry, pool_capacity);
        Ok(Self {
            rt,
            tokenizer,
            weights: HashMap::new(),
            pool,
            metrics: HashMap::new(),
        })
    }

    pub fn geometry(&self) -> &Geometry {
        &self.rt.manifest.geometry
    }

    fn ensure_weights(&mut self, model: &str) -> Result<()> {
        if !self.weights.contains_key(model) {
            let w = ModelWeights::load(&self.rt.manifest, model)?;
            // §Perf: backends with a host/device split make the
            // weights device-resident for the model's lifetime here;
            // the reference backend treats this as a no-op
            w.upload(&self.rt)?;
            self.weights.insert(model.to_string(), w);
        }
        Ok(())
    }

    /// Decode one lockstep group (benches/examples call this directly;
    /// the router worker calls it from its thread).
    pub fn decode_group(
        &mut self,
        key: &GroupKey,
        prompts: &[Vec<i32>],
        opts: &DecodeOpts,
    ) -> Result<Vec<DecodeOutcome>> {
        let model = key.method.weights_for(&key.backbone);
        self.ensure_weights(&model)?;
        let weights = &self.weights[&model];
        let engine = Engine::new(&self.rt, weights);
        let outcomes = engine.decode(key.method, opts, prompts, &mut self.pool)?;
        let agg = self
            .metrics
            .entry(format!("{}/{}", key.backbone, key.method.name()))
            .or_default();
        for o in &outcomes {
            agg.record(&RequestRecord {
                latency: o.latency,
                steps: o.steps,
                model_calls: o.model_calls,
                gen_len: o.gen_len,
                correct: None,
            });
        }
        Ok(outcomes)
    }

    pub fn metrics_json(&self) -> Json {
        Json::Obj(
            self.metrics
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Router: channel front-end + decode worker thread
// ---------------------------------------------------------------------------

pub struct GenerateRequest {
    pub backbone: String,
    pub method: Method,
    pub prompt_ids: Vec<i32>,
    pub tau_conf: Option<f32>,
}

#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub gen_ids: Vec<i32>,
    pub text: String,
    pub steps: u64,
    pub model_calls: u64,
    pub latency: Duration,
    pub gen_len: usize,
}

type Responder = mpsc::Sender<Result<GenerateResponse, String>>;

enum RouterMsg {
    Request(Box<(GenerateRequest, Responder)>),
    Metrics(mpsc::Sender<Json>),
    Health(mpsc::Sender<Json>),
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub max_queue: usize,
    pub pool_capacity: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait: Duration::from_millis(25),
            max_queue: 256,
            pool_capacity: 64,
        }
    }
}

pub struct Router {
    tx: mpsc::Sender<RouterMsg>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub geometry: Geometry,
    pub max_queue: usize,
    queued: Arc<AtomicUsize>,
    known_models: Vec<String>,
}

impl Router {
    /// Spawn the decode worker (which loads all backend state on its
    /// own thread) and wait for it to come up.
    pub fn start(artifacts: PathBuf, cfg: RouterConfig) -> Result<Router> {
        let (tx, rx) = mpsc::channel::<RouterMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Geometry, String>>();
        let queued = Arc::new(AtomicUsize::new(0));
        let wq = queued.clone();
        let wcfg = cfg.clone();
        let wartifacts = artifacts.clone();
        let worker = std::thread::Builder::new()
            .name("cdlm-decode-worker".into())
            .spawn(move || {
                let mut core =
                    match ServingCore::load(&wartifacts, wcfg.pool_capacity) {
                        Ok(c) => {
                            let _ = ready_tx
                                .send(Ok(c.rt.manifest.geometry.clone()));
                            c
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                worker_loop(&mut core, rx, wcfg, wq);
            })?;
        let geometry = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("serving core failed to load: {e}"))?;
        // Known model list comes from the manifest; re-read it cheaply
        // here so admission can reject unknown backbones without a
        // round-trip to the worker.
        let manifest = crate::runtime::Manifest::load_or_reference(&artifacts)?;
        Ok(Router {
            tx,
            worker: Some(worker),
            geometry,
            max_queue: cfg.max_queue,
            queued,
            known_models: manifest.models.iter().map(|(k, _)| k.clone()).collect(),
        })
    }

    /// Enqueue a request; returns a receiver for the response.
    pub fn submit(
        &self,
        req: GenerateRequest,
    ) -> Result<mpsc::Receiver<Result<GenerateResponse, String>>> {
        anyhow::ensure!(
            req.prompt_ids.len() == self.geometry.prompt_len,
            "prompt must be padded to {} tokens (got {})",
            self.geometry.prompt_len,
            req.prompt_ids.len()
        );
        let model = req.method.weights_for(&req.backbone);
        anyhow::ensure!(
            self.known_models.contains(&model),
            "unknown backbone '{}' for method '{}'",
            req.backbone,
            req.method.name()
        );
        let q = self.queued.load(Ordering::SeqCst);
        anyhow::ensure!(
            q < self.max_queue,
            "admission rejected: queue full ({q}/{})",
            self.max_queue
        );
        self.queued.fetch_add(1, Ordering::SeqCst);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Request(Box::new((req, rtx))))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rrx)
    }

    pub fn metrics(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Metrics(tx))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rx.recv()?)
    }

    pub fn health(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Health(tx))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(RouterMsg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    core: &mut ServingCore,
    rx: mpsc::Receiver<RouterMsg>,
    cfg: RouterConfig,
    queued: Arc<AtomicUsize>,
) {
    let mut batcher: DynamicBatcher<(GenerateRequest, Responder)> =
        DynamicBatcher::new(cfg.max_batch, cfg.max_wait);
    let mut shutdown = false;
    loop {
        let timeout = if batcher.is_empty() {
            Duration::from_millis(200)
        } else {
            batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(1))
        };
        match rx.recv_timeout(timeout) {
            Ok(RouterMsg::Request(b)) => {
                let (req, resp) = *b;
                let key = GroupKey {
                    backbone: req.backbone.clone(),
                    method: req.method,
                };
                batcher.push(Pending {
                    key,
                    payload: (req, resp),
                    enqueued: Instant::now(),
                });
                // fall through: maybe this filled a bucket
            }
            Ok(RouterMsg::Metrics(tx)) => {
                let _ = tx.send(core.metrics_json());
                continue;
            }
            Ok(RouterMsg::Health(tx)) => {
                let _ = tx.send(Json::obj(vec![
                    ("status", Json::str("ok")),
                    ("platform", Json::str(core.rt.platform())),
                    (
                        "compiled_programs",
                        Json::num(core.rt.compiled_count() as f64),
                    ),
                    (
                        "kv_slots_in_use",
                        Json::num(core.pool.in_use() as f64),
                    ),
                    ("queued", Json::num(batcher.len() as f64)),
                ]));
                continue;
            }
            Ok(RouterMsg::Shutdown) => shutdown = true,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => shutdown = true,
        }
        loop {
            let item = if shutdown {
                batcher.pop_any()
            } else {
                batcher.pop_ready(Instant::now())
            };
            let Some((key, items)) = item else { break };
            queued.fetch_sub(items.len().min(queued.load(Ordering::SeqCst)),
                             Ordering::SeqCst);
            run_group(core, &key, items);
        }
        if shutdown && batcher.is_empty() {
            return;
        }
    }
}

fn run_group(
    core: &mut ServingCore,
    key: &GroupKey,
    items: Vec<(GenerateRequest, Responder)>,
) {
    let mut opts = DecodeOpts::defaults(&core.rt.manifest.geometry.clone());
    if let Some(t) = items.iter().find_map(|(r, _)| r.tau_conf) {
        opts.tau_conf = t;
    }
    let prompts: Vec<Vec<i32>> =
        items.iter().map(|(r, _)| r.prompt_ids.clone()).collect();
    match core.decode_group(key, &prompts, &opts) {
        Ok(outcomes) => {
            for ((_, resp), o) in items.into_iter().zip(outcomes) {
                let text = core.tokenizer.decode(&o.gen, true);
                let _ = resp.send(Ok(GenerateResponse {
                    gen_ids: o.gen,
                    text,
                    steps: o.steps,
                    model_calls: o.model_calls,
                    latency: o.latency,
                    gen_len: o.gen_len,
                }));
            }
        }
        Err(e) => {
            let msg = format!("decode failed: {e:#}");
            for (_, resp) in items {
                let _ = resp.send(Err(msg.clone()));
            }
        }
    }
}
