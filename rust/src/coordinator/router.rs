//! Request router + serving core.
//!
//! All backend state (runtime, weights, KV pool, metrics) lives in one
//! `ServingCore` owned by the decode-worker thread; HTTP handler
//! threads and the CLI talk to it purely through channels. Within the
//! worker, ready batcher groups are independent — different (backbone,
//! method) keys never share sequence state or KV slots — so the worker
//! drains every ready group per wakeup and decodes them concurrently on
//! scoped threads (each group against its own KV pool), bounded by the
//! backend's `max_concurrency`. Backends that must stay single-threaded
//! (PJRT reports `max_concurrency() == 1`) keep the old serial path;
//! responses and metrics are always emitted in group order, so traces
//! are identical either way.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{DynamicBatcher, GroupKey, Pending};
use super::kv_cache::KvPool;
use super::methods::{DecodeOpts, DecodeOutcome, Method};
use super::metrics::{MetricsAggregator, RequestRecord};
use super::scheduler::Engine;
use crate::runtime::{Geometry, ModelWeights, Runtime};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Json};
use crate::util::threadpool;

// ---------------------------------------------------------------------------
// ServingCore: single-threaded owner of all backend state
// ---------------------------------------------------------------------------

pub struct ServingCore {
    pub rt: Runtime,
    pub tokenizer: Tokenizer,
    weights: HashMap<String, ModelWeights>,
    pub pool: KvPool,
    pub metrics: HashMap<String, MetricsAggregator>,
}

impl ServingCore {
    pub fn load(artifacts: &Path, pool_capacity: usize) -> Result<Self> {
        let rt = Runtime::load(artifacts)?;
        let tokenizer = Tokenizer::new();
        // cross-language vocab pin: a real artifacts directory MUST
        // carry a matching vocab.json (a missing one is a broken
        // export, not a skip); only the built-in reference manifest
        // uses the compiled-in vocab directly.
        if artifacts.join("manifest.json").exists() {
            tokenizer.verify_against(&json::load(&artifacts.join("vocab.json"))?)?;
        }
        let pool = KvPool::new(&rt.manifest.geometry, pool_capacity);
        Ok(Self {
            rt,
            tokenizer,
            weights: HashMap::new(),
            pool,
            metrics: HashMap::new(),
        })
    }

    pub fn geometry(&self) -> &Geometry {
        &self.rt.manifest.geometry
    }

    fn ensure_weights(&mut self, model: &str) -> Result<()> {
        if !self.weights.contains_key(model) {
            let w = ModelWeights::load(&self.rt.manifest, model)?;
            // §Perf: backends with a host/device split make the
            // weights device-resident for the model's lifetime here;
            // the reference backend treats this as a no-op
            w.upload(&self.rt)?;
            self.weights.insert(model.to_string(), w);
        }
        Ok(())
    }

    /// Decode one lockstep group (benches/examples call this directly;
    /// the router worker calls it from its thread).
    pub fn decode_group(
        &mut self,
        key: &GroupKey,
        prompts: &[Vec<i32>],
        opts: &DecodeOpts,
    ) -> Result<Vec<DecodeOutcome>> {
        let model = key.method.weights_for(&key.backbone);
        self.ensure_weights(&model)?;
        let weights = &self.weights[&model];
        let engine = Engine::new(&self.rt, weights);
        let outcomes = engine.decode(key.method, opts, prompts, &mut self.pool)?;
        self.record_group(key, &outcomes);
        Ok(outcomes)
    }

    /// Fold a group's outcomes into the per-(backbone, method) metrics.
    fn record_group(&mut self, key: &GroupKey, outcomes: &[DecodeOutcome]) {
        let agg = self
            .metrics
            .entry(format!("{}/{}", key.backbone, key.method.name()))
            .or_default();
        for o in outcomes {
            agg.record(&RequestRecord {
                latency: o.latency,
                steps: o.steps,
                model_calls: o.model_calls,
                gen_len: o.gen_len,
                correct: None,
            });
        }
    }

    pub fn metrics_json(&self) -> Json {
        Json::Obj(
            self.metrics
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Router: channel front-end + decode worker thread
// ---------------------------------------------------------------------------

pub struct GenerateRequest {
    pub backbone: String,
    pub method: Method,
    pub prompt_ids: Vec<i32>,
    pub tau_conf: Option<f32>,
}

#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub gen_ids: Vec<i32>,
    pub text: String,
    pub steps: u64,
    pub model_calls: u64,
    pub latency: Duration,
    pub gen_len: usize,
}

type Responder = mpsc::Sender<Result<GenerateResponse, String>>;

enum RouterMsg {
    Request(Box<(GenerateRequest, Responder)>),
    Metrics(mpsc::Sender<Json>),
    Health(mpsc::Sender<Json>),
    Shutdown,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub max_queue: usize,
    pub pool_capacity: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait: Duration::from_millis(25),
            max_queue: 256,
            pool_capacity: 64,
        }
    }
}

pub struct Router {
    tx: mpsc::Sender<RouterMsg>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub geometry: Geometry,
    pub max_queue: usize,
    queued: Arc<AtomicUsize>,
    known_models: Vec<String>,
}

impl Router {
    /// Spawn the decode worker (which loads all backend state on its
    /// own thread) and wait for it to come up.
    pub fn start(artifacts: PathBuf, cfg: RouterConfig) -> Result<Router> {
        let (tx, rx) = mpsc::channel::<RouterMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Geometry, String>>();
        let queued = Arc::new(AtomicUsize::new(0));
        let wq = queued.clone();
        let wcfg = cfg.clone();
        let wartifacts = artifacts.clone();
        let worker = std::thread::Builder::new()
            .name("cdlm-decode-worker".into())
            .spawn(move || {
                let mut core =
                    match ServingCore::load(&wartifacts, wcfg.pool_capacity) {
                        Ok(c) => {
                            let _ = ready_tx
                                .send(Ok(c.rt.manifest.geometry.clone()));
                            c
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("{e:#}")));
                            return;
                        }
                    };
                worker_loop(&mut core, rx, wcfg, wq);
            })?;
        let geometry = ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("serving core failed to load: {e}"))?;
        // Known model list comes from the manifest; re-read it cheaply
        // here so admission can reject unknown backbones without a
        // round-trip to the worker.
        let manifest = crate::runtime::Manifest::load_or_reference(&artifacts)?;
        Ok(Router {
            tx,
            worker: Some(worker),
            geometry,
            max_queue: cfg.max_queue,
            queued,
            known_models: manifest.models.iter().map(|(k, _)| k.clone()).collect(),
        })
    }

    /// Enqueue a request; returns a receiver for the response.
    pub fn submit(
        &self,
        req: GenerateRequest,
    ) -> Result<mpsc::Receiver<Result<GenerateResponse, String>>> {
        anyhow::ensure!(
            req.prompt_ids.len() == self.geometry.prompt_len,
            "prompt must be padded to {} tokens (got {})",
            self.geometry.prompt_len,
            req.prompt_ids.len()
        );
        let model = req.method.weights_for(&req.backbone);
        anyhow::ensure!(
            self.known_models.contains(&model),
            "unknown backbone '{}' for method '{}'",
            req.backbone,
            req.method.name()
        );
        let q = self.queued.load(Ordering::SeqCst);
        anyhow::ensure!(
            q < self.max_queue,
            "admission rejected: queue full ({q}/{})",
            self.max_queue
        );
        self.queued.fetch_add(1, Ordering::SeqCst);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Request(Box::new((req, rtx))))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rrx)
    }

    pub fn metrics(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Metrics(tx))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rx.recv()?)
    }

    pub fn health(&self) -> Result<Json> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(RouterMsg::Health(tx))
            .map_err(|_| anyhow::anyhow!("router worker is gone"))?;
        Ok(rx.recv()?)
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(RouterMsg::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    core: &mut ServingCore,
    rx: mpsc::Receiver<RouterMsg>,
    cfg: RouterConfig,
    queued: Arc<AtomicUsize>,
) {
    let mut batcher: DynamicBatcher<(GenerateRequest, Responder)> =
        DynamicBatcher::new(cfg.max_batch, cfg.max_wait);
    let mut shutdown = false;
    loop {
        let timeout = if batcher.is_empty() {
            Duration::from_millis(200)
        } else {
            batcher
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(1))
        };
        match rx.recv_timeout(timeout) {
            Ok(RouterMsg::Request(b)) => {
                let (req, resp) = *b;
                let key = GroupKey {
                    backbone: req.backbone.clone(),
                    method: req.method,
                };
                batcher.push(Pending {
                    key,
                    payload: (req, resp),
                    enqueued: Instant::now(),
                });
                // fall through: maybe this filled a bucket
            }
            Ok(RouterMsg::Metrics(tx)) => {
                let _ = tx.send(core.metrics_json());
                continue;
            }
            Ok(RouterMsg::Health(tx)) => {
                let _ = tx.send(Json::obj(vec![
                    ("status", Json::str("ok")),
                    ("platform", Json::str(core.rt.platform())),
                    (
                        "compiled_programs",
                        Json::num(core.rt.compiled_count() as f64),
                    ),
                    (
                        "kv_slots_in_use",
                        Json::num(core.pool.in_use() as f64),
                    ),
                    ("queued", Json::num(batcher.len() as f64)),
                ]));
                continue;
            }
            Ok(RouterMsg::Shutdown) => shutdown = true,
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => shutdown = true,
        }
        // drain every ready group this wakeup, then dispatch them
        // together — independent groups decode concurrently
        let mut groups: Vec<(GroupKey, Vec<(GenerateRequest, Responder)>)> =
            Vec::new();
        loop {
            let item = if shutdown {
                batcher.pop_any()
            } else {
                batcher.pop_ready(Instant::now())
            };
            let Some((key, items)) = item else { break };
            queued.fetch_sub(items.len().min(queued.load(Ordering::SeqCst)),
                             Ordering::SeqCst);
            groups.push((key, items));
        }
        run_groups(core, groups);
        if shutdown && batcher.is_empty() {
            return;
        }
    }
}

/// Decode opts for one group (per-request tau overrides win).
fn group_opts(
    geom: &Geometry,
    items: &[(GenerateRequest, Responder)],
) -> DecodeOpts {
    let mut opts = DecodeOpts::defaults(geom);
    if let Some(t) = items.iter().find_map(|(r, _)| r.tau_conf) {
        opts.tau_conf = t;
    }
    opts
}

/// Answer one group's requests from its decode result. Metrics are
/// recorded by the caller (serial path: inside `decode_group`; parallel
/// path: explicitly, after the scoped join), never here.
fn respond_group(
    core: &ServingCore,
    items: Vec<(GenerateRequest, Responder)>,
    result: Result<Vec<DecodeOutcome>>,
) {
    match result {
        Ok(outcomes) => {
            for ((_, resp), o) in items.into_iter().zip(outcomes) {
                let text = core.tokenizer.decode(&o.gen, true);
                let _ = resp.send(Ok(GenerateResponse {
                    gen_ids: o.gen,
                    text,
                    steps: o.steps,
                    model_calls: o.model_calls,
                    latency: o.latency,
                    gen_len: o.gen_len,
                }));
            }
        }
        Err(e) => {
            let msg = format!("decode failed: {e:#}");
            for (_, resp) in items {
                let _ = resp.send(Err(msg.clone()));
            }
        }
    }
}

/// Run a wakeup's worth of batcher groups. A single group (the common
/// case) decodes on the worker thread against the shared pool; several
/// groups fan out on scoped threads, each with its own KV pool and slot
/// set, then respond in group order — decode traces are identical to
/// running the groups back to back.
fn run_groups(
    core: &mut ServingCore,
    groups: Vec<(GroupKey, Vec<(GenerateRequest, Responder)>)>,
) {
    if groups.is_empty() {
        return;
    }
    let threads = crate::coordinator::scheduler::decode_threads(&core.rt);
    // resolve every group's weights up front; any load failure drops to
    // the serial path, which reproduces the error per group
    let all_loaded = groups.iter().all(|(key, _)| {
        core.ensure_weights(&key.method.weights_for(&key.backbone)).is_ok()
    });
    if groups.len() == 1 || threads <= 1 || !all_loaded {
        for (key, items) in groups {
            let opts = group_opts(core.geometry(), &items);
            let prompts: Vec<Vec<i32>> =
                items.iter().map(|(r, _)| r.prompt_ids.clone()).collect();
            let result = core.decode_group(&key, &prompts, &opts);
            respond_group(core, items, result);
        }
        return;
    }
    // parallel: each group decodes on a scoped worker against a private
    // KV pool; groups share only the immutable runtime + weights map
    let geom = core.rt.manifest.geometry.clone();
    let pool_cap = groups
        .iter()
        .map(|(_, items)| items.len())
        .chain(core.rt.manifest.buckets.iter().copied())
        .max()
        .unwrap_or(4);
    let meta: Vec<(String, Method, Vec<Vec<i32>>, DecodeOpts)> = groups
        .iter()
        .map(|(key, items)| {
            (
                key.method.weights_for(&key.backbone),
                key.method,
                items.iter().map(|(r, _)| r.prompt_ids.clone()).collect(),
                group_opts(&geom, items),
            )
        })
        .collect();
    let mut results: Vec<Option<Result<Vec<DecodeOutcome>>>> = Vec::new();
    results.resize_with(groups.len(), || None);
    {
        let rt = &core.rt;
        let weights_map = &core.weights;
        let geom_ref = &geom;
        // split the thread budget between the group fan-out (here) and
        // each group's own chunk fan-out, so nesting never runs more
        // than ~`threads` CPU-bound workers in total
        let per_group = (threads / groups.len()).max(1);
        let jobs: Vec<_> = results
            .iter_mut()
            .zip(&meta)
            .map(|(slot, (model, method, prompts, opts))| {
                move || {
                    let engine = Engine::new(rt, &weights_map[model]);
                    let mut pool = KvPool::new(geom_ref, pool_cap);
                    *slot = Some(engine.decode_with_threads(
                        per_group, *method, opts, prompts, &mut pool,
                    ));
                }
            })
            .collect();
        threadpool::scoped(threads, jobs);
    }
    for ((key, items), result) in groups.into_iter().zip(results) {
        let result = result.expect("group executor dropped a group");
        if let Ok(outcomes) = &result {
            core.record_group(&key, outcomes);
        }
        respond_group(core, items, result);
    }
}
