//! Request router + serving core.
//!
//! All backend state (runtime, weights, KV pool, metrics) lives in one
//! `ServingCore` owned by the decode-worker thread; HTTP handler
//! threads and the CLI talk to it purely through channels.
//!
//! The worker runs **continuous batching** by default: queued requests
//! open a resumable block-step batch ([`ActiveBatch`] over
//! `methods::machine::BatchState`) immediately, every live batch
//! advances one block per loop iteration, lanes that finalize `<eos>`
//! are retired and answered mid-batch (their KV slot recycles on the
//! spot), and compatible queued requests are admitted into freed lanes
//! at block boundaries via a bucket-1 prefill — iteration-level
//! scheduling instead of request-level. The classic closed-batch path
//! (dynamic batcher windows + run-to-completion groups, the PR 2
//! behavior) remains reachable with `RouterConfig::continuous = false`
//! and serves as the serving-bench baseline.
//!
//! Per-request tau never leaks across requests: the continuous machine
//! carries tau per lane, and the closed-batch path folds the override
//! into the batching [`GroupKey`] so mixed-tau requests never share a
//! lockstep group.
//!
//! **The lane-event pipeline.** A request is no longer a one-shot
//! `(ticket -> outcome)` round trip: `Router::submit` returns a
//! [`ResponseHandle`] over a per-request [`LaneEvent`] channel —
//! `Admitted` when the lane enters a batch, one `Committed` per
//! finalized block (incrementally detokenized delta), and exactly one
//! terminal `Finished`/`Aborted`. The same handle carries control the
//! other way: an explicit [`ResponseHandle::cancel`], a per-request
//! deadline, or a `max_new_tokens` budget retires the lane at the next
//! block boundary, freeing its KV slot and unpinning its prefix chain
//! immediately so queued work can take the lane; dropping the handle
//! (a disconnected client) is detected on the next `Committed` send
//! and cancels the same way. Expired requests are refused *before*
//! admission (`DynamicBatcher::take_for`) so a dead client never costs
//! a prefill. `/healthz` counts both: `aborted_queued` /
//! `aborted_inflight`.
//!
//! **Sharded replicas.** `RouterConfig::replicas = N` splits the
//! serving core into N independent shards, each a full `ServingCore`
//! (weights, KV pool, prefix trie) driven by its own worker thread over
//! its own inbox. The dispatcher routes each request by
//! *prefix affinity* — `prefix_affinity_hash(prompt) % N` — so
//! shared-prompt traffic always lands on the one shard whose prefix
//! trie is already warm, spilling to the least-loaded shard only when
//! the affinity shard's queue exceeds its fair share. A hot shard
//! cannot strand capacity elsewhere: at block boundaries, shards with
//! free lanes (or nothing to do at all) *steal* queued requests that
//! have already waited out the batching window on a sibling's inbox.
//! Per-lane decode traces depend only on (prompt, seed), so routing and
//! stealing never change a request's tokens, steps, or model calls —
//! accounting is byte-identical at any replica count (CI-gated).
//!
//! **Admission control.** `Router::submit` returns a typed
//! [`SubmitError`]: malformed requests (`Invalid`), a saturated global
//! queue (`QueueFull`), a client over its in-flight fairness cap
//! (`ClientCap`), and a draining router (`Draining`) are told apart so
//! the HTTP layer can answer 400 / 429 / 429 / 503 with a
//! `Retry-After` hint. [`Router::begin_drain`] starts a graceful
//! drain: new submits are refused, every queued request gets a
//! terminal `Aborted{"shutdown"}`, in-flight lanes *finish* normally,
//! then the workers exit ([`Router::join`] / [`Router::shutdown`]).
//!
//! **Supervision.** Every shard worker runs under a per-shard
//! supervisor thread behind `catch_unwind`. A panicking worker (or one
//! that misses its busy-heartbeat deadline — the stall watchdog treats
//! a wedged step like a panic) is quarantined: its generation counter
//! is bumped so a zombie incarnation stands down on its next block
//! boundary, and every admitted-but-unfinished request in the shard's
//! recovery registry is settled by the idempotency rule — a request
//! that never streamed a `Committed` delta is *re-dispatched* (its
//! decode trace is a pure function of (prompt, seed), so the replay is
//! byte-identical); one that already streamed gets a terminal
//! `Aborted{"shard_failure"}` with a Retry-After hint. The worker then
//! respawns with a fresh core (KV pool, prefix trie), bounded by
//! `restart_budget` per `restart_window`; past the budget the shard is
//! marked dead, its queue evacuates to live siblings, routing skips
//! it, and `/healthz` reports `degraded: true`. A [`FaultPlan`]
//! (`RouterConfig::fault_plan`, off by default) deterministically
//! injects worker panics, delayed steps, and KV-allocation failures to
//! test all of the above; `cdlm bench --scenario chaos` drives it.

use std::collections::{BTreeMap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{DynamicBatcher, GroupKey, Pending};
use super::faults::{FaultKind, FaultPlan};
use super::kv_cache::{prefix_affinity_hash, KvPool};
use super::methods::machine::{BatchState, CommitRun};
use super::methods::{DecodeOpts, DecodeOutcome, Method};
use super::metrics::{
    AbortRecord, MetricsAggregator, PreemptionStats, RequestRecord,
    SupervisionStats,
};
use super::scheduler::{ActiveBatch, Engine};
use crate::runtime::{Geometry, ModelWeights, Runtime};
use crate::tokenizer::{StreamDecoder, Tokenizer};
use crate::util::json::{self, Json};
use crate::util::threadpool;

// ---------------------------------------------------------------------------
// ServingCore: single-threaded owner of all backend state
// ---------------------------------------------------------------------------

pub struct ServingCore {
    pub rt: Arc<Runtime>,
    pub tokenizer: Tokenizer,
    weights: HashMap<String, Arc<ModelWeights>>,
    pub pool: KvPool,
    pub metrics: HashMap<String, MetricsAggregator>,
}

impl ServingCore {
    pub fn load(artifacts: &Path, pool_capacity: usize) -> Result<Self> {
        let rt = Runtime::load(artifacts)?;
        let tokenizer = Tokenizer::new();
        // cross-language vocab pin: a real artifacts directory MUST
        // carry a matching vocab.json (a missing one is a broken
        // export, not a skip); only the built-in reference manifest
        // uses the compiled-in vocab directly.
        if artifacts.join("manifest.json").exists() {
            tokenizer.verify_against(&json::load(&artifacts.join("vocab.json"))?)?;
        }
        let pool = KvPool::new(&rt.manifest.geometry, pool_capacity);
        Ok(Self {
            rt: Arc::new(rt),
            tokenizer,
            weights: HashMap::new(),
            pool,
            metrics: HashMap::new(),
        })
    }

    pub fn geometry(&self) -> &Geometry {
        &self.rt.manifest.geometry
    }

    /// Load (once) and share a model's weights. The `Arc` lets
    /// long-lived block-step machines hold the weights while the core
    /// keeps loading others.
    fn ensure_weights(&mut self, model: &str) -> Result<Arc<ModelWeights>> {
        if !self.weights.contains_key(model) {
            let w = ModelWeights::load(&self.rt.manifest, model)?;
            // §Perf: backends with a host/device split make the
            // weights device-resident for the model's lifetime here;
            // the reference backend treats this as a no-op
            w.upload(&self.rt)?;
            self.weights.insert(model.to_string(), Arc::new(w));
        }
        Ok(self.weights[model].clone())
    }

    /// Open a resumable block-step batch for one group key.
    pub fn open_batch(
        &mut self,
        key: &GroupKey,
        opts: DecodeOpts,
        capacity: usize,
    ) -> Result<BatchState> {
        let model = key.method.weights_for(&key.backbone);
        let weights = self.ensure_weights(&model)?;
        BatchState::new(self.rt.clone(), weights, key.method, opts, capacity)
    }

    /// Open a block-step batch whose pool under-provisions its page
    /// budgets (see [`BatchState::with_kv_budgets`]) — the preempt
    /// bench's pressure cooker.
    pub fn open_batch_budgeted(
        &mut self,
        key: &GroupKey,
        opts: DecodeOpts,
        capacity: usize,
        prompt_budget: usize,
        tail_budget: usize,
    ) -> Result<BatchState> {
        let model = key.method.weights_for(&key.backbone);
        let weights = self.ensure_weights(&model)?;
        BatchState::with_kv_budgets(
            self.rt.clone(),
            weights,
            key.method,
            opts,
            capacity,
            prompt_budget,
            tail_budget,
        )
    }

    /// Decode one lockstep group to completion (benches/examples call
    /// this directly; the closed-batch worker calls it from its
    /// thread).
    pub fn decode_group(
        &mut self,
        key: &GroupKey,
        prompts: &[Vec<i32>],
        opts: &DecodeOpts,
    ) -> Result<Vec<DecodeOutcome>> {
        let model = key.method.weights_for(&key.backbone);
        let weights = self.ensure_weights(&model)?;
        let engine = Engine::new(&self.rt, &weights);
        let outcomes = engine.decode(key.method, opts, prompts, &mut self.pool)?;
        self.record_group(key, &outcomes);
        Ok(outcomes)
    }

    /// Fold one outcome into the per-(backbone, method) metrics.
    fn record_outcome(&mut self, key: &GroupKey, o: &DecodeOutcome) {
        let agg = self
            .metrics
            .entry(format!("{}/{}", key.backbone, key.method.name()))
            .or_default();
        agg.record(&RequestRecord {
            latency: o.latency,
            steps: o.steps,
            model_calls: o.model_calls,
            gen_len: o.gen_len,
            correct: None,
        });
    }

    /// Fold a cancelled lane's wasted work into the per-(backbone,
    /// method) metrics (kept out of the §A.3 per-sample averages).
    fn record_abort(&mut self, key: &GroupKey, r: &AbortRecord) {
        self.metrics
            .entry(format!("{}/{}", key.backbone, key.method.name()))
            .or_default()
            .record_abort(r);
    }

    /// Fold a group's outcomes into the per-(backbone, method) metrics.
    fn record_group(&mut self, key: &GroupKey, outcomes: &[DecodeOutcome]) {
        for o in outcomes {
            self.record_outcome(key, o);
        }
    }

    pub fn metrics_json(&self) -> Json {
        Json::Obj(
            self.metrics
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Router: channel front-end + decode worker thread
// ---------------------------------------------------------------------------

#[derive(Clone)]
pub struct GenerateRequest {
    pub backbone: String,
    pub method: Method,
    pub prompt_ids: Vec<i32>,
    pub tau_conf: Option<f32>,
    /// Wall-clock budget measured from submission. An expired request
    /// is refused before it costs anything — at admission on the
    /// continuous path, at group dispatch on the closed-batch path —
    /// and an admitted continuous lane is cancelled at the next block
    /// boundary.
    pub timeout: Option<Duration>,
    /// Generation budget: the lane retires with a normal `Finished`
    /// (truncated) response at the first block boundary where at least
    /// this many tokens have been *delivered* (post-`<eos>` dead
    /// refinement never charges it). Needs block-boundary cancellation,
    /// so the closed-batch worker (run-to-completion groups) ignores
    /// it.
    pub max_new_tokens: Option<usize>,
    /// Fairness identity for `RouterConfig::max_per_client`: at most
    /// that many requests of one client may be in the system at once
    /// (queued or decoding). `None` is exempt — internal callers
    /// (benches, tests) and deployments without client attribution are
    /// never throttled. The HTTP layer fills it from the request's
    /// `client_id` field, defaulting to the peer IP.
    pub client: Option<String>,
    /// SLO priority (higher = more urgent, default 0). At block
    /// boundaries the continuous worker may preempt a live lane — spill
    /// its KV pages host-side and park it — when a queued request's
    /// *effective* priority (static priority plus one point per
    /// [`PRIORITY_AGE_MS`] waited) strictly exceeds the lane's. The age
    /// boost applies symmetrically, so starved low-priority work
    /// eventually outranks fresh high-priority arrivals and nothing
    /// waits forever.
    pub priority: i32,
}

impl GenerateRequest {
    pub fn new(
        backbone: impl Into<String>,
        method: Method,
        prompt_ids: Vec<i32>,
    ) -> Self {
        Self {
            backbone: backbone.into(),
            method,
            prompt_ids,
            tau_conf: None,
            timeout: None,
            max_new_tokens: None,
            client: None,
            priority: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenerateResponse {
    pub gen_ids: Vec<i32>,
    pub text: String,
    pub steps: u64,
    pub model_calls: u64,
    /// Decode time (§A.3: starts when the lane enters a batch).
    pub latency: Duration,
    /// Time from arrival to the first revealed token (queueing
    /// included).
    pub ttft: Duration,
    /// Time from arrival to the full response (queueing included).
    pub ttlt: Duration,
    pub gen_len: usize,
}

/// One hop of a request's life, streamed over its per-request channel.
/// The sequence is always `Admitted?` · `Committed*` · exactly one
/// terminal (`Finished` | `Aborted`); a request that never reaches a
/// lane (queue rejection at submit is an `Err` from `submit` itself;
/// queued-deadline expiry, shutdown, load-failure) goes straight to
/// `Aborted`.
#[derive(Debug, Clone)]
pub enum LaneEvent {
    /// The request entered a batch lane (admission prefill done).
    Admitted,
    /// One block's worth of tokens finalized. `text` is the
    /// incrementally detokenized delta: concatenating every `text` of a
    /// request reproduces the terminal response's `text` byte-for-byte
    /// (`tests/streaming.rs` pins this for all six methods). `tokens`
    /// counts the tokens this delta delivers (specials and anything
    /// at/after the stream's first `<eos>` excluded — dead post-`<eos>`
    /// refinement charges nothing); `block` is the 0-based ordinal of
    /// the event within its request.
    Committed { block: usize, text: String, tokens: usize },
    /// Terminal: the lane decoded to completion (or hit its
    /// `max_new_tokens` budget — a truncated but successful response).
    Finished(GenerateResponse),
    /// Terminal: the request was cancelled or failed. The counters
    /// carry whatever work the lane burned before retiring (zero when
    /// it never reached a lane).
    Aborted {
        reason: String,
        steps: u64,
        model_calls: u64,
        committed_tokens: usize,
    },
}

/// Client-side control half of the event pipeline: shared with the
/// worker, checked at every block boundary.
#[derive(Debug)]
pub struct RequestCtl {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
    max_new_tokens: Option<usize>,
}

impl RequestCtl {
    fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }
}

/// The caller's end of one request's event pipeline. Read events with
/// [`next_event`] (streaming) or collapse to the terminal response with
/// [`wait`] (one-shot callers). [`cancel`] — or simply dropping the
/// handle — asks the worker to retire the lane at the next block
/// boundary, freeing its KV slot and prefix-chain pin for queued work.
///
/// [`next_event`]: ResponseHandle::next_event
/// [`wait`]: ResponseHandle::wait
/// [`cancel`]: ResponseHandle::cancel
pub struct ResponseHandle {
    rx: mpsc::Receiver<LaneEvent>,
    ctl: Arc<RequestCtl>,
    /// A terminal event has been delivered through this handle
    /// (received, or synthesized on disconnect). Guarantees the
    /// exactly-one-terminal contract survives a dead worker: a channel
    /// that disconnects *without* a terminal yields one synthesized
    /// `Aborted{"worker_lost"}`, never a silent `None`/hang, and never
    /// a second terminal after a real one.
    terminal_seen: std::cell::Cell<bool>,
}

impl ResponseHandle {
    fn new(
        rx: mpsc::Receiver<LaneEvent>,
        ctl: Arc<RequestCtl>,
    ) -> ResponseHandle {
        ResponseHandle {
            rx,
            ctl,
            terminal_seen: std::cell::Cell::new(false),
        }
    }

    fn note(&self, ev: &LaneEvent) {
        if matches!(ev, LaneEvent::Finished(_) | LaneEvent::Aborted { .. })
        {
            self.terminal_seen.set(true);
        }
    }

    fn synthesize_lost(&self) -> LaneEvent {
        self.terminal_seen.set(true);
        LaneEvent::Aborted {
            reason: "worker_lost: event channel disconnected without a \
                     terminal event"
                .to_string(),
            steps: 0,
            model_calls: 0,
            committed_tokens: 0,
        }
    }

    /// Next lane event. A disconnect before the terminal event (the
    /// worker died and nothing recovered the request) is surfaced as
    /// one synthesized `Aborted{"worker_lost"}`; `None` only ever
    /// means "the terminal event was already delivered".
    pub fn next_event(&self) -> Option<LaneEvent> {
        match self.rx.recv() {
            Ok(ev) => {
                self.note(&ev);
                Some(ev)
            }
            Err(_) if !self.terminal_seen.get() => {
                Some(self.synthesize_lost())
            }
            Err(_) => None,
        }
    }

    /// Drain to the terminal event: `Finished -> Ok`, `Aborted -> Err`.
    /// A worker lost without recovery yields
    /// `Err("worker_lost: ...")`, not a hang.
    pub fn wait(&self) -> Result<GenerateResponse, String> {
        loop {
            match self.next_event() {
                Some(LaneEvent::Finished(resp)) => return Ok(resp),
                Some(LaneEvent::Aborted { reason, .. }) => {
                    return Err(reason)
                }
                Some(_) => continue,
                None => return Err("worker dropped the request".into()),
            }
        }
    }

    /// Nonblocking poll of the event pipeline (the event-loop HTTP
    /// front door sweeps hundreds of these per iteration; a blocking
    /// `next_event` would pin the loop on one connection).
    pub fn try_next_event(&self) -> TryEvent {
        match self.rx.try_recv() {
            Ok(ev) => {
                self.note(&ev);
                TryEvent::Event(ev)
            }
            Err(mpsc::TryRecvError::Empty) => TryEvent::Empty,
            Err(mpsc::TryRecvError::Disconnected)
                if !self.terminal_seen.get() =>
            {
                TryEvent::Event(self.synthesize_lost())
            }
            Err(mpsc::TryRecvError::Disconnected) => TryEvent::Closed,
        }
    }

    /// Request cancellation. Asynchronous: the worker retires the lane
    /// at its next block boundary and answers with a terminal
    /// `Aborted`.
    pub fn cancel(&self) {
        self.ctl.cancelled.store(true, Ordering::Relaxed);
    }
}

/// One nonblocking poll of a [`ResponseHandle`].
pub enum TryEvent {
    /// An event is ready.
    Event(LaneEvent),
    /// Nothing yet; poll again later.
    Empty,
    /// The channel is closed and the terminal event was already
    /// delivered (a pre-terminal worker death surfaces as an
    /// `Event(Aborted{"worker_lost"})` instead).
    Closed,
}

type EventTx = mpsc::Sender<LaneEvent>;

/// What the worker has sent through a [`LaneSlot`], tracked under the
/// slot's lock so the supervisor's recovery decision and the worker's
/// sends serialize.
#[derive(Default)]
struct SlotState {
    /// Seized by the supervisor: further worker sends are dropped (the
    /// zombie incarnation starves; the request's channel now belongs
    /// to its replay or its terminal abort).
    revoked: bool,
    /// At least one `Committed` delta reached the channel — the
    /// re-dispatch idempotency rule: a request that streamed cannot be
    /// replayed (the client already consumed part of one trace).
    committed: bool,
    /// A terminal `Finished`/`Aborted` reached the channel.
    terminal: bool,
    /// `Admitted` was sent (a replayed request suppresses the
    /// duplicate so the client sees one admission).
    admitted_sent: bool,
    /// Tokens delivered so far (the abort event's accounting when the
    /// worker died holding the exact counters).
    committed_tokens: usize,
}

/// The worker-side half of one request's event channel, wrapped so a
/// supervisor can atomically *seize* it: revoke the (possibly zombie)
/// worker's send rights and read exactly what the client has been
/// promised so far. All worker sends route through [`LaneSlot::send`];
/// a send after revocation fails like a disconnected client, which the
/// worker already handles by cancelling the lane.
struct LaneSlot {
    tx: EventTx,
    st: Mutex<SlotState>,
}

impl LaneSlot {
    fn new(tx: EventTx) -> Arc<LaneSlot> {
        Arc::new(LaneSlot { tx, st: Mutex::new(SlotState::default()) })
    }

    /// A fresh slot over the same channel for a re-dispatched request:
    /// send rights restored, `Admitted` suppressed (the client already
    /// saw one), commit/terminal state reset for the replay.
    fn replay(old: &LaneSlot) -> Arc<LaneSlot> {
        Arc::new(LaneSlot {
            tx: old.tx.clone(),
            st: Mutex::new(SlotState {
                admitted_sent: true,
                ..SlotState::default()
            }),
        })
    }

    fn lock(&self) -> MutexGuard<'_, SlotState> {
        self.st.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Worker-side send. `Err` means the event was not delivered —
    /// receiver gone or slot revoked/terminal — and the caller should
    /// treat the request as gone (cancel the lane).
    fn send(&self, ev: LaneEvent) -> Result<(), ()> {
        let mut st = self.lock();
        if st.revoked || st.terminal {
            return Err(());
        }
        match &ev {
            LaneEvent::Admitted => {
                if st.admitted_sent {
                    return Ok(());
                }
                st.admitted_sent = true;
            }
            LaneEvent::Committed { tokens, .. } => {
                st.committed = true;
                st.committed_tokens += tokens;
            }
            LaneEvent::Finished(_) | LaneEvent::Aborted { .. } => {
                st.terminal = true;
            }
        }
        self.tx.send(ev).map_err(|_| ())
    }

    /// Supervisor-side: revoke worker send rights and report
    /// `(committed, terminal, committed_tokens)` — the state the
    /// recovery decision is made on. Holding the lock for the flag
    /// flip closes the race with an in-flight worker send.
    fn seize(&self) -> (bool, bool, usize) {
        let mut st = self.lock();
        st.revoked = true;
        (st.committed, st.terminal, st.committed_tokens)
    }

    /// Supervisor-side terminal send on a seized slot (revocation does
    /// not apply to the supervisor). No-op if a terminal already went
    /// out — the exactly-one-terminal contract holds.
    fn force_terminal(&self, ev: LaneEvent) {
        let mut st = self.lock();
        if st.terminal {
            return;
        }
        st.terminal = true;
        let _ = self.tx.send(ev);
    }
}

/// Typed admission verdicts from [`Router::submit`], so the HTTP layer
/// maps each to the right status code and `Retry-After` hint instead of
/// collapsing every refusal into one 429.
#[derive(Debug)]
pub enum SubmitError {
    /// Malformed request (bad prompt length, unknown backbone) — a 400,
    /// retrying is pointless.
    Invalid(String),
    /// The global queue is at `max_queue` — a 429 with `Retry-After`.
    QueueFull { queued: usize, max: usize, retry_after: Duration },
    /// This client is at its `max_per_client` in-flight fairness cap —
    /// a 429 with `Retry-After`; other clients are unaffected.
    ClientCap { client: String, in_flight: usize, cap: usize, retry_after: Duration },
    /// The router is draining for shutdown — a 503 with `Retry-After`
    /// (another instance will take the retry after the rolling
    /// restart).
    Draining { retry_after: Duration },
    /// Every shard has exhausted its restart budget and been marked
    /// dead — a 503 with `Retry-After` (an operator or orchestrator
    /// restart is needed; `/healthz` reports `degraded`).
    Degraded { retry_after: Duration },
}

impl SubmitError {
    /// HTTP status this refusal maps to.
    pub fn status(&self) -> u16 {
        match self {
            SubmitError::Invalid(_) => 400,
            SubmitError::QueueFull { .. } | SubmitError::ClientCap { .. } => 429,
            SubmitError::Draining { .. } | SubmitError::Degraded { .. } => 503,
        }
    }

    /// `Retry-After` hint, when retrying can help.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            SubmitError::Invalid(_) => None,
            SubmitError::QueueFull { retry_after, .. }
            | SubmitError::ClientCap { retry_after, .. }
            | SubmitError::Draining { retry_after }
            | SubmitError::Degraded { retry_after } => Some(*retry_after),
        }
    }

    /// Machine-readable refusal code for the typed HTTP error body
    /// (`{"code", "message", "retry_after_ms"}`).
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::Invalid(_) => "invalid_request",
            SubmitError::QueueFull { .. } => "queue_full",
            SubmitError::ClientCap { .. } => "client_cap",
            SubmitError::Draining { .. } => "draining",
            SubmitError::Degraded { .. } => "degraded",
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Invalid(msg) => write!(f, "{msg}"),
            SubmitError::QueueFull { queued, max, .. } => {
                write!(f, "admission rejected: queue full ({queued}/{max})")
            }
            SubmitError::ClientCap { client, in_flight, cap, .. } => write!(
                f,
                "admission rejected: client '{client}' is at its fairness \
                 cap ({in_flight}/{cap} in flight)"
            ),
            SubmitError::Draining { .. } => {
                write!(f, "admission rejected: draining for shutdown")
            }
            SubmitError::Degraded { .. } => write!(
                f,
                "admission rejected: every shard is dead (restart budget \
                 exhausted); the service is degraded"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// RAII share of a client's `max_per_client` fairness budget. Travels
/// with the request (Submit -> Ticket), so *every* exit — finished,
/// aborted, expired in queue, dead channel — releases the slot by
/// dropping it; no terminal path can leak a client's budget.
struct ClientPermit {
    held: Option<(Arc<Mutex<HashMap<String, usize>>>, String)>,
}

impl ClientPermit {
    fn unlimited() -> Self {
        Self { held: None }
    }
}

impl Drop for ClientPermit {
    fn drop(&mut self) {
        if let Some((clients, name)) = self.held.take() {
            let mut m = clients.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(n) = m.get_mut(&name) {
                *n = n.saturating_sub(1);
                if *n == 0 {
                    m.remove(&name);
                }
            }
        }
    }
}

/// A submitted request in flight toward a worker lane.
struct Submit {
    req: GenerateRequest,
    events: Arc<LaneSlot>,
    ctl: Arc<RequestCtl>,
    /// Stamped at `Router::submit`, so TTFT/TTLT include the time a
    /// message waits in the channel while the worker decodes.
    submitted: Instant,
    /// The shard `prefix_affinity_hash` steered this request toward;
    /// shards compare it against their own id at admission to measure
    /// the affinity hit rate.
    affinity: usize,
    /// Router-wide request id, keying the shard's recovery registry.
    rid: u64,
    /// Held for the request's whole life; dropped on any terminal path.
    _permit: ClientPermit,
}

impl Submit {
    /// Terminal abort for a request that never reached a lane.
    fn abort(&self, reason: &str) {
        let _ = self.events.send(LaneEvent::Aborted {
            reason: reason.to_string(),
            steps: 0,
            model_calls: 0,
            committed_tokens: 0,
        });
    }
}

/// Everything the supervisor needs to settle one admitted request
/// after its worker died: the seized event slot decides replay vs
/// abort, and the cloned request rebuilds the [`Submit`] for replay.
/// Inserted at lane admission, removed at the lane's terminal event —
/// so the registry is exactly the set of admitted-but-unanswered
/// requests.
struct Recoverable {
    slot: Arc<LaneSlot>,
    ctl: Arc<RequestCtl>,
    req: GenerateRequest,
    submitted: Instant,
    affinity: usize,
}

/// Control-plane message fanned out to every shard. Metrics replies as
/// raw aggregators (not JSON) so the dispatcher can merge the shards'
/// per-(backbone, method) cells sample-exactly.
enum ControlMsg {
    Metrics(mpsc::Sender<HashMap<String, MetricsAggregator>>),
    Health(mpsc::Sender<Json>),
}

/// One shard's mailbox: its private request queue plus pending control
/// messages and the drain flag, all under one short-held lock. The
/// worker owns everything else (core, machines) thread-locally.
struct ShardInbox {
    batcher: DynamicBatcher<Submit>,
    control: Vec<ControlMsg>,
    shutdown: bool,
}

/// Shard lifecycle states (`Shard::state`).
const SHARD_LIVE: usize = 0;
const SHARD_RESTARTING: usize = 1;
const SHARD_DEAD: usize = 2;

/// One replica shard: the mailbox the dispatcher routes into, the
/// racy load gauges (`depth`, `in_flight`) routing and stealing read
/// without taking the lock, and the supervision state (heartbeat,
/// generation, lifecycle, recovery registry) shared between the
/// worker and its supervisor.
struct Shard {
    id: usize,
    inbox: Mutex<ShardInbox>,
    cv: Condvar,
    /// Queued requests in this shard's batcher (kept in sync after
    /// every locked mutation; reads are advisory).
    depth: AtomicUsize,
    /// Live lanes across this shard's machines (updated once per worker
    /// iteration; reads are advisory).
    in_flight: AtomicUsize,
    /// Worker liveness stamp: ms since `epoch` of the last block
    /// boundary (plus the busy flag below), read by the watchdog.
    heartbeat: AtomicU64,
    /// The worker had live work at its last stamp. The watchdog only
    /// applies to busy workers — an idle worker parks on its condvar
    /// for 200ms stretches and must not trip it.
    busy: AtomicBool,
    /// Worker incarnation. The supervisor bumps it *before* sweeping
    /// the registry; a superseded (wedged-then-woken) incarnation
    /// observes the mismatch at its next block boundary and stands
    /// down, and every send it attempts in between hits its revoked
    /// slots.
    generation: AtomicUsize,
    /// `SHARD_LIVE` / `SHARD_RESTARTING` / `SHARD_DEAD`.
    state: AtomicUsize,
    /// Worker respawns performed by the supervisor (lifetime).
    restarts: AtomicU64,
    /// Admitted-but-unanswered requests, by rid — what the supervisor
    /// can still recover after a worker death.
    registry: Mutex<HashMap<u64, Recoverable>>,
    /// Heartbeat time base (per shard, so stamps never mix bases).
    epoch: Instant,
    /// Lifetime SLO-preemption counters (worker bumps, dispatcher
    /// reads): lanes suspended, lanes resumed, and KV bytes spilled to
    /// the host-side cold tier. They survive worker respawns, unlike
    /// the per-batch counters a dead core takes with it.
    kv_preempts: AtomicU64,
    kv_resumes: AtomicU64,
    kv_spilled_bytes: AtomicU64,
}

impl Shard {
    fn new(id: usize, max_batch: usize, max_wait: Duration) -> Shard {
        Shard {
            id,
            inbox: Mutex::new(ShardInbox {
                batcher: DynamicBatcher::new(max_batch, max_wait),
                control: Vec::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            depth: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            heartbeat: AtomicU64::new(0),
            busy: AtomicBool::new(false),
            generation: AtomicUsize::new(0),
            state: AtomicUsize::new(SHARD_LIVE),
            restarts: AtomicU64::new(0),
            registry: Mutex::new(HashMap::new()),
            epoch: Instant::now(),
            kv_preempts: AtomicU64::new(0),
            kv_resumes: AtomicU64::new(0),
            kv_spilled_bytes: AtomicU64::new(0),
        }
    }

    /// Lock the inbox, surviving a poisoned mutex (a panicked sibling
    /// must not take the whole front door down with it).
    fn lock(&self) -> MutexGuard<'_, ShardInbox> {
        self.inbox.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn state(&self) -> usize {
        self.state.load(Ordering::SeqCst)
    }

    fn set_state(&self, s: usize) {
        self.state.store(s, Ordering::SeqCst);
    }

    /// Stamp worker liveness (called at every block boundary).
    fn beat(&self, busy: bool) {
        self.heartbeat
            .store(self.epoch.elapsed().as_millis() as u64, Ordering::SeqCst);
        self.busy.store(busy, Ordering::SeqCst);
    }

    /// Milliseconds since the last liveness stamp.
    fn heartbeat_age_ms(&self) -> u64 {
        (self.epoch.elapsed().as_millis() as u64)
            .saturating_sub(self.heartbeat.load(Ordering::SeqCst))
    }

    fn registry_lock(&self) -> MutexGuard<'_, HashMap<u64, Recoverable>> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn registry_insert(&self, rid: u64, rec: Recoverable) {
        self.registry_lock().insert(rid, rec);
    }

    fn registry_remove(&self, rid: u64) {
        self.registry_lock().remove(&rid);
    }

    /// Refresh the advisory queue-depth gauge; call before releasing
    /// any lock that mutated the batcher.
    fn sync_depth(&self, inbox: &ShardInbox) {
        self.depth.store(inbox.batcher.len(), Ordering::SeqCst);
    }

    /// Route one request into this shard. Refused (handed back) once
    /// the shard has begun draining: the worker's queue-abort pass runs
    /// exactly once, so anything pushed after it would hang forever.
    fn push(&self, p: Pending<Submit>) -> Result<(), Pending<Submit>> {
        let mut inbox = self.lock();
        if inbox.shutdown {
            return Err(p);
        }
        inbox.batcher.push(p);
        self.sync_depth(&inbox);
        drop(inbox);
        self.cv.notify_all();
        Ok(())
    }

    /// Queue a control message for the worker. Refused (`false`) once
    /// the inbox is shut down — after shard death or drain nothing will
    /// ever service it, and the caller must not block on the reply.
    fn send_control(&self, msg: ControlMsg) -> bool {
        let mut inbox = self.lock();
        if inbox.shutdown {
            return false;
        }
        inbox.control.push(msg);
        drop(inbox);
        self.cv.notify_all();
        true
    }
}

/// Dispatcher state shared by `submit` and the shard workers.
struct Dispatch {
    shards: Vec<Arc<Shard>>,
    /// Global queued-request count (the `max_queue` bound spans all
    /// shards, so a burst cannot hide by spreading out).
    queued: Arc<AtomicUsize>,
    draining: AtomicBool,
    /// Per-client in-flight counts backing `max_per_client`.
    clients: Arc<Mutex<HashMap<String, usize>>>,
    rejected_queue_full: AtomicU64,
    rejected_client_cap: AtomicU64,
    rejected_draining: AtomicU64,
    rejected_degraded: AtomicU64,
    routed_affinity: AtomicU64,
    routed_spill: AtomicU64,
    /// Router-wide request-id source; every admitted request gets one,
    /// keying the shard recovery registries.
    next_rid: AtomicU64,
    shard_panics: AtomicU64,
    watchdog_trips: AtomicU64,
    redispatched: AtomicU64,
    aborted_shard_failure: AtomicU64,
    dead_shards: AtomicU64,
    recovery_count: AtomicU64,
    recovery_total_ms: AtomicU64,
    recovery_max_ms: AtomicU64,
}

impl Dispatch {
    fn supervision(&self) -> SupervisionStats {
        let c = |a: &AtomicU64| a.load(Ordering::SeqCst);
        SupervisionStats {
            shard_panics: c(&self.shard_panics),
            watchdog_trips: c(&self.watchdog_trips),
            redispatched_requests: c(&self.redispatched),
            aborted_shard_failure: c(&self.aborted_shard_failure),
            restarts: self
                .shards
                .iter()
                .map(|s| s.restarts.load(Ordering::SeqCst))
                .sum(),
            dead_shards: c(&self.dead_shards),
            recovery_count: c(&self.recovery_count),
            recovery_total_ms: c(&self.recovery_total_ms),
            recovery_max_ms: c(&self.recovery_max_ms),
        }
    }

    /// Lifetime preempt/resume counters summed across every shard.
    fn preemption(&self) -> PreemptionStats {
        let mut p = PreemptionStats::default();
        for s in &self.shards {
            p.preempts += s.kv_preempts.load(Ordering::SeqCst);
            p.resumes += s.kv_resumes.load(Ordering::SeqCst);
            p.spilled_bytes += s.kv_spilled_bytes.load(Ordering::SeqCst);
        }
        p
    }

    /// Least-loaded shard among those still accepting work, if any.
    fn least_loaded_live(&self, exclude: Option<usize>) -> Option<usize> {
        self.shards
            .iter()
            .filter(|s| s.state() != SHARD_DEAD && Some(s.id) != exclude)
            .min_by_key(|s| {
                s.depth.load(Ordering::Relaxed)
                    + s.in_flight.load(Ordering::Relaxed)
            })
            .map(|s| s.id)
    }
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub max_queue: usize,
    /// KV slot budget. The closed-batch worker sizes the shared
    /// `ServingCore` pool with it; the continuous worker additionally
    /// treats it as the total-lane bound across live block-step
    /// batches (each lane holds at most one slot in its batch's own
    /// pool), so `--pool` caps KV memory on both paths.
    pub pool_capacity: usize,
    /// Iteration-level scheduling (default). `false` restores the
    /// closed-batch worker: batching windows + run-to-completion
    /// groups, no mid-flight admission — the serving-bench baseline.
    pub continuous: bool,
    /// Upper bound on concurrently live block-step batches (bounds KV
    /// memory: each batch owns a pool of `min(max_batch, max bucket)`
    /// slots).
    pub max_active: usize,
    /// Artificial pause before each block step (tests/demos use this to
    /// widen admission windows; zero in production).
    pub step_delay: Duration,
    /// Shared-prefix KV reuse (continuous path): admissions whose full
    /// prompt is cached skip their prefill call, and drained machines
    /// are retained as warm caches until a new key needs their room.
    /// `cdlm serve --no-prefix-cache` turns it off.
    pub prefix_cache: bool,
    /// Replica shards. Each shard is a full serving core — its own
    /// weights, KV pool, prefix trie, and worker thread — so
    /// `pool_capacity` and `max_active` are **per replica**. `1`
    /// reproduces the single-worker behavior exactly.
    pub replicas: usize,
    /// Per-client in-flight fairness cap (`0` = off). Counts a client's
    /// requests queued + decoding across all shards; excess submits get
    /// [`SubmitError::ClientCap`] so one flooding client cannot consume
    /// the whole `max_queue`.
    pub max_per_client: usize,
    /// Deterministic fault-injection plan (`None` in production).
    /// Threaded to every shard worker; see [`FaultPlan`] for the spec
    /// grammar and `cdlm serve --fault-spec/--fault-seed`.
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// Worker respawns the supervisor allows per shard within
    /// `restart_window` before declaring the shard dead (`0` = never
    /// restart: first failure kills the shard).
    pub restart_budget: usize,
    /// Sliding window over which `restart_budget` is counted.
    pub restart_window: Duration,
    /// Stall watchdog: a worker that is busy (live lanes) but hasn't
    /// stamped a block boundary for this long is treated as wedged —
    /// superseded and replaced like a panic. `Duration::ZERO` disables
    /// the watchdog. Must comfortably exceed the worst-case block step
    /// (including `step_delay`).
    pub watchdog_deadline: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait: Duration::from_millis(25),
            max_queue: 256,
            pool_capacity: 64,
            continuous: true,
            max_active: 4,
            step_delay: Duration::ZERO,
            prefix_cache: true,
            replicas: 1,
            max_per_client: 0,
            fault_plan: None,
            restart_budget: 3,
            restart_window: Duration::from_secs(60),
            watchdog_deadline: Duration::from_secs(5),
        }
    }
}

pub struct Router {
    dispatch: Arc<Dispatch>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub geometry: Geometry,
    pub max_queue: usize,
    max_batch: usize,
    max_per_client: usize,
    continuous: bool,
    known_models: Vec<String>,
}

impl Router {
    /// Spawn one supervisor per replica shard (each supervisor spawns
    /// and, on failure, respawns a decode worker that loads its own
    /// full serving core) and wait for all of them to come up.
    pub fn start(artifacts: PathBuf, mut cfg: RouterConfig) -> Result<Router> {
        let replicas = cfg.replicas.max(1);
        if let Some(plan) = &cfg.fault_plan {
            plan.bind_replicas(replicas);
        }
        if !cfg.continuous {
            // the closed-batch worker runs groups to completion, so a
            // healthy step can legitimately outlast any fixed deadline
            cfg.watchdog_deadline = Duration::ZERO;
        }
        let queued = Arc::new(AtomicUsize::new(0));
        let shards: Vec<Arc<Shard>> = (0..replicas)
            .map(|id| Arc::new(Shard::new(id, cfg.max_batch, cfg.max_wait)))
            .collect();
        let dispatch = Arc::new(Dispatch {
            shards,
            queued,
            draining: AtomicBool::new(false),
            clients: Arc::new(Mutex::new(HashMap::new())),
            rejected_queue_full: AtomicU64::new(0),
            rejected_client_cap: AtomicU64::new(0),
            rejected_draining: AtomicU64::new(0),
            rejected_degraded: AtomicU64::new(0),
            routed_affinity: AtomicU64::new(0),
            routed_spill: AtomicU64::new(0),
            next_rid: AtomicU64::new(0),
            shard_panics: AtomicU64::new(0),
            watchdog_trips: AtomicU64::new(0),
            redispatched: AtomicU64::new(0),
            aborted_shard_failure: AtomicU64::new(0),
            dead_shards: AtomicU64::new(0),
            recovery_count: AtomicU64::new(0),
            recovery_total_ms: AtomicU64::new(0),
            recovery_max_ms: AtomicU64::new(0),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<Geometry, String>>();
        // the continuous worker decodes exclusively through per-batch
        // KV pools (pool_capacity bounds their total lanes); don't
        // also allocate the shared core pool it would never touch
        let core_pool = if cfg.continuous { 0 } else { cfg.pool_capacity };
        let mut workers = Vec::with_capacity(replicas);
        for id in 0..replicas {
            let sdispatch = dispatch.clone();
            let scfg = cfg.clone();
            let sartifacts = artifacts.clone();
            let sready = ready_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cdlm-shard-supervisor-{id}"))
                    .spawn(move || {
                        supervise_shard(
                            sartifacts, core_pool, sdispatch, id, scfg, sready,
                        );
                    })?,
            );
        }
        drop(ready_tx);
        let mut geometry: Option<Geometry> = None;
        for _ in 0..replicas {
            let up = ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker died during startup"));
            match up {
                Ok(Ok(g)) => geometry = Some(g),
                Ok(Err(e)) => {
                    // one replica failed to load: drain the siblings
                    // that did come up, then surface the error
                    for s in &dispatch.shards {
                        let mut inbox = s.lock();
                        inbox.shutdown = true;
                        drop(inbox);
                        s.cv.notify_all();
                    }
                    for w in workers {
                        let _ = w.join();
                    }
                    anyhow::bail!("serving core failed to load: {e}");
                }
                Err(e) => {
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(e);
                }
            }
        }
        let geometry = geometry.expect("replicas >= 1 sent a geometry");
        // Known model list comes from the manifest; re-read it cheaply
        // here so admission can reject unknown backbones without a
        // round-trip to a worker.
        let manifest = crate::runtime::Manifest::load_or_reference(&artifacts)?;
        Ok(Router {
            dispatch,
            workers,
            geometry,
            max_queue: cfg.max_queue,
            max_batch: cfg.max_batch.max(1),
            max_per_client: cfg.max_per_client,
            continuous: cfg.continuous,
            known_models: manifest.models.iter().map(|(k, _)| k.clone()).collect(),
        })
    }

    pub fn replicas(&self) -> usize {
        self.dispatch.shards.len()
    }

    /// How long a refused client should wait before retrying: roughly
    /// the time the current backlog needs to drain one scheduling round
    /// per replica, clamped to [1s, 30s].
    fn retry_after_hint(&self) -> Duration {
        let q = self.dispatch.queued.load(Ordering::SeqCst);
        let per_round = (self.replicas() * self.max_batch).max(1);
        Duration::from_secs(((q / per_round) as u64).clamp(1, 30))
    }

    /// Enqueue a request; returns the handle to its event pipeline.
    ///
    /// Routing: the block-aligned prompt-prefix hash names an affinity
    /// shard (warm prefix trie); the request spills to the least-loaded
    /// shard only when the affinity shard's queue already exceeds its
    /// fair share of `max_queue`.
    pub fn submit(
        &self,
        req: GenerateRequest,
    ) -> Result<ResponseHandle, SubmitError> {
        if req.prompt_ids.len() != self.geometry.prompt_len {
            return Err(SubmitError::Invalid(format!(
                "prompt must be padded to {} tokens (got {})",
                self.geometry.prompt_len,
                req.prompt_ids.len()
            )));
        }
        let model = req.method.weights_for(&req.backbone);
        if !self.known_models.contains(&model) {
            return Err(SubmitError::Invalid(format!(
                "unknown backbone '{}' for method '{}'",
                req.backbone,
                req.method.name()
            )));
        }
        let d = &self.dispatch;
        if d.draining.load(Ordering::SeqCst) {
            d.rejected_draining.fetch_add(1, Ordering::SeqCst);
            return Err(SubmitError::Draining {
                retry_after: self.retry_after_hint(),
            });
        }
        // fairness cap first: a flooding client must be refused by its
        // own budget before it can even contend for the global queue
        let permit = match (&req.client, self.max_per_client) {
            (Some(name), cap) if cap > 0 => {
                let mut m = d.clients.lock().unwrap_or_else(|e| e.into_inner());
                let n = m.entry(name.clone()).or_insert(0);
                if *n >= cap {
                    let in_flight = *n;
                    drop(m);
                    d.rejected_client_cap.fetch_add(1, Ordering::SeqCst);
                    return Err(SubmitError::ClientCap {
                        client: name.clone(),
                        in_flight,
                        cap,
                        retry_after: self.retry_after_hint(),
                    });
                }
                *n += 1;
                ClientPermit {
                    held: Some((d.clients.clone(), name.clone())),
                }
            }
            _ => ClientPermit::unlimited(),
        };
        // reserve-then-rollback: acting on the fetch_add result keeps
        // the bound exact under concurrent submits (a load-then-add
        // here would be the same racy RMW the worker's decrement had)
        let q = d.queued.fetch_add(1, Ordering::SeqCst);
        if q >= self.max_queue {
            d.queued.fetch_sub(1, Ordering::SeqCst);
            drop(permit); // release the fairness slot with the refusal
            d.rejected_queue_full.fetch_add(1, Ordering::SeqCst);
            return Err(SubmitError::QueueFull {
                queued: q,
                max: self.max_queue,
                retry_after: self.retry_after_hint(),
            });
        }
        let now = Instant::now();
        let ctl = Arc::new(RequestCtl {
            cancelled: AtomicBool::new(false),
            deadline: req.timeout.map(|t| now + t),
            max_new_tokens: req.max_new_tokens,
        });
        // prefix-affinity routing with least-loaded spill, over *live*
        // shards only — a dead shard's queue is never drained
        let shards = &d.shards;
        let live: Vec<usize> = shards
            .iter()
            .filter(|s| s.state() != SHARD_DEAD)
            .map(|s| s.id)
            .collect();
        if live.is_empty() {
            d.queued.fetch_sub(1, Ordering::SeqCst);
            drop(permit);
            d.rejected_degraded.fetch_add(1, Ordering::SeqCst);
            return Err(SubmitError::Degraded {
                retry_after: self.retry_after_hint(),
            });
        }
        let affinity = (prefix_affinity_hash(
            &req.prompt_ids,
            self.geometry.block_size,
        ) % shards.len() as u64) as usize;
        let fair_share = (self.max_queue / live.len()).max(1);
        let target = if live.contains(&affinity)
            && shards[affinity].depth.load(Ordering::Relaxed) < fair_share
        {
            d.routed_affinity.fetch_add(1, Ordering::SeqCst);
            affinity
        } else {
            d.routed_spill.fetch_add(1, Ordering::SeqCst);
            d.least_loaded_live(None).unwrap_or(affinity)
        };
        // the continuous machine carries tau per lane; the closed path
        // folds the override into the group key (tau-uniform groups)
        let key = if self.continuous {
            GroupKey::new(req.backbone.clone(), req.method)
        } else {
            let tau =
                if req.method.uses_tau_conf() { req.tau_conf } else { None };
            GroupKey::new(req.backbone.clone(), req.method).with_tau(tau)
        };
        let (etx, erx) = mpsc::channel();
        let slot = LaneSlot::new(etx);
        let mut pending = Pending {
            key,
            enqueued: now,
            deadline: ctl.deadline,
            payload: Submit {
                req,
                events: slot,
                ctl: ctl.clone(),
                submitted: now,
                affinity,
                rid: d.next_rid.fetch_add(1, Ordering::SeqCst),
                _permit: permit,
            },
        };
        // push-retry: a shard may refuse (drain began, or its worker
        // just died and the supervisor closed the inbox) between the
        // liveness check and the push — try the remaining live shards
        // before giving up
        let mut tried = vec![target];
        let mut placed = false;
        loop {
            let t = *tried.last().expect("tried starts non-empty");
            match shards[t].push(pending) {
                Ok(()) => {
                    placed = true;
                    break;
                }
                Err(p) => {
                    if d.draining.load(Ordering::SeqCst) {
                        d.queued.fetch_sub(1, Ordering::SeqCst);
                        d.rejected_draining.fetch_add(1, Ordering::SeqCst);
                        return Err(SubmitError::Draining {
                            retry_after: self.retry_after_hint(),
                        });
                    }
                    pending = p;
                    let next = shards
                        .iter()
                        .filter(|s| {
                            s.state() != SHARD_DEAD && !tried.contains(&s.id)
                        })
                        .min_by_key(|s| {
                            s.depth.load(Ordering::Relaxed)
                                + s.in_flight.load(Ordering::Relaxed)
                        })
                        .map(|s| s.id);
                    match next {
                        Some(n) => tried.push(n),
                        None => break,
                    }
                }
            }
        }
        if !placed {
            d.queued.fetch_sub(1, Ordering::SeqCst);
            d.rejected_degraded.fetch_add(1, Ordering::SeqCst);
            return Err(SubmitError::Degraded {
                retry_after: self.retry_after_hint(),
            });
        }
        // hint every other shard: an idle sibling may wake and steal
        // once the request has waited out the batching window
        for s in shards {
            if !tried.contains(&s.id) {
                s.cv.notify_all();
            }
        }
        Ok(ResponseHandle::new(erx, ctl))
    }

    /// Merged per-(backbone, method) metrics across every shard.
    /// Sample-exact: shards reply with their raw aggregators and the
    /// merge concatenates samples, so percentiles equal a single-worker
    /// run over the same requests.
    pub fn metrics(&self) -> Result<Json> {
        let mut merged: HashMap<String, MetricsAggregator> = HashMap::new();
        for shard in &self.dispatch.shards {
            if shard.state() == SHARD_DEAD {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            if !shard.send_control(ControlMsg::Metrics(tx)) {
                continue;
            }
            // a worker that dies mid-request takes its per-cell
            // aggregators down with its core; skip the shard rather
            // than fail the whole endpoint (the supervision counters
            // still record the loss)
            let Ok(m) = rx.recv() else { continue };
            for (k, v) in m {
                match merged.entry(k) {
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        e.get_mut().merge(&v)
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(v);
                    }
                }
            }
        }
        let mut obj: BTreeMap<String, Json> =
            merged.into_iter().map(|(k, v)| (k, v.to_json())).collect();
        let sup = self.dispatch.supervision();
        obj.insert(
            "shard_panics".to_string(),
            Json::num(sup.shard_panics as f64),
        );
        obj.insert(
            "redispatched_requests".to_string(),
            Json::num(sup.redispatched_requests as f64),
        );
        obj.insert(
            "watchdog_trips".to_string(),
            Json::num(sup.watchdog_trips as f64),
        );
        obj.insert("supervision".to_string(), sup.to_json());
        obj.insert(
            "preemption".to_string(),
            self.dispatch.preemption().to_json(),
        );
        Ok(Json::Obj(obj))
    }

    /// Merged health across every shard: numeric gauges/counters are
    /// summed, the per-shard breakdown rides along under `"shards"`,
    /// and the dispatcher contributes its routing/rejection counters.
    pub fn health(&self) -> Result<Json> {
        let mut per_shard = Vec::with_capacity(self.replicas());
        for shard in &self.dispatch.shards {
            let state_name = match shard.state() {
                SHARD_DEAD => "dead",
                SHARD_RESTARTING => "restarting",
                _ => "live",
            };
            // a dead (or mid-restart, inbox-refusing) shard cannot
            // answer: synthesize its entry from supervisor-side state
            // so /healthz never hangs on a shard that will not reply
            let reply = if shard.state() == SHARD_DEAD {
                None
            } else {
                let (tx, rx) = mpsc::channel();
                if shard.send_control(ControlMsg::Health(tx)) {
                    rx.recv().ok()
                } else {
                    None
                }
            };
            let mut entry = match reply {
                Some(Json::Obj(m)) => m,
                _ => BTreeMap::from([
                    ("status".to_string(), Json::str(state_name)),
                    (
                        "replica".to_string(),
                        Json::num(shard.id as f64),
                    ),
                    (
                        "queued".to_string(),
                        Json::num(
                            shard.depth.load(Ordering::SeqCst) as f64
                        ),
                    ),
                    ("in_flight_lanes".to_string(), Json::num(0.0)),
                ]),
            };
            entry.insert("state".to_string(), Json::str(state_name));
            entry.insert(
                "last_heartbeat_ms".to_string(),
                Json::num(shard.heartbeat_age_ms() as f64),
            );
            entry.insert(
                "restarts".to_string(),
                Json::num(shard.restarts.load(Ordering::SeqCst) as f64),
            );
            per_shard.push(Json::Obj(entry));
        }
        let d = &self.dispatch;
        let mut merged: BTreeMap<String, Json> = BTreeMap::new();
        for h in &per_shard {
            let Json::Obj(m) = h else { continue };
            for (k, v) in m {
                if k == "replica"
                    || k == "state"
                    || k == "last_heartbeat_ms"
                {
                    continue; // per-shard identity/liveness: not summable
                }
                match v {
                    Json::Num(n) => {
                        let slot = merged
                            .entry(k.clone())
                            .or_insert(Json::Num(0.0));
                        if let Json::Num(acc) = slot {
                            *acc += n;
                        }
                    }
                    other => {
                        merged.entry(k.clone()).or_insert_with(|| other.clone());
                    }
                }
            }
        }
        let count = |c: &AtomicU64| {
            Json::num(c.load(Ordering::SeqCst) as f64)
        };
        merged.insert("replicas".into(), Json::num(self.replicas() as f64));
        merged.insert(
            "rejected_queue_full".into(),
            count(&d.rejected_queue_full),
        );
        merged.insert(
            "rejected_client_cap".into(),
            count(&d.rejected_client_cap),
        );
        merged
            .insert("rejected_draining".into(), count(&d.rejected_draining));
        merged.insert("routed_affinity".into(), count(&d.routed_affinity));
        merged.insert("routed_spill".into(), count(&d.routed_spill));
        merged
            .insert("rejected_degraded".into(), count(&d.rejected_degraded));
        let any_dead =
            d.shards.iter().any(|s| s.state() == SHARD_DEAD);
        merged.insert("degraded".into(), Json::Bool(any_dead));
        let sup = d.supervision();
        merged.insert(
            "shard_panics".into(),
            Json::num(sup.shard_panics as f64),
        );
        merged.insert(
            "watchdog_trips".into(),
            Json::num(sup.watchdog_trips as f64),
        );
        merged.insert(
            "redispatched_requests".into(),
            Json::num(sup.redispatched_requests as f64),
        );
        merged.insert("supervision".into(), sup.to_json());
        merged.insert("shards".into(), Json::Arr(per_shard));
        Ok(Json::Obj(merged))
    }

    /// Begin a graceful drain without blocking: new submits are refused
    /// with [`SubmitError::Draining`] (HTTP 503), every *queued*
    /// request is answered with a terminal `Aborted{"shutdown"}`, and
    /// in-flight lanes keep decoding to their natural `Finished` — a
    /// rolling restart never truncates a response mid-stream. Call
    /// [`Router::join`] to wait for the workers to exit.
    pub fn begin_drain(&self) {
        self.dispatch.draining.store(true, Ordering::SeqCst);
        for shard in &self.dispatch.shards {
            let mut inbox = shard.lock();
            inbox.shutdown = true;
            drop(inbox);
            shard.cv.notify_all();
        }
    }

    /// Wait for every shard worker to finish its drain and exit.
    pub fn join(mut self) {
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Graceful drain, blocking until every worker has exited: every
    /// request still in the system receives a terminal event — nothing
    /// is ever answered by a silently dropped channel. Queued requests
    /// abort with `Aborted{"shutdown"}`; in-flight continuous lanes
    /// finish normally (the closed-batch worker likewise decodes its
    /// remaining queue to completion).
    pub fn shutdown(self) {
        self.begin_drain();
        self.join();
    }
}

// ---------------------------------------------------------------------------
// Shard supervision: spawn, watch, recover, respawn
// ---------------------------------------------------------------------------

/// How one worker incarnation ended.
enum WorkerExit {
    /// Graceful: the drain finished (or the core never loaded — the
    /// load error already went out through the handshake channel).
    Clean,
    /// The supervisor bumped the shard generation (watchdog trip) and
    /// this incarnation noticed and stood down.
    Superseded,
    /// `catch_unwind` caught a panic inside the worker loop.
    Panicked(String),
}

fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

/// Run one shard forever: spawn its decode worker, watch it (exit +
/// stall watchdog), and on failure recover — supersede the incarnation,
/// sweep the recovery registry (replay or abort each admitted request
/// by the idempotency rule), then respawn within the restart budget or
/// take the shard out of service.
///
/// The supervisor thread is the one `Router::join` waits on; it returns
/// only when its worker drained cleanly or the shard died.
fn supervise_shard(
    artifacts: PathBuf,
    core_pool: usize,
    d: Arc<Dispatch>,
    id: usize,
    cfg: RouterConfig,
    ready: mpsc::Sender<Result<Geometry, String>>,
) {
    let shard = d.shards[id].clone();
    // consumed on the first generation: startup errors surface through
    // Router::start, later ones through /healthz + the supervision
    // counters
    let mut ready = Some(ready);
    let mut restart_log: Vec<Instant> = Vec::new();
    let mut pending_recovery: Option<Instant> = None;
    loop {
        let gen = shard.generation.load(Ordering::SeqCst);
        shard.beat(false);
        let (ltx, lrx) = mpsc::channel::<Result<Geometry, String>>();
        let wshard = shard.clone();
        let wpeers = d.shards.clone();
        let wq = d.queued.clone();
        let wcfg = cfg.clone();
        let wartifacts = artifacts.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("cdlm-decode-worker-{id}-g{gen}"))
            .spawn(move || -> WorkerExit {
                let mut core =
                    match ServingCore::load(&wartifacts, core_pool) {
                        Ok(c) => {
                            let _ = ltx
                                .send(Ok(c.rt.manifest.geometry.clone()));
                            c
                        }
                        Err(e) => {
                            let _ = ltx.send(Err(format!("{e:#}")));
                            return WorkerExit::Clean;
                        }
                    };
                let replicas = wpeers.len();
                let out = catch_unwind(AssertUnwindSafe(|| {
                    if wcfg.continuous {
                        worker_loop_continuous(
                            &mut core, wshard, wpeers, wcfg, wq, gen,
                        )
                    } else {
                        worker_loop_closed(
                            &mut core, wshard, wcfg, replicas, wq, gen,
                        )
                    }
                }));
                match out {
                    Ok(exit) => exit,
                    Err(p) => WorkerExit::Panicked(panic_msg(p)),
                }
            });
        let handle = match spawned {
            Ok(h) => h,
            Err(e) => {
                if let Some(r) = ready.take() {
                    let _ = r.send(Err(format!(
                        "failed to spawn decode worker: {e}"
                    )));
                } else {
                    eprintln!(
                        "shard {id}: failed to respawn decode worker: {e}"
                    );
                    mark_shard_dead(&d, &shard);
                }
                return;
            }
        };
        // load handshake: geometry up, or a load error (first
        // generation reports through Router::start; a respawn that
        // cannot reload its core kills the shard)
        match lrx.recv() {
            Ok(Ok(geom)) => {
                if let Some(r) = ready.take() {
                    let _ = r.send(Ok(geom));
                } else if let Some(t0) = pending_recovery.take() {
                    let ms = t0.elapsed().as_millis() as u64;
                    d.recovery_count.fetch_add(1, Ordering::SeqCst);
                    d.recovery_total_ms.fetch_add(ms, Ordering::SeqCst);
                    d.recovery_max_ms.fetch_max(ms, Ordering::SeqCst);
                }
                shard.set_state(SHARD_LIVE);
            }
            Ok(Err(e)) => {
                let _ = handle.join();
                if let Some(r) = ready.take() {
                    let _ = r.send(Err(e));
                } else {
                    eprintln!(
                        "shard {id}: core reload failed during \
                         recovery: {e}"
                    );
                    mark_shard_dead(&d, &shard);
                }
                return;
            }
            Err(_) => {
                let _ = handle.join();
                if let Some(r) = ready.take() {
                    let _ = r
                        .send(Err("worker died during startup".to_string()));
                } else {
                    eprintln!(
                        "shard {id}: worker died while reloading its core"
                    );
                    mark_shard_dead(&d, &shard);
                }
                return;
            }
        }
        // monitor: poll for worker exit and for a stalled heartbeat.
        // 20ms granularity is far below any sane watchdog deadline and
        // adds no load (the worker never blocks on the supervisor).
        let deadline_ms = cfg.watchdog_deadline.as_millis() as u64;
        let mut wedged = false;
        loop {
            if handle.is_finished() {
                break;
            }
            if deadline_ms > 0
                && shard.busy.load(Ordering::SeqCst)
                && shard.heartbeat_age_ms() > deadline_ms
            {
                wedged = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        if wedged {
            // treat like a panic, but the thread is still running:
            // abandon the handle (the incarnation observes the
            // generation bump below and stands down on its own; its
            // seized slots make every send it attempts a no-op)
            d.watchdog_trips.fetch_add(1, Ordering::SeqCst);
            eprintln!(
                "shard {id}: watchdog tripped (busy, no heartbeat for \
                 {}ms > {deadline_ms}ms); superseding worker",
                shard.heartbeat_age_ms()
            );
        } else {
            let exit = match handle.join() {
                Ok(exit) => exit,
                // a panic outside catch_unwind (core load/handshake)
                Err(p) => WorkerExit::Panicked(panic_msg(p)),
            };
            match exit {
                WorkerExit::Clean | WorkerExit::Superseded => return,
                WorkerExit::Panicked(msg) => {
                    d.shard_panics.fetch_add(1, Ordering::SeqCst);
                    eprintln!("shard {id}: worker panicked: {msg}");
                }
            }
        }
        // ---- recovery: supersede the incarnation, settle every
        // admitted-but-unanswered request, then respawn or die
        let t0 = Instant::now();
        shard.set_state(SHARD_RESTARTING);
        shard.generation.fetch_add(1, Ordering::SeqCst);
        shard.cv.notify_all(); // wake a parked zombie so it stands down
        let now = Instant::now();
        restart_log.retain(|t| now.duration_since(*t) < cfg.restart_window);
        let can_restart = restart_log.len() < cfg.restart_budget;
        let swept: Vec<Recoverable> = {
            let mut reg = shard.registry_lock();
            reg.drain().map(|(_, rec)| rec).collect()
        };
        for rec in swept {
            let (committed, terminal, tokens) = rec.slot.seize();
            if terminal {
                continue; // answered between death and sweep
            }
            if committed {
                // the client consumed part of one decode trace: a
                // replay could only duplicate or contradict it, so the
                // idempotency rule says abort (client retries with the
                // Retry-After hint)
                d.aborted_shard_failure.fetch_add(1, Ordering::SeqCst);
                rec.slot.force_terminal(LaneEvent::Aborted {
                    reason: "shard_failure: worker lost after streaming \
                             began; partial output cannot be replayed"
                        .to_string(),
                    steps: 0,
                    model_calls: 0,
                    committed_tokens: tokens,
                });
                continue;
            }
            // no delta ever reached the client: per-lane traces are
            // pure functions of (prompt, seed), so a from-scratch
            // replay is byte-identical and invisible
            redispatch(&d, &shard, rec, can_restart);
        }
        if can_restart {
            restart_log.push(now);
            shard.restarts.fetch_add(1, Ordering::SeqCst);
            pending_recovery = Some(t0);
            continue;
        }
        eprintln!(
            "shard {id}: restart budget exhausted ({} failures within \
             {:?}); taking shard out of service",
            restart_log.len() + 1,
            cfg.restart_window
        );
        mark_shard_dead(&d, &shard);
        return;
    }
}

/// Queue one recovered request for a fresh decode: on the shard's own
/// (about-to-respawn) inbox when it still has restart budget, else on
/// the least-loaded live sibling. Recovery bypasses admission control —
/// the request was already admitted once; bouncing it on `max_queue`
/// now would turn a transparent replay into a client-visible failure.
fn redispatch(d: &Dispatch, from: &Shard, rec: Recoverable, self_ok: bool) {
    let rid = d.next_rid.fetch_add(1, Ordering::SeqCst);
    // continuous-path key (the closed path keeps no recovery registry)
    let key = GroupKey::new(rec.req.backbone.clone(), rec.req.method);
    let pending = Pending {
        key,
        enqueued: rec.submitted,
        deadline: rec.ctl.deadline,
        payload: Submit {
            req: rec.req,
            events: LaneSlot::replay(&rec.slot),
            ctl: rec.ctl,
            submitted: rec.submitted,
            affinity: rec.affinity,
            rid,
            _permit: ClientPermit::unlimited(),
        },
    };
    let target = if self_ok {
        Some(from.id)
    } else {
        d.least_loaded_live(Some(from.id))
    };
    let refused = match target {
        Some(t) => {
            // the sweep's take already decremented nothing — these
            // requests left `queued` at admission — so re-queueing
            // must count them back in
            d.queued.fetch_add(1, Ordering::SeqCst);
            match d.shards[t].push(pending) {
                Ok(()) => {
                    d.redispatched.fetch_add(1, Ordering::SeqCst);
                    None
                }
                Err(p) => {
                    d.queued.fetch_sub(1, Ordering::SeqCst);
                    Some(p)
                }
            }
        }
        None => Some(pending),
    };
    if let Some(p) = refused {
        d.aborted_shard_failure.fetch_add(1, Ordering::SeqCst);
        p.payload
            .abort("shard_failure: no healthy shard available for replay");
    }
}

/// Take a shard out of service for good: flip it dead, close its inbox,
/// and evacuate everything stranded inside — queued requests move to
/// live siblings, pending control messages are dropped (their receivers
/// synthesize a reply from supervisor-side state).
fn mark_shard_dead(d: &Dispatch, shard: &Shard) {
    shard.set_state(SHARD_DEAD);
    d.dead_shards.fetch_add(1, Ordering::SeqCst);
    shard.in_flight.store(0, Ordering::Relaxed);
    let (stranded, control) = {
        let mut inbox = shard.lock();
        inbox.shutdown = true;
        let mut stranded: Vec<Pending<Submit>> = Vec::new();
        while let Some((_k, items)) = inbox.batcher.pop_any() {
            stranded.extend(items);
        }
        shard.sync_depth(&inbox);
        (stranded, std::mem::take(&mut inbox.control))
    };
    drop(control);
    for p in stranded {
        // still counted in `queued` (never taken by a worker): keep the
        // count on a successful move, give it back on refusal
        let moved = match d.least_loaded_live(Some(shard.id)) {
            Some(t) => d.shards[t].push(p).err(),
            None => Some(p),
        };
        match moved {
            None => {
                d.redispatched.fetch_add(1, Ordering::SeqCst);
            }
            Some(p) => {
                d.queued.fetch_sub(1, Ordering::SeqCst);
                d.aborted_shard_failure.fetch_add(1, Ordering::SeqCst);
                p.payload.abort(
                    "shard_failure: no healthy shard available for replay",
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Continuous worker: block-step machines + mid-flight admission
// ---------------------------------------------------------------------------

/// Per-lane response ticket: the lane's event channel, its control
/// block, arrival/admission instants (TTFT/TTLT accounting), and the
/// streaming state (incremental detokenizer + committed-token count the
/// generation budget is charged against).
struct Ticket {
    events: Arc<LaneSlot>,
    ctl: Arc<RequestCtl>,
    enqueued: Instant,
    admitted: Instant,
    detok: StreamDecoder,
    committed_tokens: usize,
    blocks_committed: usize,
    /// The event channel came back disconnected (client dropped its
    /// handle) or the slot was seized by the supervisor: cancel the
    /// lane at the next block boundary.
    dead: bool,
    /// Router-wide request id, keying this shard's recovery registry
    /// while the lane is admitted-but-unanswered.
    rid: u64,
    /// Static SLO priority from the request; the preemption passes
    /// compare it age-boosted (see [`effective_priority`]).
    priority: i32,
    /// Client fairness slot, released when the ticket drops on any
    /// terminal path.
    _permit: ClientPermit,
}

impl Ticket {
    /// Split a queued submit into its lane ticket and the request to
    /// admit (the admission instant is stamped here).
    fn from_submit(sub: Submit) -> (Ticket, GenerateRequest) {
        (
            Ticket {
                events: sub.events,
                ctl: sub.ctl,
                enqueued: sub.submitted,
                admitted: Instant::now(),
                detok: StreamDecoder::new(),
                committed_tokens: 0,
                blocks_committed: 0,
                dead: false,
                rid: sub.rid,
                priority: sub.req.priority,
                _permit: sub._permit,
            },
            sub.req,
        )
    }
}

/// Why a lane leaves its batch early at a block boundary.
enum Cancel {
    /// Terminal `Aborted`: the work is wasted.
    Abort(&'static str),
    /// `max_new_tokens` reached: terminal `Finished` with the
    /// truncated-but-valid partial response.
    Budget,
}

/// The block-boundary cancellation policy, in priority order.
fn cancel_of(t: &Ticket, now: Instant) -> Option<Cancel> {
    if t.dead {
        return Some(Cancel::Abort("client disconnected"));
    }
    if t.ctl.is_cancelled() {
        return Some(Cancel::Abort("cancelled by client"));
    }
    if t.ctl.deadline.is_some_and(|d| now > d) {
        return Some(Cancel::Abort("deadline exceeded"));
    }
    if t.ctl.max_new_tokens.is_some_and(|m| t.committed_tokens >= m) {
        return Some(Cancel::Budget);
    }
    None
}

/// Milliseconds of waiting that buy one effective-priority point. The
/// boost applies to queued requests, parked lanes, and live lanes
/// alike, so preemption is strictly relative: holding a lane does not
/// freeze a request's rank, and being preempted does not erase the
/// seniority a lane accrued while waiting.
const PRIORITY_AGE_MS: u64 = 500;

/// SLO scheduling weight at a block boundary: static request priority
/// plus one point per [`PRIORITY_AGE_MS`] elapsed since `enqueued`.
/// All preempt/resume decisions compare these values, and preemption
/// requires a *strictly* greater challenger, so equal-priority traffic
/// never thrashes.
fn effective_priority(priority: i32, enqueued: Instant, now: Instant) -> i64 {
    priority as i64
        + (now.duration_since(enqueued).as_millis() as u64 / PRIORITY_AGE_MS)
            as i64
}

/// Serving counters surfaced on `/healthz`. Live batches report their
/// own admission counts; these fold in batches that already dropped
/// (poisoned, or reclaimed after draining).
#[derive(Default)]
struct ServeStats {
    closed_total_admissions: u64,
    closed_mid_flight: u64,
    closed_kv_allocs: u64,
    closed_prefix_hits: u64,
    closed_prefix_hit_blocks: u64,
    closed_prefix_evictions: u64,
    retired_early: u64,
    /// Requests terminated while still queued (deadline already expired
    /// or cancelled before a lane/prefill was ever spent on them).
    aborted_queued: u64,
    /// Lanes cancelled mid-decode (disconnect, deadline, explicit
    /// cancel, shutdown) — their KV slots and chain pins were reclaimed
    /// at the block boundary.
    aborted_inflight: u64,
    /// Requests this shard admitted into a lane or group.
    admitted_requests: u64,
    /// Of those, how many were admitted by the shard their prompt's
    /// prefix hash named (affinity hit rate = affinity / admitted).
    affinity_admissions: u64,
    /// Queued requests this shard took from a sibling's inbox at a
    /// block boundary (thief-side count).
    stolen: u64,
    /// Preempt/resume counters folded in from dropped batches, mirroring
    /// the `closed_*` admission counters above.
    closed_preempts: u64,
    closed_resumes: u64,
    closed_spilled_bytes: u64,
}

impl ServeStats {
    /// Fold a batch's lifetime counters in before dropping it.
    fn absorb(&mut self, st: &BatchState) {
        self.closed_total_admissions += st.total_admissions;
        self.closed_mid_flight += st.mid_flight_admissions;
        self.closed_kv_allocs += st.kv_total_allocs();
        self.closed_prefix_hits += st.prefix_hits();
        self.closed_prefix_hit_blocks += st.prefix_hit_blocks();
        self.closed_prefix_evictions += st.prefix_evictions();
        self.closed_preempts += st.kv_preempts();
        self.closed_resumes += st.kv_resumes();
        self.closed_spilled_bytes += st.kv_spilled_bytes();
    }
}

/// KV lanes a batch draws from the `pool_capacity` budget (cache-less
/// methods hold no slots).
fn kv_lanes_of(ab: &ActiveBatch<Ticket>) -> usize {
    if ab.key.method.uses_kv_cache() {
        ab.state.capacity()
    } else {
        0
    }
}

fn worker_loop_continuous(
    core: &mut ServingCore,
    shard: Arc<Shard>,
    peers: Vec<Arc<Shard>>,
    cfg: RouterConfig,
    queued: Arc<AtomicUsize>,
    my_gen: usize,
) -> WorkerExit {
    let mut active: Vec<ActiveBatch<Ticket>> = Vec::new();
    let mut stats = ServeStats::default();
    let mut draining = false;
    // fault-injection state: per-incarnation ordinals the plan's
    // step/admit triggers match against (None plan = zero overhead
    // beyond two counter bumps per iteration)
    let fault = cfg.fault_plan.clone();
    let mut fault_steps: u64 = 0;
    let mut fault_admits: u64 = 0;
    // lanes one new machine would hold (each lane needs at most one KV
    // slot, so total lanes bound total continuous KV memory)
    let bucket_cap = core
        .rt
        .manifest
        .buckets
        .iter()
        .copied()
        .max()
        .unwrap_or(1);
    let batch_cap = cfg.max_batch.clamp(1, bucket_cap);
    loop {
        // ---- 0. supersession check: the supervisor declared this
        // incarnation wedged and already swept + re-dispatched its
        // requests. Stand down — but first answer any lane we still
        // hold: an admission that raced the supervisor's registry sweep
        // (we wedged inside the admission phase) would otherwise strand
        // its client. Sends on slots the supervisor seized fail
        // harmlessly, so already-recovered lanes are untouched.
        if shard.generation.load(Ordering::SeqCst) != my_gen {
            for ab in active.iter_mut() {
                for lane in ab.ticketed_lanes() {
                    if let Some((t, o)) = ab.cancel(lane) {
                        shard.registry_remove(t.rid);
                        let _ = t.events.send(LaneEvent::Aborted {
                            reason: "shard_failure: worker superseded by \
                                     its supervisor"
                                .to_string(),
                            steps: o.steps,
                            model_calls: o.model_calls,
                            committed_tokens: t.committed_tokens,
                        });
                    }
                }
                // parked lanes are admitted work too: answer them so a
                // preempted client is never stranded by supersession
                while !ab.parked.is_empty() {
                    let (t, o) = ab.discard_parked(0);
                    shard.registry_remove(t.rid);
                    let _ = t.events.send(LaneEvent::Aborted {
                        reason: "shard_failure: worker superseded by \
                                 its supervisor"
                            .to_string(),
                        steps: o.steps,
                        model_calls: o.model_calls,
                        committed_tokens: t.committed_tokens,
                    });
                }
            }
            return WorkerExit::Superseded;
        }
        // ---- 1. ingest the inbox (park on the condvar only when fully
        // idle — drained batches retained as warm prefix caches don't
        // count; a sibling with queued work keeps the nap short so a
        // steal opportunity is never slept through)
        // parked lanes count as live work: the worker must keep cycling
        // so its resume pass can seat them the moment a lane frees
        let any_live =
            active.iter().any(|ab| !ab.is_empty() || !ab.parked.is_empty());
        shard.beat(any_live);
        let peers_queued = peers.iter().any(|p| {
            p.id != shard.id && p.depth.load(Ordering::Relaxed) > 0
        });
        let mut inbox = shard.lock();
        if !any_live
            && !draining
            && inbox.control.is_empty()
            && !inbox.shutdown
        {
            let nap = if !inbox.batcher.is_empty() || peers_queued {
                Duration::from_millis(1)
            } else {
                Duration::from_millis(200)
            };
            inbox = shard
                .cv
                .wait_timeout(inbox, nap)
                .unwrap_or_else(|e| e.into_inner())
                .0;
        }
        let control = std::mem::take(&mut inbox.control);
        if inbox.shutdown {
            draining = true;
        }
        // ---- 1.5 graceful drain begins: answer every *queued* request
        // with a terminal Aborted{"shutdown"} (the inbox refuses pushes
        // once its shutdown flag is set, so nothing arrives after this
        // sweep), then keep stepping the in-flight lanes below until
        // they all finish naturally.
        let mut drained: Vec<Pending<Submit>> = Vec::new();
        if draining {
            while let Some((_key, items)) = inbox.batcher.pop_any() {
                drained.extend(items);
            }
        }
        let queued_here = inbox.batcher.len();
        shard.sync_depth(&inbox);
        drop(inbox);
        for p in drained {
            queued.fetch_sub(1, Ordering::SeqCst);
            stats.aborted_queued += 1;
            p.payload.abort("shutdown");
        }
        for msg in control {
            match msg {
                ControlMsg::Metrics(tx) => {
                    let _ = tx.send(core.metrics.clone());
                }
                ControlMsg::Health(tx) => {
                    let _ = tx.send(health_json(
                        core,
                        shard.id,
                        queued_here,
                        &active,
                        &stats,
                    ));
                }
            }
        }
        // ---- 1.6 reap expired queued requests every iteration: a dead
        // client's permit and terminal 504 must not wait for a free
        // lane of its key to show up (the worker wakes at least every
        // 200ms even when idle, so the delay is bounded by one wakeup)
        if !draining {
            let expired = {
                let mut inbox = shard.lock();
                let v = inbox.batcher.take_expired(Instant::now());
                shard.sync_depth(&inbox);
                v
            };
            for p in expired {
                queued.fetch_sub(1, Ordering::SeqCst);
                stats.aborted_queued += 1;
                p.payload.abort("deadline expired before admission");
            }
        }
        // ---- 1.7 work stealing at the block boundary: capacity here
        // must not idle while a sibling's queue holds requests that
        // already waited out their batching window (`max_wait` is the
        // age gate — a fresh affinity-routed arrival is left for its
        // own shard). Lock discipline: never two inboxes at once — the
        // victim's lock is released before our own is retaken, so steal
        // cycles cannot deadlock.
        if !draining && peers.len() > 1 {
            let now = Instant::now();
            let mut loot: Vec<Pending<Submit>> = Vec::new();
            let mut reaped: Vec<Pending<Submit>> = Vec::new();
            // (a) deficit steal: live batches with free lanes our own
            // queue cannot fill
            let (wants, idle) = {
                let inbox = shard.lock();
                let wants: Vec<(GroupKey, usize)> = active
                    .iter()
                    .filter_map(|ab| {
                        let free = ab.free_lanes();
                        let own = inbox.batcher.len_for(&ab.key);
                        (free > own).then(|| (ab.key.clone(), free - own))
                    })
                    .collect();
                let idle = inbox.batcher.is_empty()
                    && active
                        .iter()
                        .all(|ab| ab.is_empty() && ab.parked.is_empty());
                (wants, idle)
            };
            for (key, mut need) in wants {
                for victim in &peers {
                    if need == 0 {
                        break;
                    }
                    if victim.id == shard.id
                        || victim.depth.load(Ordering::Relaxed) == 0
                    {
                        continue;
                    }
                    let mut vin = victim.lock();
                    let (fresh, expired) = vin
                        .batcher
                        .steal_for(&key, need, now, cfg.max_wait);
                    victim.sync_depth(&vin);
                    drop(vin);
                    need = need.saturating_sub(fresh.len());
                    loot.extend(fresh);
                    reaped.extend(expired);
                }
            }
            // (b) idle steal: nothing of our own at all — take up to a
            // batch of the oldest keys from the deepest sibling
            if idle && loot.is_empty() {
                let victim = peers
                    .iter()
                    .filter(|p| p.id != shard.id)
                    .max_by_key(|p| p.depth.load(Ordering::Relaxed))
                    .filter(|p| p.depth.load(Ordering::Relaxed) > 0);
                if let Some(victim) = victim {
                    let mut vin = victim.lock();
                    for key in vin.batcher.keys_by_age() {
                        if loot.len() >= batch_cap {
                            break;
                        }
                        let (fresh, expired) = vin.batcher.steal_for(
                            &key,
                            batch_cap - loot.len(),
                            now,
                            cfg.max_wait,
                        );
                        loot.extend(fresh);
                        reaped.extend(expired);
                    }
                    victim.sync_depth(&vin);
                }
            }
            for p in reaped {
                queued.fetch_sub(1, Ordering::SeqCst);
                stats.aborted_queued += 1;
                p.payload.abort("deadline expired before admission");
            }
            if !loot.is_empty() {
                stats.stolen += loot.len() as u64;
                let mut inbox = shard.lock();
                for p in loot {
                    inbox.batcher.push(p);
                }
                shard.sync_depth(&inbox);
            }
        }
        // ---- 2. open machines for queued keys no live batch can host.
        // A block-step batch admits later arrivals mid-flight, so there
        // is nothing to gain from holding a request back for a fuller
        // bucket: open immediately. `max_active` and `pool_capacity`
        // (total lanes ≈ total KV slots) bound continuous KV memory,
        // but a key with no live batch at all may exceed them —
        // otherwise sustained traffic on one key (whose batches never
        // drain thanks to mid-flight refills) would starve every other
        // key forever. The overflow is bounded by the number of
        // distinct queued keys (backbone × method, a dozen at most).
        let queued_keys = {
            let inbox = shard.lock();
            inbox.batcher.keys_by_age()
        };
        for key in queued_keys {
            let has_room = active
                .iter()
                .any(|ab| ab.key == key && ab.free_lanes() > 0);
            if has_room {
                continue;
            }
            let key_served = active.iter().any(|ab| ab.key == key);
            // only slot-holding lanes draw on the KV budget; the
            // cache-less baselines' batches are bounded by max_active
            let new_kv_lanes =
                if key.method.uses_kv_cache() { batch_cap } else { 0 };
            let over_caps = |batches: usize, kv_lanes: usize| {
                batches >= cfg.max_active.max(1)
                    || kv_lanes + new_kv_lanes
                        > cfg.pool_capacity.max(batch_cap)
            };
            let totals = |active: &[ActiveBatch<Ticket>]| {
                (active.len(), active.iter().map(kv_lanes_of).sum::<usize>())
            };
            let (n_all, kv_all) = totals(&active);
            if over_caps(n_all, kv_all) {
                // a served key only gets a second batch if room actually
                // exists once the retained warm caches are reclaimed —
                // check BEFORE evicting, so hopeless pressure never
                // destroys other keys' warm prefix chains for nothing
                // a batch with parked lanes is pinned (their spilled KV
                // resumes into *this* batch's pool), so it counts as
                // live for capacity even when no lane is stepping
                let pinned = |ab: &&ActiveBatch<Ticket>| {
                    !ab.is_empty() || !ab.parked.is_empty()
                };
                let n_live = active.iter().filter(pinned).count();
                let kv_live: usize =
                    active.iter().filter(pinned).map(kv_lanes_of).sum();
                if key_served && over_caps(n_live, kv_live) {
                    continue; // at capacity and this key already decodes
                }
                // reclaim the coldest drained machines (retained only as
                // warm prefix caches) until we're under the caps
                loop {
                    let (n, kv) = totals(&active);
                    if !over_caps(n, kv) {
                        break;
                    }
                    let idle = active
                        .iter()
                        .enumerate()
                        .filter(|(_, ab)| {
                            ab.is_empty() && ab.parked.is_empty()
                        })
                        .min_by_key(|(_, ab)| ab.last_active)
                        .map(|(i, _)| i);
                    let Some(i) = idle else { break };
                    let reclaimed = active.remove(i);
                    stats.absorb(&reclaimed.state);
                }
            }
            let opts = DecodeOpts::defaults(core.geometry());
            match core.open_batch(&key, opts, cfg.max_batch) {
                Ok(mut state) => {
                    state.set_prefix_cache(cfg.prefix_cache);
                    active.push(ActiveBatch::new(key, state));
                }
                Err(e) => {
                    // fail this key's queued requests (bad weights)
                    let msg = format!("decode failed: {e:#}");
                    let (fresh, expired) = {
                        let mut inbox = shard.lock();
                        let r = inbox.batcher.take_for(
                            &key,
                            usize::MAX,
                            Instant::now(),
                        );
                        shard.sync_depth(&inbox);
                        r
                    };
                    queued.fetch_sub(
                        fresh.len() + expired.len(),
                        Ordering::SeqCst,
                    );
                    for p in expired {
                        stats.aborted_queued += 1;
                        p.payload.abort("deadline expired before admission");
                    }
                    for p in fresh {
                        p.payload.abort(&msg);
                    }
                }
            }
        }
        // ---- 2.7 resume pass: parked (preempted) lanes come back
        // first. Dead parked entries — client gone, cancelled, deadline
        // or generation budget hit while parked — are settled without
        // ever re-costing a lane. Then free lanes seat the
        // highest-effective-priority parked entries, unless a queued
        // request for the same key outranks them strictly (the lane is
        // left free for the admission pass below instead).
        for ab in active.iter_mut() {
            let now = Instant::now();
            for idx in (0..ab.parked.len()).rev() {
                let kind = cancel_of(&ab.parked[idx].1, now);
                match kind {
                    None => {}
                    Some(Cancel::Budget) => {
                        let (t, o) = ab.discard_parked(idx);
                        core.record_outcome(&ab.key, &o);
                        respond_lane(core, &shard, t, o);
                    }
                    Some(Cancel::Abort(reason)) => {
                        let (t, o) = ab.discard_parked(idx);
                        abort_lane(
                            core, &shard, &ab.key, &t, &o, reason,
                            &mut stats,
                        );
                    }
                }
            }
            while ab.free_lanes() > 0 && !ab.parked.is_empty() {
                let now = Instant::now();
                let (idx, eff) = ab
                    .parked
                    .iter()
                    .enumerate()
                    .map(|(i, (_, t))| {
                        (i, effective_priority(t.priority, t.enqueued, now))
                    })
                    .max_by_key(|&(_, e)| e)
                    .expect("parked is non-empty");
                let challenger = {
                    let inbox = shard.lock();
                    inbox.batcher.max_priority_for(&ab.key, |p| {
                        effective_priority(
                            p.payload.req.priority,
                            p.enqueued,
                            now,
                        )
                    })
                };
                if challenger.is_some_and(|q| q > eff) {
                    break; // yield the free lane to the queued request
                }
                if ab.try_resume(idx).is_none() {
                    break; // page pressure: retry at the next boundary
                }
                shard.kv_resumes.fetch_add(1, Ordering::SeqCst);
            }
        }
        // ---- 2.8 preempt pass: when a batch is full and a queued
        // request of its key strictly outranks the weakest live lane
        // (both age-boosted), that lane suspends at this block boundary
        // — its pages spill to the host-side cold tier and its ticket
        // parks — so the admission pass can seat the challenger. Strict
        // inequality means equal-priority traffic never preempts, and
        // one suspension frees exactly one lane per pass, so thrash is
        // bounded by the block cadence.
        if !draining {
            for ab in active.iter_mut() {
                while ab.free_lanes() == 0 && !ab.is_empty() {
                    let now = Instant::now();
                    let challenger = {
                        let inbox = shard.lock();
                        inbox.batcher.max_priority_for(&ab.key, |p| {
                            effective_priority(
                                p.payload.req.priority,
                                p.enqueued,
                                now,
                            )
                        })
                    };
                    let Some(challenger) = challenger else { break };
                    let victim = ab
                        .ticketed_lanes()
                        .into_iter()
                        .filter_map(|lane| {
                            ab.ticket(lane).map(|t| {
                                (
                                    lane,
                                    effective_priority(
                                        t.priority, t.enqueued, now,
                                    ),
                                )
                            })
                        })
                        .min_by_key(|&(_, e)| e);
                    let Some((lane, lane_eff)) = victim else { break };
                    if challenger <= lane_eff {
                        break;
                    }
                    let spilled0 = ab.state.kv_spilled_bytes();
                    if !ab.suspend(lane) {
                        break;
                    }
                    shard.kv_preempts.fetch_add(1, Ordering::SeqCst);
                    shard.kv_spilled_bytes.fetch_add(
                        ab.state.kv_spilled_bytes() - spilled0,
                        Ordering::SeqCst,
                    );
                }
            }
        }
        // ---- 3. admission: feed queued requests into free lanes at
        // the block boundary (bucket-1 prefill inside `admit`).
        // Requests whose deadline already expired — or whose client
        // already cancelled — are terminated here WITHOUT consuming a
        // lane, a prefill call, or a prefix-chain pin.
        for ab in active.iter_mut() {
            loop {
                let free = ab.free_lanes();
                if free == 0 {
                    break;
                }
                let (fresh, expired) = {
                    let mut inbox = shard.lock();
                    let r = inbox
                        .batcher
                        .take_for(&ab.key, free, Instant::now());
                    shard.sync_depth(&inbox);
                    r
                };
                if fresh.is_empty() && expired.is_empty() {
                    break;
                }
                queued.fetch_sub(
                    fresh.len() + expired.len(),
                    Ordering::SeqCst,
                );
                for p in expired {
                    stats.aborted_queued += 1;
                    p.payload.abort("deadline expired before admission");
                }
                for p in fresh {
                    if p.payload.ctl.is_cancelled() {
                        stats.aborted_queued += 1;
                        p.payload.abort("cancelled before admission");
                        continue;
                    }
                    let affinity_hit = p.payload.affinity == shard.id;
                    let rec = Recoverable {
                        slot: p.payload.events.clone(),
                        ctl: p.payload.ctl.clone(),
                        req: p.payload.req.clone(),
                        submitted: p.payload.submitted,
                        affinity: p.payload.affinity,
                    };
                    let (ticket, req) = Ticket::from_submit(p.payload);
                    if ticket.events.send(LaneEvent::Admitted).is_err() {
                        // handle already dropped: the client is gone,
                        // don't spend the prefill
                        stats.aborted_queued += 1;
                        continue;
                    }
                    // register for recovery the moment the client is
                    // promised an admission; removed on every terminal
                    // path, so the registry is exactly the set of
                    // admitted-but-unanswered requests
                    shard.registry_insert(ticket.rid, rec);
                    if let Some(n) = fault
                        .as_ref()
                        .and_then(|f| f.at_admit(shard.id, fault_admits))
                    {
                        ab.state.inject_kv_alloc_failures(n);
                    }
                    fault_admits += 1;
                    match ab.admit(&req.prompt_ids, req.tau_conf, ticket) {
                        Ok(_) => {
                            stats.admitted_requests += 1;
                            if affinity_hit {
                                stats.affinity_admissions += 1;
                            }
                        }
                        Err((t, e)) => {
                            shard.registry_remove(t.rid);
                            let _ = t.events.send(LaneEvent::Aborted {
                                reason: format!("admission failed: {e:#}"),
                                steps: 0,
                                model_calls: 0,
                                committed_tokens: 0,
                            });
                        }
                    }
                }
            }
        }
        // ---- 3.5 block-boundary heartbeat + fault triggers: stamp
        // liveness exactly where a healthy worker provably makes
        // progress (the watchdog only judges busy workers), then give
        // the fault plan its chance to wedge or kill this incarnation.
        let stepping = active.iter().any(|ab| !ab.is_empty());
        shard.beat(stepping);
        if stepping {
            if let Some(k) = fault
                .as_ref()
                .and_then(|f| f.at_step(shard.id, fault_steps))
            {
                match k {
                    FaultKind::Panic => panic!(
                        "injected worker panic (fault plan, shard {}, \
                         step ordinal {})",
                        shard.id, fault_steps
                    ),
                    FaultKind::Delay(d) => std::thread::sleep(d),
                    _ => {}
                }
            }
            fault_steps += 1;
        }
        // ---- 4. cancellation sweep, then advance every live batch one
        // block; retire + answer finished lanes immediately. The sweep
        // runs at the block boundary — exactly where lane state is
        // consistent and a departure cannot perturb cohort mates — and
        // frees the cancelled lane's KV slot + chain pin on the spot,
        // so the admission pass above can refill it next iteration.
        for ab in active.iter_mut() {
            if ab.is_empty() {
                continue;
            }
            let now = Instant::now();
            for lane in ab.ticketed_lanes() {
                let kind = match ab.ticket_mut(lane) {
                    Some(t) => cancel_of(t, now),
                    None => None,
                };
                match kind {
                    None => {}
                    Some(Cancel::Budget) => {
                        // generation budget reached: a truncated but
                        // successful response
                        if let Some((t, o)) = ab.cancel(lane) {
                            core.record_outcome(&ab.key, &o);
                            respond_lane(core, &shard, t, o);
                        }
                    }
                    Some(Cancel::Abort(reason)) => {
                        if let Some((t, o)) = ab.cancel(lane) {
                            abort_lane(
                                core, &shard, &ab.key, &t, &o, reason,
                                &mut stats,
                            );
                        }
                    }
                }
            }
            if ab.is_empty() {
                continue; // every lane was cancelled
            }
            if !cfg.step_delay.is_zero() {
                std::thread::sleep(cfg.step_delay);
            }
            match ab.step() {
                Ok((runs, mut finished)) => {
                    let still_live = !ab.is_empty();
                    if still_live {
                        stats.retired_early += finished.len() as u64;
                    }
                    // stream each lane's block delta — lanes that
                    // finished this cycle get their final Committed
                    // before their Finished below
                    for run in &runs {
                        if let Some(t) = ab.ticket_mut(run.lane) {
                            emit_commit(core, t, run);
                        } else if let Some((_, t, _)) = finished
                            .iter_mut()
                            .find(|(l, _, _)| *l == run.lane)
                        {
                            emit_commit(core, t, run);
                        }
                    }
                    for (_, ticket, outcome) in finished {
                        core.record_outcome(&ab.key, &outcome);
                        respond_lane(core, &shard, ticket, outcome);
                    }
                }
                Err(e) => {
                    // drain through the cancel path so every lane's
                    // Aborted event and the /metrics wasted_* counters
                    // carry the work it actually burned (the lanes are
                    // still well-formed; only the failed program call
                    // poisoned the batch)
                    let msg = format!("decode failed: {e:#}");
                    for lane in ab.ticketed_lanes() {
                        if let Some((t, o)) = ab.cancel(lane) {
                            abort_lane(
                                core, &shard, &ab.key, &t, &o, &msg,
                                &mut stats,
                            );
                        }
                    }
                    // parked lanes would resume into this poisoned
                    // batch's pool: settle them now, before the retain
                    // pass drops the batch (and their spilled KV)
                    while !ab.parked.is_empty() {
                        let (t, o) = ab.discard_parked(0);
                        abort_lane(
                            core, &shard, &ab.key, &t, &o, &msg, &mut stats,
                        );
                    }
                    ab.poisoned = true;
                }
            }
        }
        // ---- 5. drop poisoned batches. Drained batches are *retained*
        // — their pools hold the warm prefix chains the next burst of
        // the same key admits against — until step 2 reclaims their
        // room for a new key.
        active.retain(|ab| {
            if ab.poisoned {
                stats.absorb(&ab.state);
            }
            !ab.poisoned
        });
        // replica gauge: the dispatcher's least-loaded fallback reads
        // live lanes without taking the inbox lock (a superseded
        // incarnation must not clobber its replacement's gauge)
        if shard.generation.load(Ordering::SeqCst) == my_gen {
            let lanes: usize = active.iter().map(|ab| ab.live_lanes()).sum();
            shard.in_flight.store(lanes, Ordering::Relaxed);
        }
        // drain completes once every in-flight lane has delivered its
        // terminal event — nothing is cut short, nothing is dropped.
        // Parked lanes block completion too: the resume pass keeps
        // seating them as live lanes finish, so they drain naturally.
        if draining
            && active
                .iter()
                .all(|ab| ab.is_empty() && ab.parked.is_empty())
        {
            for ab in &active {
                stats.absorb(&ab.state);
            }
            return WorkerExit::Clean;
        }
    }
}

/// Detokenize one committed block run into the lane's stream and send
/// the `Committed` event. A failed send means the client dropped its
/// handle — the lane is marked dead and the next boundary sweep cancels
/// it (write-failure disconnect detection, one block of slack at most).
///
/// `tokens` — and the `max_new_tokens` budget it feeds — count the
/// tokens this delta actually *delivers*: the stream decoder drops
/// specials and everything at/after the first `<eos>`, and this toy
/// tokenizer is one char per token, so the delta's char count is
/// exactly its delivered-token count. Dead post-`<eos>` refinement
/// (the teacher baselines decode every block) charges nothing.
fn emit_commit(core: &ServingCore, t: &mut Ticket, run: &CommitRun) {
    let text = core.tokenizer.decode_stream(&mut t.detok, &run.tokens);
    let revealed = text.chars().count();
    t.committed_tokens += revealed;
    let block = t.blocks_committed;
    t.blocks_committed += 1;
    let sent = t.events.send(LaneEvent::Committed {
        block,
        text,
        tokens: revealed,
    });
    if sent.is_err() {
        t.dead = true;
    }
}

/// Answer one retired lane with its terminal `Finished` event.
/// TTFT/TTLT include queueing: the lane's decode-relative first-token
/// offset is rebased onto its admission instant. (A streaming client's
/// *observed* TTFT is stamped by the HTTP layer from the first
/// `Committed` chunk actually written to the socket.)
fn respond_lane(
    core: &ServingCore,
    shard: &Shard,
    ticket: Ticket,
    o: DecodeOutcome,
) {
    shard.registry_remove(ticket.rid);
    let wait = ticket.admitted - ticket.enqueued;
    let text = core.tokenizer.decode(&o.gen, true);
    let _ = ticket.events.send(LaneEvent::Finished(GenerateResponse {
        text,
        steps: o.steps,
        model_calls: o.model_calls,
        latency: o.latency,
        ttft: wait + o.ttft,
        ttlt: Instant::now() - ticket.enqueued,
        gen_len: o.gen_len,
        gen_ids: o.gen,
    }));
}

/// Terminal `Aborted` for a cancelled in-flight lane: surfaces the
/// wasted work on the event, `/metrics` (per backbone/method) and the
/// `aborted_inflight` counter on `/healthz`.
fn abort_lane(
    core: &mut ServingCore,
    shard: &Shard,
    key: &GroupKey,
    ticket: &Ticket,
    o: &DecodeOutcome,
    reason: &str,
    stats: &mut ServeStats,
) {
    shard.registry_remove(ticket.rid);
    stats.aborted_inflight += 1;
    core.record_abort(
        key,
        &AbortRecord {
            steps: o.steps,
            model_calls: o.model_calls,
            committed_tokens: ticket.committed_tokens,
        },
    );
    let _ = ticket.events.send(LaneEvent::Aborted {
        reason: reason.to_string(),
        steps: o.steps,
        model_calls: o.model_calls,
        committed_tokens: ticket.committed_tokens,
    });
}

fn health_json(
    core: &ServingCore,
    shard_id: usize,
    queued_here: usize,
    active: &[ActiveBatch<Ticket>],
    stats: &ServeStats,
) -> Json {
    let in_flight: usize = active.iter().map(|ab| ab.live_lanes()).sum();
    let decoding = active.iter().filter(|ab| !ab.is_empty()).count();
    let kv_in_use: usize = core.pool.in_use()
        + active.iter().map(|ab| ab.state.kv_in_use()).sum::<usize>();
    let total_admissions = stats.closed_total_admissions
        + active.iter().map(|ab| ab.state.total_admissions).sum::<u64>();
    let mid_flight = stats.closed_mid_flight
        + active
            .iter()
            .map(|ab| ab.state.mid_flight_admissions)
            .sum::<u64>();
    let kv_allocs = stats.closed_kv_allocs
        + core.pool.total_allocs
        + active.iter().map(|ab| ab.state.kv_total_allocs()).sum::<u64>();
    let prefix_hits = stats.closed_prefix_hits
        + core.pool.prefix_hits
        + active.iter().map(|ab| ab.state.prefix_hits()).sum::<u64>();
    let prefix_hit_blocks = stats.closed_prefix_hit_blocks
        + core.pool.prefix_hit_blocks
        + active.iter().map(|ab| ab.state.prefix_hit_blocks()).sum::<u64>();
    let prefix_evictions = stats.closed_prefix_evictions
        + core.pool.prefix_evictions
        + active.iter().map(|ab| ab.state.prefix_evictions()).sum::<u64>();
    // resident shared pages are live state, not a lifetime counter:
    // only pools that still exist contribute
    let kv_shared_slots = core.pool.prefix_resident_pages()
        + active.iter().map(|ab| ab.state.kv_shared_pages()).sum::<usize>();
    let kv_preempts = stats.closed_preempts
        + active.iter().map(|ab| ab.state.kv_preempts()).sum::<u64>();
    let kv_resumes = stats.closed_resumes
        + active.iter().map(|ab| ab.state.kv_resumes()).sum::<u64>();
    let kv_spilled_bytes = stats.closed_spilled_bytes
        + active.iter().map(|ab| ab.state.kv_spilled_bytes()).sum::<u64>();
    let parked_lanes: usize =
        active.iter().map(|ab| ab.parked_lanes()).sum();
    Json::obj(vec![
        ("status", Json::str("ok")),
        ("platform", Json::str(core.rt.platform())),
        ("compiled_programs", Json::num(core.rt.compiled_count() as f64)),
        ("kv_slots_in_use", Json::num(kv_in_use as f64)),
        ("kv_total_allocs", Json::num(kv_allocs as f64)),
        ("kv_shared_slots", Json::num(kv_shared_slots as f64)),
        ("queued", Json::num(queued_here as f64)),
        // active = machines with live lanes (the pre-retention meaning);
        // drained machines kept only as warm prefix caches report
        // separately so "idle server" stays distinguishable
        ("active_batches", Json::num(decoding as f64)),
        ("retained_batches", Json::num((active.len() - decoding) as f64)),
        ("in_flight_lanes", Json::num(in_flight as f64)),
        // SLO preemption: lifetime suspend/resume counters plus the
        // current number of lanes parked with spilled KV
        ("kv_preempts", Json::num(kv_preempts as f64)),
        ("kv_resumes", Json::num(kv_resumes as f64)),
        ("kv_spilled_bytes", Json::num(kv_spilled_bytes as f64)),
        ("parked_lanes", Json::num(parked_lanes as f64)),
        ("total_admissions", Json::num(total_admissions as f64)),
        ("mid_flight_admissions", Json::num(mid_flight as f64)),
        ("retired_early", Json::num(stats.retired_early as f64)),
        ("aborted_queued", Json::num(stats.aborted_queued as f64)),
        ("aborted_inflight", Json::num(stats.aborted_inflight as f64)),
        ("prefix_hits", Json::num(prefix_hits as f64)),
        ("prefix_hit_blocks", Json::num(prefix_hit_blocks as f64)),
        ("prefix_evictions", Json::num(prefix_evictions as f64)),
        // per-replica identity + dispatcher-visible counters ("replica"
        // is excluded from the cross-shard sum; the rest add up)
        ("replica", Json::num(shard_id as f64)),
        ("admitted_requests", Json::num(stats.admitted_requests as f64)),
        (
            "affinity_admissions",
            Json::num(stats.affinity_admissions as f64),
        ),
        ("stolen", Json::num(stats.stolen as f64)),
    ])
}

// ---------------------------------------------------------------------------
// Closed-batch worker (legacy): batching windows + run-to-completion
// ---------------------------------------------------------------------------

fn worker_loop_closed(
    core: &mut ServingCore,
    shard: Arc<Shard>,
    _cfg: RouterConfig,
    replicas: usize,
    queued: Arc<AtomicUsize>,
    my_gen: usize,
) -> WorkerExit {
    // closed-batch admission accounting for /healthz: every request
    // dispatched into a group counts as an admission; mid-flight joins
    // and early retirement don't exist on this path, so those stay 0.
    let mut stats = ServeStats::default();
    // closed groups run to completion — there is no block boundary to
    // steal at, so the closed path relies on dispatcher routing alone.
    // The decode thread budget is split across replicas up front so N
    // shards decoding concurrently never oversubscribe the host.
    let threads = crate::coordinator::scheduler::decode_threads_shared(
        &core.rt, replicas,
    );
    loop {
        if shard.generation.load(Ordering::SeqCst) != my_gen {
            return WorkerExit::Superseded;
        }
        // the closed path stamps idle liveness only: groups run to
        // completion, so a healthy decode can legitimately outlast any
        // fixed deadline (Router::start disables the watchdog here)
        shard.beat(false);
        let mut inbox = shard.lock();
        if inbox.control.is_empty() && !inbox.shutdown {
            let nap = if inbox.batcher.is_empty() {
                Duration::from_millis(200)
            } else {
                inbox
                    .batcher
                    .next_deadline()
                    .map(|d| {
                        d.saturating_duration_since(Instant::now())
                            .max(Duration::from_millis(1))
                    })
                    .unwrap_or(Duration::from_millis(1))
            };
            inbox = shard
                .cv
                .wait_timeout(inbox, nap)
                .unwrap_or_else(|e| e.into_inner())
                .0;
        }
        let control = std::mem::take(&mut inbox.control);
        let shutdown = inbox.shutdown;
        // drain every ready group this wakeup, then dispatch them
        // together — independent groups decode concurrently. The closed
        // path runs groups to completion, so there is no lane to cancel
        // mid-decode (and `max_new_tokens` is documented as ignored
        // here); queued-deadline expiry IS enforced, at dispatch: an
        // expired request never costs a group slot or a decode, same
        // contract as the continuous path's `take_for`.
        let mut popped: Vec<(GroupKey, Group)> = Vec::new();
        loop {
            let item = if shutdown {
                inbox.batcher.pop_any()
            } else {
                inbox.batcher.pop_ready(Instant::now())
            };
            let Some(g) = item else { break };
            popped.push(g);
        }
        let queued_here = inbox.batcher.len();
        shard.sync_depth(&inbox);
        drop(inbox);
        for msg in control {
            match msg {
                ControlMsg::Metrics(tx) => {
                    let _ = tx.send(core.metrics.clone());
                }
                ControlMsg::Health(tx) => {
                    let _ = tx.send(health_json(
                        core,
                        shard.id,
                        queued_here,
                        &[],
                        &stats,
                    ));
                }
            }
        }
        let mut groups: Vec<(GroupKey, Group)> = Vec::new();
        for (key, items) in popped {
            // pushes and pops are balanced, so a plain decrement is
            // exact (the old `min(load)` clamp was a racy read-modify-
            // write that could leak permits under concurrent submits)
            queued.fetch_sub(items.len(), Ordering::SeqCst);
            let now = Instant::now();
            let mut live: Group = Vec::with_capacity(items.len());
            for p in items {
                if shutdown {
                    // drain contract: queued work gets its terminal
                    // Aborted{"shutdown"} instead of a silent drop
                    stats.aborted_queued += 1;
                    p.payload.abort("shutdown");
                } else if p.deadline.is_some_and(|d| now > d) {
                    stats.aborted_queued += 1;
                    p.payload.abort("deadline expired before admission");
                } else if p.payload.events.send(LaneEvent::Admitted).is_err()
                {
                    // handle already dropped: the client is gone, don't
                    // spend a group slot on a run-to-completion decode
                    stats.aborted_queued += 1;
                } else {
                    stats.closed_total_admissions += 1;
                    stats.admitted_requests += 1;
                    if p.payload.affinity == shard.id {
                        stats.affinity_admissions += 1;
                    }
                    live.push(p);
                }
            }
            if !live.is_empty() {
                groups.push((key, live));
            }
        }
        run_groups(core, groups, threads);
        if shutdown {
            // the inbox refuses pushes once `shutdown` is set, so the
            // pop_any sweep above has already emptied it for good
            return WorkerExit::Clean;
        }
    }
}

type Group = Vec<Pending<Submit>>;

/// Decode opts for one group. Groups are tau-uniform by construction
/// (tau is folded into the `GroupKey`), so applying the key's tau is
/// exact — no request can inherit another's override.
fn group_opts(geom: &Geometry, key: &GroupKey) -> DecodeOpts {
    let mut opts = DecodeOpts::defaults(geom);
    if let Some(t) = key.tau() {
        opts.tau_conf = t;
    }
    opts
}

/// Answer one group's requests from its decode result. The closed path
/// decodes to completion, so the event stream collapses to a single
/// whole-response `Committed` delta followed by `Finished` — the wire
/// contract (concatenated deltas == final text, one terminal event)
/// holds on both worker paths. Metrics are recorded by the caller
/// (serial path: inside `decode_group`; parallel path: explicitly,
/// after the scoped join), never here.
fn respond_group(
    core: &ServingCore,
    items: Group,
    decode_start: Instant,
    result: Result<Vec<DecodeOutcome>>,
) {
    match result {
        Ok(outcomes) => {
            for (p, o) in items.into_iter().zip(outcomes) {
                let wait = decode_start - p.enqueued;
                let text = core.tokenizer.decode(&o.gen, true);
                let _ = p.payload.events.send(LaneEvent::Committed {
                    block: 0,
                    text: text.clone(),
                    tokens: o.gen_len,
                });
                let _ =
                    p.payload.events.send(LaneEvent::Finished(
                        GenerateResponse {
                            text,
                            steps: o.steps,
                            model_calls: o.model_calls,
                            latency: o.latency,
                            ttft: wait + o.ttft,
                            ttlt: Instant::now() - p.enqueued,
                            gen_len: o.gen_len,
                            gen_ids: o.gen,
                        },
                    ));
            }
        }
        Err(e) => {
            let msg = format!("decode failed: {e:#}");
            for p in items {
                p.payload.abort(&msg);
            }
        }
    }
}

/// Run a wakeup's worth of batcher groups. A single group (the common
/// case) decodes on the worker thread against the shared pool; several
/// groups fan out on scoped threads, each with its own KV pool and slot
/// set, then respond in group order — decode traces are identical to
/// running the groups back to back.
fn run_groups(
    core: &mut ServingCore,
    groups: Vec<(GroupKey, Group)>,
    threads: usize,
) {
    if groups.is_empty() {
        return;
    }
    // resolve every group's weights up front; any load failure drops to
    // the serial path, which reproduces the error per group
    let all_loaded = groups.iter().all(|(key, _)| {
        core.ensure_weights(&key.method.weights_for(&key.backbone)).is_ok()
    });
    if groups.len() == 1 || threads <= 1 || !all_loaded {
        for (key, items) in groups {
            let opts = group_opts(core.geometry(), &key);
            let prompts: Vec<Vec<i32>> = items
                .iter()
                .map(|p| p.payload.req.prompt_ids.clone())
                .collect();
            let t0 = Instant::now();
            let result = core.decode_group(&key, &prompts, &opts);
            respond_group(core, items, t0, result);
        }
        return;
    }
    // parallel: each group decodes on a scoped worker against a private
    // KV pool; groups share only the immutable runtime + weights map
    let geom = core.rt.manifest.geometry.clone();
    let pool_cap = groups
        .iter()
        .map(|(_, items)| items.len())
        .chain(core.rt.manifest.buckets.iter().copied())
        .max()
        .unwrap_or(4);
    let meta: Vec<(String, Method, Vec<Vec<i32>>, DecodeOpts)> = groups
        .iter()
        .map(|(key, items)| {
            (
                key.method.weights_for(&key.backbone),
                key.method,
                items
                    .iter()
                    .map(|p| p.payload.req.prompt_ids.clone())
                    .collect(),
                group_opts(&geom, key),
            )
        })
        .collect();
    let mut results: Vec<Option<Result<Vec<DecodeOutcome>>>> = Vec::new();
    results.resize_with(groups.len(), || None);
    let t0 = Instant::now();
    {
        let rt = &core.rt;
        let weights_map = &core.weights;
        let geom_ref = &geom;
        // split the thread budget between the group fan-out (here) and
        // each group's own chunk fan-out, so nesting never runs more
        // than ~`threads` CPU-bound workers in total
        let per_group = (threads / groups.len()).max(1);
        let jobs: Vec<_> = results
            .iter_mut()
            .zip(&meta)
            .map(|(slot, (model, method, prompts, opts))| {
                move || {
                    let engine = Engine::new(rt, &weights_map[model]);
                    let mut pool = KvPool::new(geom_ref, pool_cap);
                    *slot = Some(engine.decode_with_threads(
                        per_group, *method, opts, prompts, &mut pool,
                    ));
                }
            })
            .collect();
        threadpool::scoped(threads, jobs);
    }
    for ((key, items), result) in groups.into_iter().zip(results) {
        let result = result.expect("group executor dropped a group");
        if let Ok(outcomes) = &result {
            core.record_group(&key, outcomes);
        }
        respond_group(core, items, t0, result);
    }
}
