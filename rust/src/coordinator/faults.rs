//! Seeded fault injection for the supervision layer.
//!
//! A [`FaultPlan`] is a deterministic schedule of faults — worker
//! panics, delayed steps, KV-page allocation failures, socket resets —
//! keyed to *work indices* (a shard's step-cycle ordinal, its admission
//! ordinal, or an accepted request's ordinal), never wall-clock time,
//! so a plan replays identically across runs and machines. Off by
//! default; `cdlm serve`/`cdlm bench` arm one with `--fault-seed N`
//! (a conservative derived plan) or `--fault-spec SPEC` (explicit).
//!
//! Spec grammar (comma-separated points):
//!
//! ```text
//! panic@shard<S>:step<K>        worker S panics before its K-th step cycle
//! delay:<MS>@shard<S>:step<K>   worker S sleeps MS ms before step cycle K
//! kvfail:<N>@shard<S>:admit<K>  worker S's K-th admission fails its next
//!                               N KV-page allocations
//! sockreset@req<K>              the K-th accepted /generate socket is
//!                               reset after submit (client sees a dead
//!                               connection, the lane must be cleaned up)
//! panic@step<K>                 omitting shard<S> makes a point wildcard:
//!                               it fires on whichever shard reaches the
//!                               trigger first
//! ```
//!
//! Every point fires **at most once** per process (an atomic latch), so
//! a respawned worker — whose step counter restarts at zero — does not
//! re-trip the fault that killed its predecessor; injecting a second
//! kill takes a second point. Shard indices are taken modulo the live
//! replica count, so a plan written for one topology still names a real
//! shard in another.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::Duration;

use crate::util::rng::SplitMix64;

/// What a triggered fault point does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the shard worker thread (exercises `catch_unwind`
    /// supervision + re-dispatch).
    Panic,
    /// Sleep before the step cycle (exercises the stall watchdog when
    /// the delay exceeds its deadline).
    Delay(Duration),
    /// Fail the next N KV-page allocations in the admitting batch.
    KvFail(u64),
    /// Reset the accepted socket (server layer).
    SockReset,
}

/// When a fault point triggers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Trigger {
    /// Before the shard's K-th step cycle (a cycle = one pass that
    /// advances live batches one block).
    Step(u64),
    /// At the shard's K-th lane admission.
    Admit(u64),
    /// At the server's K-th accepted `/generate` request.
    Request(u64),
}

#[derive(Debug, Clone)]
struct FaultPoint {
    /// `None` = wildcard: first shard to reach the trigger fires it.
    shard: Option<usize>,
    trigger: Trigger,
    kind: FaultKind,
}

/// A deterministic, fire-once schedule of injected faults.
#[derive(Debug)]
pub struct FaultPlan {
    points: Vec<FaultPoint>,
    fired: Vec<AtomicBool>,
    /// Live replica count, bound by `Router::start` so `shard<S>`
    /// resolves to `S % replicas` regardless of topology.
    replicas: AtomicUsize,
    spec: String,
}

impl FaultPlan {
    fn from_points(points: Vec<FaultPoint>, spec: String) -> Self {
        let fired = points.iter().map(|_| AtomicBool::new(false)).collect();
        Self { points, fired, replicas: AtomicUsize::new(1), spec }
    }

    /// Parse the spec grammar (see module docs). Errors name the
    /// offending clause.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut points = Vec::new();
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty())
        {
            let (kind_s, target) = clause
                .split_once('@')
                .ok_or_else(|| format!("fault '{clause}': missing '@'"))?;
            let (name, arg) = match kind_s.split_once(':') {
                Some((n, a)) => (n, Some(a)),
                None => (kind_s, None),
            };
            let num = |what: &str| -> Result<u64, String> {
                arg.ok_or_else(|| {
                    format!("fault '{clause}': '{name}' needs :{what}")
                })?
                .parse::<u64>()
                .map_err(|_| format!("fault '{clause}': bad {what}"))
            };
            let kind = match name {
                "panic" => FaultKind::Panic,
                "delay" => FaultKind::Delay(Duration::from_millis(num("ms")?)),
                "kvfail" => FaultKind::KvFail(num("count")?),
                "sockreset" => FaultKind::SockReset,
                other => {
                    return Err(format!(
                        "fault '{clause}': unknown kind '{other}'"
                    ))
                }
            };
            let (shard, at) = match target.split_once(':') {
                Some((s, rest)) => {
                    let id = s
                        .strip_prefix("shard")
                        .and_then(|n| n.parse::<usize>().ok())
                        .ok_or_else(|| {
                            format!("fault '{clause}': bad target '{s}'")
                        })?;
                    (Some(id), rest)
                }
                None => (None, target),
            };
            let ordinal = |prefix: &str| {
                at.strip_prefix(prefix)
                    .and_then(|n| n.parse::<u64>().ok())
                    .ok_or_else(|| {
                        format!("fault '{clause}': bad trigger '{at}'")
                    })
            };
            let trigger = if at.starts_with("step") {
                Trigger::Step(ordinal("step")?)
            } else if at.starts_with("admit") {
                Trigger::Admit(ordinal("admit")?)
            } else if at.starts_with("req") {
                if shard.is_some() {
                    return Err(format!(
                        "fault '{clause}': req triggers are server-wide, \
                         drop the shard prefix"
                    ));
                }
                Trigger::Request(ordinal("req")?)
            } else {
                return Err(format!("fault '{clause}': bad trigger '{at}'"));
            };
            match (kind, trigger) {
                (FaultKind::SockReset, Trigger::Request(_)) => {}
                (FaultKind::SockReset, _) => {
                    return Err(format!(
                        "fault '{clause}': sockreset needs a req<K> trigger"
                    ))
                }
                (_, Trigger::Request(_)) => {
                    return Err(format!(
                        "fault '{clause}': req<K> only triggers sockreset"
                    ))
                }
                (FaultKind::KvFail(_), Trigger::Step(_)) => {
                    return Err(format!(
                        "fault '{clause}': kvfail needs an admit<K> trigger"
                    ))
                }
                _ => {}
            }
            points.push(FaultPoint { shard, trigger, kind });
        }
        if points.is_empty() {
            return Err("empty fault spec".into());
        }
        Ok(Self::from_points(points, spec.to_string()))
    }

    /// Derive a conservative plan from a seed: one wildcard worker
    /// panic *before any step* (pre-commit, so the victim's in-flight
    /// requests are all re-dispatchable and integer accounting is
    /// preserved — the property the faulted `--check-baseline` CI leg
    /// gates), plus one seeded delayed step later in the run. Richer
    /// scenarios (mid-stream kills, KV exhaustion, socket resets) take
    /// an explicit `--fault-spec`.
    pub fn from_seed(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let delay_step = 4 + rng.below(8);
        let delay_ms = 20 + rng.below(60);
        let spec =
            format!("panic@step0,delay:{delay_ms}@step{delay_step}");
        let points = vec![
            FaultPoint {
                shard: None,
                trigger: Trigger::Step(0),
                kind: FaultKind::Panic,
            },
            FaultPoint {
                shard: None,
                trigger: Trigger::Step(delay_step),
                kind: FaultKind::Delay(Duration::from_millis(delay_ms)),
            },
        ];
        Self::from_points(points, spec)
    }

    /// Canonical spec string (logging, bench schema).
    pub fn spec(&self) -> &str {
        &self.spec
    }

    /// Points fired so far. The chaos bench gates on this being nonzero:
    /// an armed plan that never fires means the trace missed its
    /// triggers and the run exercised nothing.
    pub fn fired_count(&self) -> usize {
        self.fired.iter().filter(|f| f.load(Ordering::SeqCst)).count()
    }

    /// Total points in the plan.
    pub fn point_count(&self) -> usize {
        self.points.len()
    }

    /// Bind the live replica count so `shard<S>` targets resolve.
    pub fn bind_replicas(&self, replicas: usize) {
        self.replicas.store(replicas.max(1), Ordering::SeqCst);
    }

    /// Find-and-latch the first unfired point matching `pred`.
    fn fire<F>(&self, pred: F) -> Option<FaultKind>
    where
        F: Fn(&FaultPoint) -> bool,
    {
        for (i, p) in self.points.iter().enumerate() {
            if !pred(p) {
                continue;
            }
            if self.fired[i]
                .compare_exchange(
                    false,
                    true,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                )
                .is_ok()
            {
                return Some(p.kind);
            }
        }
        None
    }

    fn shard_matches(&self, p: &FaultPoint, shard: usize) -> bool {
        let replicas = self.replicas.load(Ordering::SeqCst).max(1);
        match p.shard {
            None => true,
            Some(s) => s % replicas == shard,
        }
    }

    /// A `Panic`/`Delay` point due before shard `shard`'s step cycle
    /// `step` (0-based, counted per worker incarnation).
    pub fn at_step(&self, shard: usize, step: u64) -> Option<FaultKind> {
        self.fire(|p| {
            matches!(p.kind, FaultKind::Panic | FaultKind::Delay(_))
                && p.trigger == Trigger::Step(step)
                && self.shard_matches(p, shard)
        })
    }

    /// A `KvFail` point due at shard `shard`'s admission ordinal
    /// `admit`; returns the number of allocations to fail.
    pub fn at_admit(&self, shard: usize, admit: u64) -> Option<u64> {
        match self.fire(|p| {
            matches!(p.kind, FaultKind::KvFail(_))
                && p.trigger == Trigger::Admit(admit)
                && self.shard_matches(p, shard)
        }) {
            Some(FaultKind::KvFail(n)) => Some(n),
            _ => None,
        }
    }

    /// True when the server should reset the `ordinal`-th accepted
    /// `/generate` socket.
    pub fn at_request(&self, ordinal: u64) -> bool {
        self.fire(|p| {
            p.kind == FaultKind::SockReset
                && p.trigger == Trigger::Request(ordinal)
        })
        .is_some()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        let plan = FaultPlan::parse(
            "panic@shard0:step12, delay:500@shard1:step3, \
             kvfail:2@shard0:admit1, sockreset@req3, panic@step4",
        )
        .unwrap();
        assert_eq!(plan.points.len(), 5);
        assert_eq!(plan.points[0].shard, Some(0));
        assert_eq!(plan.points[0].trigger, Trigger::Step(12));
        assert_eq!(plan.points[0].kind, FaultKind::Panic);
        assert_eq!(
            plan.points[1].kind,
            FaultKind::Delay(Duration::from_millis(500))
        );
        assert_eq!(plan.points[2].kind, FaultKind::KvFail(2));
        assert_eq!(plan.points[2].trigger, Trigger::Admit(1));
        assert_eq!(plan.points[3].kind, FaultKind::SockReset);
        assert_eq!(plan.points[4].shard, None, "wildcard shard");
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "",
            "panic",
            "panic@",
            "panic@shard0",
            "panic@shardx:step1",
            "explode@shard0:step1",
            "delay@shard0:step1",
            "kvfail:2@shard0:step1",
            "sockreset@shard0:step1",
            "panic@req1",
            "sockreset@req1,panic@shard0:stepx",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn points_fire_exactly_once() {
        let plan = FaultPlan::parse("panic@shard0:step2").unwrap();
        plan.bind_replicas(2);
        assert!(plan.at_step(0, 1).is_none());
        assert!(plan.at_step(1, 2).is_none(), "wrong shard");
        assert_eq!(plan.at_step(0, 2), Some(FaultKind::Panic));
        assert!(plan.at_step(0, 2).is_none(), "latched after firing");
    }

    #[test]
    fn wildcard_fires_on_first_matching_shard_only() {
        let plan = FaultPlan::parse("panic@step0").unwrap();
        plan.bind_replicas(4);
        assert_eq!(plan.at_step(3, 0), Some(FaultKind::Panic));
        assert!(plan.at_step(0, 0).is_none());
    }

    #[test]
    fn shard_targets_resolve_modulo_replicas() {
        let plan = FaultPlan::parse("panic@shard5:step0").unwrap();
        plan.bind_replicas(2);
        assert_eq!(plan.at_step(1, 0), Some(FaultKind::Panic));
    }

    #[test]
    fn seeded_plan_is_deterministic_and_conservative() {
        let a = FaultPlan::from_seed(0xC4A05);
        let b = FaultPlan::from_seed(0xC4A05);
        assert_eq!(a.spec(), b.spec());
        // the kill is always pre-commit (step 0): re-dispatch territory
        assert_eq!(a.at_step(0, 0), Some(FaultKind::Panic));
        assert!(matches!(
            FaultPlan::from_seed(1).points[1].kind,
            FaultKind::Delay(_)
        ));
    }

    #[test]
    fn kvfail_and_sockreset_lookups() {
        let plan =
            FaultPlan::parse("kvfail:3@shard1:admit0,sockreset@req2").unwrap();
        plan.bind_replicas(2);
        assert_eq!(plan.at_admit(1, 0), Some(3));
        assert!(plan.at_admit(1, 0).is_none(), "latched");
        assert!(!plan.at_request(1));
        assert!(plan.at_request(2));
        assert!(!plan.at_request(2), "latched");
    }
}
