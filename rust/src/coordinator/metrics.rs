//! Serving metrics with the paper's §A.3 accounting: per-sample averages
//! of latency / refinement steps / generation length, plus TPS
//! (valid tokens per second of generation wall-clock).

use std::time::Duration;

use crate::util::json::Json;
use crate::util::stats::Summary;

#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub latency: Duration,
    pub steps: u64,
    pub model_calls: u64,
    pub gen_len: usize,
    pub correct: Option<bool>,
}

/// Work a cancelled lane burned before it was retired (deadline,
/// client disconnect, shutdown): the §A.3 counters it accrued plus the
/// tokens it had already committed. Aborted requests never enter the
/// per-sample averages — they'd skew the paper metrics — but their
/// wasted work is visible per (backbone, method) on `/metrics`.
#[derive(Debug, Clone)]
pub struct AbortRecord {
    pub steps: u64,
    pub model_calls: u64,
    pub committed_tokens: usize,
}

#[derive(Debug, Default, Clone)]
pub struct MetricsAggregator {
    latency_s: Summary,
    steps: Summary,
    model_calls: Summary,
    gen_len: Summary,
    n_scored: usize,
    n_correct: usize,
    n_aborted: usize,
    wasted_steps: u64,
    wasted_model_calls: u64,
    wasted_tokens: u64,
}

impl MetricsAggregator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, r: &RequestRecord) {
        self.latency_s.push(r.latency.as_secs_f64());
        self.steps.push(r.steps as f64);
        self.model_calls.push(r.model_calls as f64);
        self.gen_len.push(r.gen_len as f64);
        if let Some(c) = r.correct {
            self.n_scored += 1;
            self.n_correct += usize::from(c);
        }
    }

    /// Fold in a cancelled lane's partial work. Kept out of the
    /// per-sample §A.3 aggregates by design.
    pub fn record_abort(&mut self, r: &AbortRecord) {
        self.n_aborted += 1;
        self.wasted_steps += r.steps;
        self.wasted_model_calls += r.model_calls;
        self.wasted_tokens += r.committed_tokens as u64;
    }

    /// Fold another aggregator (e.g. a sibling replica's view of the
    /// same (backbone, method) cell) into this one. Sample-exact: every
    /// underlying Summary keeps its raw samples, so merged percentiles
    /// and means equal those of a single aggregator that saw all
    /// requests.
    pub fn merge(&mut self, other: &MetricsAggregator) {
        self.latency_s.merge(&other.latency_s);
        self.steps.merge(&other.steps);
        self.model_calls.merge(&other.model_calls);
        self.gen_len.merge(&other.gen_len);
        self.n_scored += other.n_scored;
        self.n_correct += other.n_correct;
        self.n_aborted += other.n_aborted;
        self.wasted_steps += other.wasted_steps;
        self.wasted_model_calls += other.wasted_model_calls;
        self.wasted_tokens += other.wasted_tokens;
    }

    pub fn count(&self) -> usize {
        self.latency_s.count()
    }

    pub fn aborted(&self) -> usize {
        self.n_aborted
    }

    /// Per-sample average latency (seconds) — paper "Latency (s)".
    pub fn avg_latency_s(&self) -> f64 {
        self.latency_s.mean()
    }

    pub fn p95_latency_s(&self) -> f64 {
        self.latency_s.percentile(95.0)
    }

    /// Per-sample average refinement steps — paper "Total Steps".
    pub fn avg_steps(&self) -> f64 {
        self.steps.mean()
    }

    pub fn avg_model_calls(&self) -> f64 {
        self.model_calls.mean()
    }

    /// Per-sample average valid generated tokens — paper "Gen. Length".
    pub fn avg_gen_len(&self) -> f64 {
        self.gen_len.mean()
    }

    /// Tokens per second: total valid tokens / total generation time —
    /// paper "TPS".
    pub fn tps(&self) -> f64 {
        let t = self.latency_s.sum();
        if t == 0.0 {
            0.0
        } else {
            self.gen_len.sum() / t
        }
    }

    /// Accuracy over scored requests (0-100) — paper "Score".
    pub fn score(&self) -> f64 {
        if self.n_scored == 0 {
            0.0
        } else {
            100.0 * self.n_correct as f64 / self.n_scored as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("tps", Json::num(self.tps())),
            ("avg_latency_s", Json::num(self.avg_latency_s())),
            ("p95_latency_s", Json::num(self.p95_latency_s())),
            ("avg_steps", Json::num(self.avg_steps())),
            ("avg_model_calls", Json::num(self.avg_model_calls())),
            ("avg_gen_len", Json::num(self.avg_gen_len())),
            ("score", Json::num(self.score())),
            ("aborted", Json::num(self.n_aborted as f64)),
            ("wasted_steps", Json::num(self.wasted_steps as f64)),
            (
                "wasted_model_calls",
                Json::num(self.wasted_model_calls as f64),
            ),
            ("wasted_tokens", Json::num(self.wasted_tokens as f64)),
        ])
    }
}

/// Snapshot of the dispatcher's supervision counters, surfaced on both
/// `/metrics` and `/healthz`. Panics and watchdog trips count
/// *detections*; `redispatched` / `aborted_shard_failure` split the
/// victim's requests by the idempotency rule (no `Committed` delta yet
/// sent → replay elsewhere, else terminal abort); `recovery_*_ms`
/// measure detection → respawned-worker-ready.
#[derive(Debug, Default, Clone, Copy)]
pub struct SupervisionStats {
    pub shard_panics: u64,
    pub watchdog_trips: u64,
    pub redispatched_requests: u64,
    pub aborted_shard_failure: u64,
    pub restarts: u64,
    pub dead_shards: u64,
    pub recovery_count: u64,
    pub recovery_total_ms: u64,
    pub recovery_max_ms: u64,
}

impl SupervisionStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shard_panics", Json::num(self.shard_panics as f64)),
            ("watchdog_trips", Json::num(self.watchdog_trips as f64)),
            (
                "redispatched_requests",
                Json::num(self.redispatched_requests as f64),
            ),
            (
                "aborted_shard_failure",
                Json::num(self.aborted_shard_failure as f64),
            ),
            ("restarts", Json::num(self.restarts as f64)),
            ("dead_shards", Json::num(self.dead_shards as f64)),
            ("recovery_count", Json::num(self.recovery_count as f64)),
            (
                "recovery_total_ms",
                Json::num(self.recovery_total_ms as f64),
            ),
            ("recovery_max_ms", Json::num(self.recovery_max_ms as f64)),
        ])
    }
}

/// Lifetime SLO-preemption counters, surfaced on both `/metrics` (as
/// the `preemption` object, summed across shards) and `/healthz` (as
/// flat `kv_preempts` / `kv_resumes` / `kv_spilled_bytes` keys).
/// `preempts` counts lanes suspended at a block boundary to make room
/// for higher-priority work, `resumes` counts lanes seated back from
/// the cold tier (byte-identical continuation), and `spilled_bytes`
/// totals the KV bytes ever written to the host-side spill arena.
#[derive(Debug, Default, Clone, Copy)]
pub struct PreemptionStats {
    pub preempts: u64,
    pub resumes: u64,
    pub spilled_bytes: u64,
}

impl PreemptionStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("preempts", Json::num(self.preempts as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            ("spilled_bytes", Json::num(self.spilled_bytes as f64)),
        ])
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn rec(ms: u64, steps: u64, gen: usize, ok: bool) -> RequestRecord {
        RequestRecord {
            latency: Duration::from_millis(ms),
            steps,
            model_calls: steps + 1,
            gen_len: gen,
            correct: Some(ok),
        }
    }

    #[test]
    fn per_sample_averages() {
        let mut m = MetricsAggregator::new();
        m.record(&rec(100, 10, 20, true));
        m.record(&rec(300, 30, 40, false));
        assert_eq!(m.count(), 2);
        assert!((m.avg_latency_s() - 0.2).abs() < 1e-9);
        assert_eq!(m.avg_steps(), 20.0);
        assert_eq!(m.avg_gen_len(), 30.0);
        assert_eq!(m.score(), 50.0);
    }

    #[test]
    fn tps_is_tokens_over_total_time() {
        let mut m = MetricsAggregator::new();
        m.record(&rec(500, 5, 25, true));
        m.record(&rec(500, 5, 25, true));
        assert!((m.tps() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn unscored_requests_do_not_affect_score() {
        let mut m = MetricsAggregator::new();
        m.record(&RequestRecord {
            latency: Duration::from_millis(10),
            steps: 1,
            model_calls: 1,
            gen_len: 5,
            correct: None,
        });
        m.record(&rec(10, 1, 5, true));
        assert_eq!(m.score(), 100.0);
    }

    #[test]
    fn empty_aggregator_is_safe() {
        let m = MetricsAggregator::new();
        assert_eq!(m.tps(), 0.0);
        assert_eq!(m.score(), 0.0);
    }

    #[test]
    fn aborts_tracked_outside_the_paper_aggregates() {
        let mut m = MetricsAggregator::new();
        m.record(&rec(100, 10, 20, true));
        m.record_abort(&AbortRecord {
            steps: 7,
            model_calls: 9,
            committed_tokens: 5,
        });
        assert_eq!(m.count(), 1, "aborts never enter the sample count");
        assert_eq!(m.aborted(), 1);
        assert_eq!(m.avg_steps(), 10.0, "averages unchanged by aborts");
        let j = m.to_json();
        assert_eq!(j.get("aborted").unwrap().as_i64(), Some(1));
        assert_eq!(j.get("wasted_steps").unwrap().as_i64(), Some(7));
        assert_eq!(j.get("wasted_model_calls").unwrap().as_i64(), Some(9));
        assert_eq!(j.get("wasted_tokens").unwrap().as_i64(), Some(5));
    }

    #[test]
    fn merge_equals_single_aggregator() {
        let mut a = MetricsAggregator::new();
        let mut b = MetricsAggregator::new();
        let mut whole = MetricsAggregator::new();
        for (i, r) in
            [rec(100, 10, 20, true), rec(200, 20, 30, false)].iter().enumerate()
        {
            if i % 2 == 0 {
                a.record(r);
            } else {
                b.record(r);
            }
            whole.record(r);
        }
        b.record_abort(&AbortRecord {
            steps: 3,
            model_calls: 4,
            committed_tokens: 2,
        });
        whole.record_abort(&AbortRecord {
            steps: 3,
            model_calls: 4,
            committed_tokens: 2,
        });
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.aborted(), whole.aborted());
        assert_eq!(a.avg_steps(), whole.avg_steps());
        assert_eq!(a.tps(), whole.tps());
        assert_eq!(a.score(), whole.score());
        assert_eq!(a.to_json().to_string(), whole.to_json().to_string());
    }

    #[test]
    fn json_has_paper_fields() {
        let mut m = MetricsAggregator::new();
        m.record(&rec(100, 10, 20, true));
        let j = m.to_json();
        for k in ["tps", "avg_latency_s", "avg_steps", "avg_gen_len", "score"] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
    }
}
