//! Decode scheduler: bucket selection, batch padding, engine dispatch,
//! the parallel chunk executor, and the continuous-batching driver
//! ([`ActiveBatch`]) that pairs a resumable block-step machine with
//! per-lane response tickets.
//!
//! AOT programs exist for fixed batch buckets (manifest `buckets`, e.g.
//! {1, 2, 4}); the scheduler chunks a request list into bucket-sized
//! lockstep batches, pads the tail chunk by *borrowing* a live lane
//! (dead lanes never clone prompt buffers), runs the decode engine, and
//! drops padded outcomes.
//!
//! Chunks are independent by construction — each gets its own sequence
//! states and its own KV slot set, and every decode engine's outputs
//! depend only on its own chunk's content. `Engine::decode` therefore
//! dispatches multi-chunk plans concurrently on scoped worker threads
//! (`util::threadpool::scoped`), bounded by the backend's
//! `max_concurrency` (overridable with `CDLM_DECODE_THREADS`), and
//! reassembles results in chunk order — same-seed decode traces are
//! byte-identical to the serial path, which
//! `tests/parallel_decode.rs` pins property-style.

use anyhow::Result;

use super::batcher::GroupKey;
use super::kv_cache::KvPool;
use super::methods::machine::{BatchState, CommitRun, SuspendedLane};
use super::methods::{self, DecodeOpts, DecodeOutcome, Method};
use crate::runtime::{Geometry, ModelWeights, Programs, Runtime};
use crate::util::threadpool;

/// An engine bound to one model's weights.
pub struct Engine<'rt> {
    pub rt: &'rt Runtime,
    pub weights: &'rt ModelWeights,
    pub geom: Geometry,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, weights: &'rt ModelWeights) -> Self {
        let geom = rt.manifest.geometry.clone();
        Self { rt, weights, geom }
    }

    /// Worker threads the chunk executor may use (see
    /// [`decode_threads`]).
    pub fn decode_threads(&self) -> usize {
        decode_threads(self.rt)
    }

    /// Decode `prompts` with `method`, chunking to exported buckets.
    /// Multi-chunk plans run concurrently when the backend allows it;
    /// outcomes are always returned in request order and are
    /// trace-identical to [`Engine::decode_serial`].
    pub fn decode(
        &self,
        method: Method,
        opts: &DecodeOpts,
        prompts: &[Vec<i32>],
        pool: &mut KvPool,
    ) -> Result<Vec<DecodeOutcome>> {
        self.decode_with_threads(self.decode_threads(), method, opts,
                                 prompts, pool)
    }

    /// Strictly serial decode on the shared pool (the reference path
    /// the parallel executor is pinned against).
    pub fn decode_serial(
        &self,
        method: Method,
        opts: &DecodeOpts,
        prompts: &[Vec<i32>],
        pool: &mut KvPool,
    ) -> Result<Vec<DecodeOutcome>> {
        self.decode_with_threads(1, method, opts, prompts, pool)
    }

    /// Decode with an explicit thread budget (tests pin parallel ==
    /// serial through this entry point). The budget is always clamped
    /// to the backend's `max_concurrency` — a single-threaded backend
    /// (PJRT) can never be fanned out, whatever the caller asks for.
    pub fn decode_with_threads(
        &self,
        threads: usize,
        method: Method,
        opts: &DecodeOpts,
        prompts: &[Vec<i32>],
        pool: &mut KvPool,
    ) -> Result<Vec<DecodeOutcome>> {
        let threads =
            threads.min(self.rt.backend().max_concurrency().max(1));
        let chunks = plan_chunks(prompts.len(), &self.rt.manifest.buckets);
        if threads <= 1 || chunks.len() <= 1 {
            return self.run_chunks_serial(&chunks, method, opts, prompts,
                                          pool);
        }
        self.run_chunks_parallel(&chunks, threads, method, opts, prompts)
    }

    fn run_chunks_serial(
        &self,
        chunks: &[Chunk],
        method: Method,
        opts: &DecodeOpts,
        prompts: &[Vec<i32>],
        pool: &mut KvPool,
    ) -> Result<Vec<DecodeOutcome>> {
        let progs = Programs::new(self.rt, self.weights);
        let mut out = Vec::with_capacity(prompts.len());
        for chunk in chunks {
            let padded = pad_chunk(&prompts[out.len()..out.len() + chunk.real],
                                   chunk.bucket);
            let mut results = methods::decode_batch(
                &progs, &self.geom, opts, method, &padded, pool,
            )?;
            results.truncate(chunk.real);
            out.extend(results);
        }
        Ok(out)
    }

    /// One scoped job per chunk, each against its own KV slot set (a
    /// private pool sized to the chunk bucket — the engines allocate at
    /// most one slot per lane). Results land in per-chunk slots and are
    /// reassembled in plan order, so the outcome stream is deterministic
    /// regardless of which worker finishes first.
    fn run_chunks_parallel(
        &self,
        chunks: &[Chunk],
        threads: usize,
        method: Method,
        opts: &DecodeOpts,
        prompts: &[Vec<i32>],
    ) -> Result<Vec<DecodeOutcome>> {
        let mut starts = Vec::with_capacity(chunks.len());
        let mut acc = 0usize;
        for c in chunks {
            starts.push(acc);
            acc += c.real;
        }
        let mut results: Vec<Option<Result<Vec<DecodeOutcome>>>> = Vec::new();
        results.resize_with(chunks.len(), || None);
        let (rt, weights, geom) = (self.rt, self.weights, &self.geom);
        let jobs: Vec<_> = results
            .iter_mut()
            .zip(chunks.iter().zip(&starts))
            .map(|(slot, (&chunk, &start))| {
                move || {
                    let progs = Programs::new(rt, weights);
                    let mut pool = KvPool::new(geom, chunk.bucket);
                    let padded = pad_chunk(
                        &prompts[start..start + chunk.real],
                        chunk.bucket,
                    );
                    let r = methods::decode_batch(
                        &progs, geom, opts, method, &padded, &mut pool,
                    );
                    *slot = Some(r.map(|mut v| {
                        v.truncate(chunk.real);
                        v
                    }));
                }
            })
            .collect();
        threadpool::scoped(threads, jobs);
        let mut out = Vec::with_capacity(prompts.len());
        for r in results {
            out.extend(r.expect("chunk executor dropped a chunk")?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Continuous scheduling: one in-flight block-step batch + its tickets
// ---------------------------------------------------------------------------

/// An in-flight continuous batch: a resumable [`BatchState`] plus one
/// caller-supplied ticket per lane (the router uses the response
/// channel + arrival time; tests use plain indices). The worker loop
/// drives it one block per [`ActiveBatch::step`]; lanes that finish
/// retire immediately with their ticket, and freed lanes accept new
/// admissions between steps — iteration-level scheduling instead of
/// run-to-completion groups.
pub struct ActiveBatch<T> {
    pub key: GroupKey,
    pub state: BatchState,
    /// Set by the driver after a step error: every ticket has been
    /// failed and the batch must be dropped, not stepped again.
    pub poisoned: bool,
    /// Last admission or live step. Drained batches are retained as
    /// warm prefix caches; the driver reclaims the coldest one first
    /// when it needs room for a new key.
    pub last_active: std::time::Instant,
    /// Lanes preempted off the machine with their tickets: KV spilled
    /// to the pool's cold tier, waiting for [`ActiveBatch::try_resume`]
    /// (or [`ActiveBatch::discard_parked`] if the requester gives up).
    /// A batch with parked lanes is NOT drained even when
    /// [`ActiveBatch::is_empty`] — the driver must check both before
    /// reclaiming or dropping it, or parked requests would vanish
    /// without a terminal event.
    pub parked: Vec<(SuspendedLane, T)>,
    tickets: Vec<Option<T>>,
}

impl<T> ActiveBatch<T> {
    pub fn new(key: GroupKey, state: BatchState) -> ActiveBatch<T> {
        let cap = state.capacity();
        ActiveBatch {
            key,
            state,
            poisoned: false,
            last_active: std::time::Instant::now(),
            parked: Vec::new(),
            tickets: (0..cap).map(|_| None).collect(),
        }
    }

    pub fn free_lanes(&self) -> usize {
        self.state.free_lanes()
    }

    pub fn live_lanes(&self) -> usize {
        self.state.live_lanes()
    }

    pub fn is_empty(&self) -> bool {
        self.state.is_empty()
    }

    /// Admit one request into a free lane (bucket-1 prefill) and file
    /// its ticket. On failure the ticket is handed back so the caller
    /// can answer the requester.
    pub fn admit(
        &mut self,
        prompt_ids: &[i32],
        tau: Option<f32>,
        ticket: T,
    ) -> Result<usize, (T, anyhow::Error)> {
        match self.state.admit(prompt_ids, tau) {
            Ok(lane) => {
                self.tickets[lane] = Some(ticket);
                self.last_active = std::time::Instant::now();
                Ok(lane)
            }
            Err(e) => Err((ticket, e)),
        }
    }

    /// Advance every live lane by one block, then retire finished lanes
    /// early. Returns the cycle's [`CommitRun`]s (which lane finalized
    /// which token span — the event pipeline turns these into streamed
    /// block deltas) plus `(lane, ticket, outcome)` for every lane that
    /// finished; a finished lane's final block run precedes its retire
    /// entry, so the driver can emit `Committed` before `Finished`.
    #[allow(clippy::type_complexity)]
    pub fn step(
        &mut self,
    ) -> Result<(Vec<CommitRun>, Vec<(usize, T, DecodeOutcome)>)> {
        self.last_active = std::time::Instant::now();
        let runs = self.state.step_cycle()?;
        let finished = self
            .state
            .take_finished()
            .into_iter()
            .map(|(lane, outcome)| {
                let ticket = self.tickets[lane]
                    .take()
                    .expect("retired lane has a ticket");
                (lane, ticket, outcome)
            })
            .collect();
        Ok((runs, finished))
    }

    /// Cancel one live lane between block cycles: its state drops, its
    /// KV slot frees (unpinning any prefix chain) and its ticket comes
    /// back with the partial outcome for wasted-work accounting. The
    /// freed lane is immediately admissible.
    pub fn cancel(&mut self, lane: usize) -> Option<(T, DecodeOutcome)> {
        let outcome = self.state.cancel_lane(lane)?;
        let ticket =
            self.tickets[lane].take().expect("cancelled lane has a ticket");
        Some((ticket, outcome))
    }

    /// Borrow one live lane's ticket (commit-event bookkeeping).
    pub fn ticket_mut(&mut self, lane: usize) -> Option<&mut T> {
        self.tickets.get_mut(lane).and_then(Option::as_mut)
    }

    /// Lane ids that currently hold a ticket (live lanes), ascending.
    pub fn ticketed_lanes(&self) -> Vec<usize> {
        self.tickets
            .iter()
            .enumerate()
            .filter_map(|(i, t)| t.as_ref().map(|_| i))
            .collect()
    }

    /// Borrow one live lane's ticket immutably (preemption policy
    /// reads request priority without touching lane state).
    pub fn ticket(&self, lane: usize) -> Option<&T> {
        self.tickets.get(lane).and_then(Option::as_ref)
    }

    /// Preempt one live lane between block cycles: its decode state and
    /// spilled KV park on this batch with the ticket, and the lane
    /// frees for a new admission. Returns `false` for empty or
    /// already-finished lanes (those retire through
    /// [`ActiveBatch::step`], not preemption).
    pub fn suspend(&mut self, lane: usize) -> bool {
        match self.state.suspend_lane(lane) {
            Some(s) => {
                let ticket = self.tickets[lane]
                    .take()
                    .expect("suspended lane has a ticket");
                self.parked.push((s, ticket));
                true
            }
            None => false,
        }
    }

    /// Number of lanes currently parked on this batch.
    pub fn parked_lanes(&self) -> usize {
        self.parked.len()
    }

    /// Resume parked entry `idx` onto a free lane with byte-identical
    /// continuation. On success the ticket is re-filed and the lane id
    /// returned; if the machine cannot seat it right now the entry goes
    /// back to its position for a later retry.
    pub fn try_resume(&mut self, idx: usize) -> Option<usize> {
        if idx >= self.parked.len() {
            return None;
        }
        let (s, ticket) = self.parked.remove(idx);
        match self.state.resume_lane(s) {
            Ok(lane) => {
                self.tickets[lane] = Some(ticket);
                self.last_active = std::time::Instant::now();
                Some(lane)
            }
            Err(s) => {
                self.parked.insert(idx, (s, ticket));
                None
            }
        }
    }

    /// Drop parked entry `idx` for good (requester gone or batch
    /// teardown): spilled KV and chain pins release, and the ticket
    /// comes back with the partial outcome for abort accounting.
    pub fn discard_parked(&mut self, idx: usize) -> (T, DecodeOutcome) {
        let (s, ticket) = self.parked.remove(idx);
        (ticket, self.state.discard_suspended(s))
    }
}

/// Worker threads the decode executors (chunk fan-out here, group
/// fan-out in the router worker) may use: the machine's parallelism,
/// overridable with `CDLM_DECODE_THREADS`, always clamped to the
/// backend's `max_concurrency`. A backend cap of 1 (PJRT) wins over
/// everything — those backends must never see calls from two threads.
pub fn decode_threads(rt: &Runtime) -> usize {
    let cap = rt.backend().max_concurrency().max(1);
    if cap == 1 {
        return 1;
    }
    let machine = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    std::env::var("CDLM_DECODE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(machine)
        .min(cap)
}

/// `decode_threads` divided evenly across `replicas` concurrent shard
/// workers, never below 1 — N replicas decoding at once share the same
/// machine, so each gets a proportional slice of the thread budget
/// instead of all of them fanning out to the full parallelism.
pub fn decode_threads_shared(rt: &Runtime, replicas: usize) -> usize {
    (decode_threads(rt) / replicas.max(1)).max(1)
}

/// Borrow `real` lanes and pad to `bucket` by aliasing the last live
/// lane — no prompt buffer is ever cloned for a dead lane.
fn pad_chunk(real: &[Vec<i32>], bucket: usize) -> Vec<&[i32]> {
    let mut padded: Vec<&[i32]> = real.iter().map(Vec::as_slice).collect();
    let last = *padded.last().expect("chunk has at least one live lane");
    while padded.len() < bucket {
        padded.push(last);
    }
    padded
}

/// One lockstep batch: `real` live lanes padded up to `bucket`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub bucket: usize,
    pub real: usize,
}

/// Greedy chunk plan: largest buckets first, a padded tail chunk last.
pub fn plan_chunks(n: usize, buckets: &[usize]) -> Vec<Chunk> {
    let mut sorted: Vec<usize> = buckets.to_vec();
    sorted.sort_unstable();
    let max = *sorted.last().expect("no buckets");
    let mut out = Vec::new();
    let mut left = n;
    while left >= max {
        out.push(Chunk { bucket: max, real: max });
        left -= max;
    }
    if left > 0 {
        let bucket = sorted.iter().copied().find(|&b| b >= left).unwrap_or(max);
        out.push(Chunk { bucket, real: left });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn chunk_plan_exact_fit() {
        let c = plan_chunks(8, &[1, 2, 4]);
        assert_eq!(c, vec![Chunk { bucket: 4, real: 4 }, Chunk { bucket: 4, real: 4 }]);
    }

    #[test]
    fn chunk_plan_tail_padding() {
        let c = plan_chunks(7, &[1, 2, 4]);
        assert_eq!(
            c,
            vec![
                Chunk { bucket: 4, real: 4 },
                Chunk { bucket: 4, real: 3 },
            ]
        );
        let c = plan_chunks(1, &[1, 2, 4]);
        assert_eq!(c, vec![Chunk { bucket: 1, real: 1 }]);
        let c = plan_chunks(2, &[1, 2, 4]);
        assert_eq!(c, vec![Chunk { bucket: 2, real: 2 }]);
    }

    #[test]
    fn property_chunks_cover_all_requests() {
        check("chunks-cover", 100, |r| {
            let n = 1 + r.index(40);
            let chunks = plan_chunks(n, &[1, 2, 4]);
            let total: usize = chunks.iter().map(|c| c.real).sum();
            let valid = chunks.iter().all(|c| c.real <= c.bucket && c.real > 0);
            total == n && valid
        });
    }

    #[test]
    fn pad_chunk_aliases_last_lane() {
        let prompts = vec![vec![1, 2], vec![3, 4]];
        let padded = pad_chunk(&prompts, 4);
        assert_eq!(padded.len(), 4);
        assert_eq!(padded[1], &[3, 4]);
        // dead lanes alias lane 1's buffer, no copies
        assert!(std::ptr::eq(padded[1].as_ptr(), padded[2].as_ptr()));
        assert!(std::ptr::eq(padded[2].as_ptr(), padded[3].as_ptr()));
    }
}
