//! Decode scheduler: bucket selection, batch padding, engine dispatch.
//!
//! AOT programs exist for fixed batch buckets (manifest `buckets`, e.g.
//! {1, 2, 4}); the scheduler chunks a request list into bucket-sized
//! lockstep batches, pads the tail chunk with replicated prompts (dead
//! lanes), runs the decode engine, and drops padded outcomes.

use anyhow::Result;

use super::kv_cache::KvPool;
use super::methods::{self, DecodeOpts, DecodeOutcome, Method};
use crate::runtime::{Geometry, ModelWeights, Programs, Runtime};

/// An engine bound to one model's weights.
pub struct Engine<'rt> {
    pub rt: &'rt Runtime,
    pub weights: &'rt ModelWeights,
    pub geom: Geometry,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, weights: &'rt ModelWeights) -> Self {
        let geom = rt.manifest.geometry.clone();
        Self { rt, weights, geom }
    }

    /// Decode `prompts` with `method`, chunking to exported buckets.
    pub fn decode(
        &self,
        method: Method,
        opts: &DecodeOpts,
        prompts: &[Vec<i32>],
        pool: &mut KvPool,
    ) -> Result<Vec<DecodeOutcome>> {
        let progs = Programs::new(self.rt, self.weights);
        let mut out = Vec::with_capacity(prompts.len());
        for chunk in plan_chunks(prompts.len(), &self.rt.manifest.buckets) {
            let lo = out.len();
            let real = &prompts[lo..lo + chunk.real];
            let mut padded: Vec<Vec<i32>> = real.to_vec();
            while padded.len() < chunk.bucket {
                padded.push(real.last().unwrap().clone());
            }
            let mut results = methods::decode_batch(
                &progs, &self.geom, opts, method, &padded, pool,
            )?;
            results.truncate(chunk.real);
            out.extend(results);
        }
        Ok(out)
    }
}

/// One lockstep batch: `real` live lanes padded up to `bucket`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub bucket: usize,
    pub real: usize,
}

/// Greedy chunk plan: largest buckets first, a padded tail chunk last.
pub fn plan_chunks(n: usize, buckets: &[usize]) -> Vec<Chunk> {
    let mut sorted: Vec<usize> = buckets.to_vec();
    sorted.sort_unstable();
    let max = *sorted.last().expect("no buckets");
    let mut out = Vec::new();
    let mut left = n;
    while left >= max {
        out.push(Chunk { bucket: max, real: max });
        left -= max;
    }
    if left > 0 {
        let bucket = sorted.iter().copied().find(|&b| b >= left).unwrap_or(max);
        out.push(Chunk { bucket, real: left });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn chunk_plan_exact_fit() {
        let c = plan_chunks(8, &[1, 2, 4]);
        assert_eq!(c, vec![Chunk { bucket: 4, real: 4 }, Chunk { bucket: 4, real: 4 }]);
    }

    #[test]
    fn chunk_plan_tail_padding() {
        let c = plan_chunks(7, &[1, 2, 4]);
        assert_eq!(
            c,
            vec![
                Chunk { bucket: 4, real: 4 },
                Chunk { bucket: 4, real: 3 },
            ]
        );
        let c = plan_chunks(1, &[1, 2, 4]);
        assert_eq!(c, vec![Chunk { bucket: 1, real: 1 }]);
        let c = plan_chunks(2, &[1, 2, 4]);
        assert_eq!(c, vec![Chunk { bucket: 2, real: 2 }]);
    }

    #[test]
    fn property_chunks_cover_all_requests() {
        check("chunks-cover", 100, |r| {
            let n = 1 + r.index(40);
            let chunks = plan_chunks(n, &[1, 2, 4]);
            let total: usize = chunks.iter().map(|c| c.real).sum();
            let valid = chunks.iter().all(|c| c.real <= c.bucket && c.real > 0);
            total == n && valid
        });
    }
}
