//! L3 coordinator — the paper's serving system around the AOT compute:
//! request router + admission, dynamic batcher, block KV-cache manager,
//! decode scheduler, per-method engines, and §A.3-style metrics.

pub mod batcher;
pub mod faults;
pub mod kv_cache;
pub mod methods;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod sequence;

pub use batcher::{DynamicBatcher, GroupKey, Pending};
pub use faults::{FaultKind, FaultPlan};
pub use kv_cache::{ChainPin, KvLease, KvPool, SuspendedKv};
pub use methods::machine::{BatchState, CommitRun, SuspendedLane};
pub use methods::{DecodeOpts, DecodeOutcome, Method, ALL_METHODS};
pub use metrics::{AbortRecord, MetricsAggregator, RequestRecord};
pub use router::{
    GenerateRequest, GenerateResponse, LaneEvent, ResponseHandle, Router,
    ServingCore, SubmitError, TryEvent,
};
pub use scheduler::{ActiveBatch, Engine};
pub use sequence::SequenceState;
