//! `cdlm` — CLI for the CDLM serving stack.
//!
//! Subcommands:
//!   serve      start the HTTP server (router + dynamic batcher)
//!   generate   one-shot decode from the command line
//!   eval       method x family evaluation grid (paper-table rows)
//!   analysis   print Fig. 4 arithmetic-intensity / Fig. 9 roofline
//!   info       artifacts manifest summary

use std::time::Duration;

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::{
    DecodeOpts, GroupKey, Method, Router, ServingCore, ALL_METHODS,
};
use cdlm::server::{self, http::ServerConfig};
use cdlm::util::cli::Args;
use cdlm::workload::{self, Family};
use cdlm::{analysis, artifacts_dir};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "eval" => cmd_eval(&args),
        "analysis" => cmd_analysis(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "cdlm — Consistency Diffusion Language Model serving stack\n\
         \n\
         USAGE: cdlm <command> [--flags]\n\
         \n\
         COMMANDS:\n\
         \x20 serve      --addr 127.0.0.1:8472 --backbone dream --max-batch 4 --max-wait-ms 25\n\
         \x20 generate   --prompt 'q:3*4+5=?' --method cdlm --backbone dream [--tau 0.9]\n\
         \x20 eval       --methods cdlm,ar --families chain-arith --n 16 --backbone dream\n\
         \x20 analysis   [--fig 4|9]\n\
         \x20 info\n"
    );
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let router = Router::start(
        artifacts_dir(),
        RouterConfig {
            max_batch: args.get_usize("max-batch", 4),
            max_wait: Duration::from_millis(
                args.get_usize("max-wait-ms", 25) as u64,
            ),
            max_queue: args.get_usize("max-queue", 256),
            pool_capacity: args.get_usize("pool", 64),
        },
    )?;
    server::serve(
        router,
        ServerConfig {
            addr: args.get_or("addr", "127.0.0.1:8472").to_string(),
            default_backbone: args.get_or("backbone", "dream").to_string(),
        },
    )
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let prompt = args
        .get("prompt")
        .ok_or_else(|| anyhow::anyhow!("--prompt required"))?;
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let backbone = args.get_or("backbone", "dream").to_string();
    let mut core = ServingCore::load(&artifacts_dir(), 8)?;
    let geom = core.rt.manifest.geometry.clone();

    let mut ids = vec![cdlm::tokenizer::BOS];
    ids.extend(core.tokenizer.encode(&format!("{prompt}a:"))?);
    anyhow::ensure!(ids.len() <= geom.prompt_len, "prompt too long");
    let mut prompt_ids = vec![cdlm::tokenizer::PAD; geom.prompt_len - ids.len()];
    prompt_ids.extend(ids);

    let mut opts = DecodeOpts::defaults(&geom);
    opts.tau_conf = args.get_f64("tau", 0.9) as f32;
    let key = GroupKey { backbone, method };
    let out = core.decode_group(&key, &[prompt_ids], &opts)?;
    let o = &out[0];
    println!("text:        {}", core.tokenizer.decode(&o.gen, true));
    println!(
        "final:       {}",
        workload::extract_final(&core.tokenizer.decode(&o.gen, true))
            .unwrap_or("(none)")
    );
    println!("steps:       {}", o.steps);
    println!("model calls: {}", o.model_calls);
    println!("latency:     {:.1} ms", o.latency.as_secs_f64() * 1e3);
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let mut core = ServingCore::load(&artifacts_dir(), 16)?;
    let geom = core.rt.manifest.geometry.clone();
    let n = args.get_usize("n", 16);
    let backbone = args.get_or("backbone", "dream").to_string();
    let methods: Vec<Method> = match args.get("methods") {
        None => vec![Method::Cdlm],
        Some("all") => ALL_METHODS.to_vec(),
        Some(s) => s
            .split(',')
            .filter_map(Method::from_name)
            .collect(),
    };
    let families: Vec<Family> = match args.get("families") {
        None => vec![Family::ChainArith],
        Some("all") => workload::FAMILIES.to_vec(),
        Some(s) => s.split(',').filter_map(Family::from_name).collect(),
    };
    let mut opts = DecodeOpts::defaults(&geom);
    opts.tau_conf = args.get_f64("tau", 0.9) as f32;

    println!(
        "{:<14} {:<14} {:>8} {:>10} {:>8} {:>9} {:>7}",
        "family", "method", "TPS", "lat(ms)", "steps", "gen.len", "score"
    );
    for fam in &families {
        let samples = workload::generate(*fam, n, 0xE7A1);
        let enc: Vec<_> = samples
            .iter()
            .map(|s| {
                workload::encode_example(
                    &core.tokenizer,
                    *fam,
                    s,
                    geom.prompt_len,
                    geom.gen_len,
                )
            })
            .collect::<anyhow::Result<_>>()?;
        let prompts: Vec<Vec<i32>> =
            enc.iter().map(|e| e.prompt_ids.clone()).collect();
        for m in &methods {
            let key = GroupKey { backbone: backbone.clone(), method: *m };
            let outs = core.decode_group(&key, &prompts, &opts)?;
            let mut agg = cdlm::coordinator::MetricsAggregator::new();
            for (o, s) in outs.iter().zip(&samples) {
                let text = core.tokenizer.decode(&o.gen, true);
                agg.record(&cdlm::coordinator::RequestRecord {
                    latency: o.latency,
                    steps: o.steps,
                    model_calls: o.model_calls,
                    gen_len: o.gen_len,
                    correct: Some(workload::score(&text, s)),
                });
            }
            println!(
                "{:<14} {:<14} {:>8.1} {:>10.1} {:>8.1} {:>9.1} {:>7.1}",
                fam.name(),
                m.name(),
                agg.tps(),
                agg.avg_latency_s() * 1e3,
                agg.avg_steps(),
                agg.avg_gen_len(),
                agg.score()
            );
        }
    }
    Ok(())
}

fn cmd_analysis(args: &Args) -> anyhow::Result<()> {
    use analysis::intensity::{
        ArchConfig, DecodeMode, IntensityModel, Workload, PAPER_BATCH_SIZES,
    };
    use analysis::roofline::A100;
    let fig = args.get_usize("fig", 4);
    let ar = IntensityModel::new(ArchConfig::llama31_8b(), Workload::paper());
    let dlm = IntensityModel::new(ArchConfig::llada_8b(), Workload::paper());
    let modes = [
        ("AR (LLaMA-3.1-8B)", &ar, DecodeMode::Ar),
        ("Vanilla DLM (LLaDA-8B)", &dlm, DecodeMode::VanillaDlm),
        ("Block DLM B=4", &dlm, DecodeMode::BlockDlm { block: 4 }),
        ("Block DLM B=16", &dlm, DecodeMode::BlockDlm { block: 16 }),
        ("Block DLM B=32", &dlm, DecodeMode::BlockDlm { block: 32 }),
    ];
    if fig == 4 {
        println!("Arithmetic intensity vs batch size (ridge {:.1} FLOP/B)",
                 A100.ridge());
        print!("{:<24}", "mode");
        for bs in PAPER_BATCH_SIZES {
            print!("{bs:>9}");
        }
        println!();
        for (name, m, mode) in modes {
            print!("{name:<24}");
            for bs in PAPER_BATCH_SIZES {
                print!("{:>9.1}", m.ai(mode, bs));
            }
            println!();
        }
    } else {
        println!(
            "Roofline (A100: peak {:.1} TF/s, bw {:.0} GB/s, ridge {:.1})",
            A100.peak_flops / 1e12,
            A100.bandwidth / 1e9,
            A100.ridge()
        );
        for (name, m, mode) in modes {
            print!("{name:<24}");
            for bs in PAPER_BATCH_SIZES {
                let p = A100.simulate_mode(m, mode, bs);
                print!("{:>9.1}", p.attainable_tflops);
            }
            println!();
        }
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let core = ServingCore::load(&dir, 1)?;
    let m = &core.rt.manifest;
    let g = &m.geometry;
    println!("artifacts:   {}", dir.display());
    println!("backend:     {}", core.rt.backend_name());
    println!("platform:    {}", core.rt.platform());
    println!(
        "geometry:    d={} L={} H={} P={} Lg={} B={} V={}",
        g.d_model, g.n_layers, g.n_heads, g.prompt_len, g.gen_len,
        g.block_size, g.vocab_size
    );
    println!("programs:    {}", m.programs.len());
    println!("buckets:     {:?}  sweep blocks: {:?}", m.buckets, m.sweep_blocks);
    println!("fast mode:   {}", m.fast_mode);
    println!("models:");
    for (k, v) in &m.models {
        println!("  {k:<16} {v}");
    }
    Ok(())
}
