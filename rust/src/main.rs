//! `cdlm` — CLI for the CDLM serving stack.
//!
//! Subcommands:
//!   serve      start the HTTP server (router + continuous batching)
//!   generate   one-shot decode from the command line
//!   eval       method x family evaluation grid (paper-table rows)
//!   bench      decode-throughput grid (+ cancelled-lane accounting
//!              cells) -> machine-readable JSON; --scenario serving
//!              runs staggered arrivals through the router (continuous
//!              vs closed-batch) -> BENCH_serving.json; --scenario
//!              stream drives streaming clients + mid-stream cancels
//!              -> BENCH_stream.json; --scenario chaos replays a trace
//!              under a seeded fault plan and gates the recovery
//!              invariants -> BENCH_chaos.json; --scenario hotpath
//!              microbenches the steady-state decode step and
//!              hard-gates it allocation-free -> BENCH_hotpath.json;
//!              --scenario preempt over-subscribes a paged KV pool and
//!              gates suspend/spill/resume byte-identity plus
//!              more-live-lanes-than-contiguous-cap ->
//!              BENCH_preempt.json
//!   analysis   print Fig. 4 arithmetic-intensity / Fig. 9 roofline
//!   info       artifacts manifest summary

use std::sync::Arc;
use std::time::{Duration, Instant};

use cdlm::coordinator::router::RouterConfig;
use cdlm::coordinator::{
    DecodeOpts, DecodeOutcome, FaultPlan, GenerateRequest, GroupKey, Method,
    Router, ServingCore, SuspendedLane, ALL_METHODS,
};
use cdlm::server::{self, http::ServerConfig};
use cdlm::util::cli::Args;
use cdlm::util::json::Json;
use cdlm::util::stats::Summary;
use cdlm::workload::{self, Family};
use cdlm::{analysis, artifacts_dir};

/// Count heap acquisitions so `bench --scenario hotpath` can hard-gate
/// allocation-free steady-state decode steps. Pure pass-through to the
/// system allocator plus one relaxed counter bump per acquisition —
/// negligible for every other subcommand, so it stays installed
/// unconditionally (the gate refuses to run against an uncounted
/// binary; see `util::alloc_count`).
#[global_allocator]
static COUNTING_ALLOC: cdlm::util::alloc_count::CountingAlloc =
    cdlm::util::alloc_count::CountingAlloc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "eval" => cmd_eval(&args),
        "bench" => cmd_bench(&args),
        "analysis" => cmd_analysis(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "cdlm — Consistency Diffusion Language Model serving stack\n\
         \n\
         USAGE: cdlm <command> [--flags]\n\
         \n\
         COMMANDS:\n\
         \x20 serve      --addr 127.0.0.1:8472 --backbone dream --max-batch 4 --max-wait-ms 25 [--replicas 1] [--max-queue-depth 256] [--max-per-client 0] [--closed-batch] [--no-prefix-cache] [--io-timeout-ms 10000] [--http-threads 8] [--blocking-http] [--restart-budget 3] [--restart-window-ms 60000] [--watchdog-ms 5000] [--fault-seed N | --fault-spec SPEC]\n\
         \x20 generate   --prompt 'q:3*4+5=?' --method cdlm --backbone dream [--tau 0.9]\n\
         \x20 eval       --methods cdlm,ar --families chain-arith --n 16 --backbone dream\n\
         \x20 bench      --methods all --batches 1,2,4,8 --n 16 --out BENCH_decode.json [--replicas 1] [--check-baseline BENCH_baseline.json] [--cancel-block 2]\n\
         \x20 bench      --scenario serving --method cdlm --n 32 --arrival-ms 3 --out BENCH_serving.json\n\
         \x20 bench      --scenario prefix --method cdlm --n 24 --distinct 6 --arrival-ms 2 --out BENCH_prefix.json\n\
         \x20 bench      --scenario stream --method cdlm --n 16 --arrival-ms 2 --cancel-every 4 --cancel-after-blocks 1 --out BENCH_stream.json\n\
         \x20 bench      --scenario shard --method cdlm --n 24 --distinct 6 --replicas 4 --arrival-ms 2 --out BENCH_shard.json\n\
         \x20 bench      --scenario chaos --method cdlm --n 24 --distinct 6 --replicas 4 --arrival-ms 2 [--fault-seed N | --fault-spec SPEC] --out BENCH_chaos.json\n\
         \x20 bench      --scenario hotpath --methods all --batches 1,4 --repeats 6 --out BENCH_hotpath.json  (hard-gates 0 allocs/steady step)\n\
         \x20 bench      --scenario preempt --method cdlm --n 16 --out BENCH_preempt.json  (hard-gates preempt/resume byte-identity + paged over-subscription)\n\
         \x20 analysis   [--fig 4|9]\n\
         \x20 info\n"
    );
}

/// `--fault-spec SPEC` (explicit) or `--fault-seed N` (derived plan);
/// both absent -> no injection. Shared by serve and the bench
/// scenarios so every entry point arms faults the same way.
fn fault_plan_from_args(args: &Args) -> anyhow::Result<Option<Arc<FaultPlan>>> {
    if let Some(spec) = args.get("fault-spec") {
        let plan = FaultPlan::parse(spec)
            .map_err(|e| anyhow::anyhow!("--fault-spec: {e}"))?;
        return Ok(Some(Arc::new(plan)));
    }
    if args.has("fault-seed") {
        let seed = args.get_usize("fault-seed", 0) as u64;
        return Ok(Some(Arc::new(FaultPlan::from_seed(seed))));
    }
    Ok(None)
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let fault_plan = fault_plan_from_args(args)?;
    let router = Router::start(
        artifacts_dir(),
        RouterConfig {
            max_batch: args.get_usize("max-batch", 4),
            max_wait: Duration::from_millis(
                args.get_usize("max-wait-ms", 25) as u64,
            ),
            // --max-queue-depth is the documented spelling; --max-queue
            // stays accepted for older scripts
            max_queue: args.get_usize(
                "max-queue-depth",
                args.get_usize("max-queue", 256),
            ),
            pool_capacity: args.get_usize("pool", 64),
            continuous: !args.has("closed-batch"),
            max_active: args.get_usize("max-active", 4),
            step_delay: Duration::from_millis(
                args.get_usize("step-delay-ms", 0) as u64,
            ),
            prefix_cache: !args.has("no-prefix-cache"),
            replicas: args.get_usize("replicas", 1).max(1),
            max_per_client: args.get_usize("max-per-client", 0),
            fault_plan: fault_plan.clone(),
            restart_budget: args.get_usize("restart-budget", 3),
            restart_window: Duration::from_millis(
                args.get_usize("restart-window-ms", 60_000) as u64,
            ),
            watchdog_deadline: Duration::from_millis(
                args.get_usize("watchdog-ms", 5_000) as u64,
            ),
        },
    )?;
    server::serve(
        router,
        ServerConfig {
            addr: args.get_or("addr", "127.0.0.1:8472").to_string(),
            default_backbone: args.get_or("backbone", "dream").to_string(),
            io_timeout: Duration::from_millis(
                args.get_usize("io-timeout-ms", 10_000) as u64,
            ),
            http_threads: args.get_usize("http-threads", 8),
            blocking: args.has("blocking-http"),
            fault_plan,
        },
    )
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let prompt = args
        .get("prompt")
        .ok_or_else(|| anyhow::anyhow!("--prompt required"))?;
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let backbone = args.get_or("backbone", "dream").to_string();
    let mut core = ServingCore::load(&artifacts_dir(), 8)?;
    let geom = core.rt.manifest.geometry.clone();

    let mut ids = vec![cdlm::tokenizer::BOS];
    ids.extend(core.tokenizer.encode(&format!("{prompt}a:"))?);
    anyhow::ensure!(ids.len() <= geom.prompt_len, "prompt too long");
    let mut prompt_ids = vec![cdlm::tokenizer::PAD; geom.prompt_len - ids.len()];
    prompt_ids.extend(ids);

    let mut opts = DecodeOpts::defaults(&geom);
    opts.tau_conf = args.get_f64("tau", 0.9) as f32;
    let key = GroupKey::new(backbone, method);
    let out = core.decode_group(&key, &[prompt_ids], &opts)?;
    let o = &out[0];
    println!("text:        {}", core.tokenizer.decode(&o.gen, true));
    println!(
        "final:       {}",
        workload::extract_final(&core.tokenizer.decode(&o.gen, true))
            .unwrap_or("(none)")
    );
    println!("steps:       {}", o.steps);
    println!("model calls: {}", o.model_calls);
    println!("latency:     {:.1} ms", o.latency.as_secs_f64() * 1e3);
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let mut core = ServingCore::load(&artifacts_dir(), 16)?;
    let geom = core.rt.manifest.geometry.clone();
    let n = args.get_usize("n", 16);
    let backbone = args.get_or("backbone", "dream").to_string();
    let methods: Vec<Method> = match args.get("methods") {
        None => vec![Method::Cdlm],
        Some("all") => ALL_METHODS.to_vec(),
        Some(s) => s
            .split(',')
            .filter_map(Method::from_name)
            .collect(),
    };
    let families: Vec<Family> = match args.get("families") {
        None => vec![Family::ChainArith],
        Some("all") => workload::FAMILIES.to_vec(),
        Some(s) => s.split(',').filter_map(Family::from_name).collect(),
    };
    let mut opts = DecodeOpts::defaults(&geom);
    opts.tau_conf = args.get_f64("tau", 0.9) as f32;

    println!(
        "{:<14} {:<14} {:>8} {:>10} {:>8} {:>9} {:>7}",
        "family", "method", "TPS", "lat(ms)", "steps", "gen.len", "score"
    );
    for fam in &families {
        let samples = workload::generate(*fam, n, 0xE7A1);
        let enc: Vec<_> = samples
            .iter()
            .map(|s| {
                workload::encode_example(
                    &core.tokenizer,
                    *fam,
                    s,
                    geom.prompt_len,
                    geom.gen_len,
                )
            })
            .collect::<anyhow::Result<_>>()?;
        let prompts: Vec<Vec<i32>> =
            enc.iter().map(|e| e.prompt_ids.clone()).collect();
        for m in &methods {
            let key = GroupKey::new(backbone.clone(), *m);
            let outs = core.decode_group(&key, &prompts, &opts)?;
            let mut agg = cdlm::coordinator::MetricsAggregator::new();
            for (o, s) in outs.iter().zip(&samples) {
                let text = core.tokenizer.decode(&o.gen, true);
                agg.record(&cdlm::coordinator::RequestRecord {
                    latency: o.latency,
                    steps: o.steps,
                    model_calls: o.model_calls,
                    gen_len: o.gen_len,
                    correct: Some(workload::score(&text, s)),
                });
            }
            println!(
                "{:<14} {:<14} {:>8.1} {:>10.1} {:>8.1} {:>9.1} {:>7.1}",
                fam.name(),
                m.name(),
                agg.tps(),
                agg.avg_latency_s() * 1e3,
                agg.avg_steps(),
                agg.avg_gen_len(),
                agg.score()
            );
        }
    }
    Ok(())
}

/// Decode-throughput bench: method x batch grid on the serving core,
/// emitting the machine-readable `BENCH_decode.json` every perf PR
/// records its trajectory against (schema documented in rust/README.md).
/// `--scenario serving` instead drives staggered arrivals through the
/// router, continuous vs closed-batch, emitting `BENCH_serving.json`.
fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    match args.get_or("scenario", "decode") {
        "serving" => return cmd_bench_serving(args),
        "prefix" => return cmd_bench_prefix(args),
        "stream" => return cmd_bench_stream(args),
        "shard" => return cmd_bench_shard(args),
        "chaos" => return cmd_bench_chaos(args),
        "hotpath" => return cmd_bench_hotpath(args),
        "preempt" => return cmd_bench_preempt(args),
        _ => {}
    }
    let n = args.get_usize("n", 16);
    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_decode.json").to_string();
    let methods: Vec<Method> = match args.get("methods") {
        None | Some("all") => ALL_METHODS.to_vec(),
        Some(s) => s.split(',').filter_map(Method::from_name).collect(),
    };
    anyhow::ensure!(!methods.is_empty(), "no valid methods selected");
    let batches: Vec<usize> = args
        .get("batches")
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.parse().ok())
                .filter(|&b| b > 0)
                .collect()
        })
        // 8 > the largest exported bucket (4): the two-chunk plan also
        // exercises the parallel chunk executor in the default grid
        .unwrap_or_else(|| vec![1, 2, 4, 8]);
    anyhow::ensure!(!batches.is_empty(), "no valid batch sizes selected");
    let max_bs = *batches.iter().max().expect("batches nonempty");

    let mut core = ServingCore::load(&artifacts_dir(), (2 * max_bs).max(16))?;
    let geom = core.rt.manifest.geometry.clone();
    let mut opts = DecodeOpts::defaults(&geom);
    opts.tau_conf = args.get_f64("tau", 0.9) as f32;

    let samples = workload::generate(Family::ChainArith, n, 0xE7A1);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;

    println!(
        "{:<14} {:>6} {:>12} {:>10} {:>10} {:>8} {:>8}",
        "method", "batch", "tokens/s", "p50(ms)", "p95(ms)", "steps",
        "calls"
    );
    let mut results = Vec::new();
    for m in &methods {
        let key = GroupKey::new(backbone.clone(), *m);
        for &requested_bs in &batches {
            // the JSON must record the batch that actually decoded, not
            // the requested one (n < batch clamps the group size)
            let bs = requested_bs.min(prompts.len());
            // warm-up outside the timed region: compiling backends must
            // build this batch's program variants before the clock runs
            core.decode_group(&key, &prompts[..bs], &opts)?;
            let mut lat_s = Summary::new();
            let mut steps = Summary::new();
            let mut calls = Summary::new();
            let mut tokens = 0usize;
            let (mut total_steps, mut total_calls) = (0u64, 0u64);
            let t0 = Instant::now();
            for chunk in prompts.chunks(bs) {
                let outs = core.decode_group(&key, chunk, &opts)?;
                for o in &outs {
                    lat_s.push(o.latency.as_secs_f64());
                    steps.push(o.steps as f64);
                    calls.push(o.model_calls as f64);
                    tokens += o.gen_len;
                    total_steps += o.steps;
                    total_calls += o.model_calls;
                }
            }
            let wall_s = t0.elapsed().as_secs_f64();
            let tps = tokens as f64 / wall_s.max(1e-9);
            println!(
                "{:<14} {:>6} {:>12.1} {:>10.2} {:>10.2} {:>8.1} {:>8.1}",
                m.name(),
                bs,
                tps,
                lat_s.percentile(50.0) * 1e3,
                lat_s.percentile(95.0) * 1e3,
                steps.mean(),
                calls.mean()
            );
            results.push(Json::obj(vec![
                ("method", Json::str(m.name())),
                ("batch", Json::num(bs as f64)),
                ("requests", Json::num(lat_s.count() as f64)),
                ("tokens", Json::num(tokens as f64)),
                ("wall_s", Json::num(wall_s)),
                ("tokens_per_s", Json::num(tps)),
                ("p50_latency_ms", Json::num(lat_s.percentile(50.0) * 1e3)),
                ("p95_latency_ms", Json::num(lat_s.percentile(95.0) * 1e3)),
                ("avg_steps", Json::num(steps.mean())),
                ("avg_model_calls", Json::num(calls.mean())),
                // integer totals: the deterministic accounting CI gates
                // on (latency fields stay unasserted — runners are noisy)
                ("total_steps", Json::num(total_steps as f64)),
                ("total_model_calls", Json::num(total_calls as f64)),
            ]));
        }
    }
    // ---- cancelled-lane accounting cells: admit a full machine batch,
    // advance `--cancel-block` block cycles, then cancel every
    // surviving lane at the boundary (the streaming pipeline's
    // disconnect/deadline path). The work burned up to the cancel is a
    // pure function of the reference backend, so these integers are
    // gated by --check-baseline exactly like the full-decode cells
    // (python/tools/gen_bench_baseline.py ports the same truncation).
    let cancel_block = args.get_usize("cancel-block", 2);
    for m in &methods {
        let key = GroupKey::new(backbone.clone(), *m);
        let bs = 4.min(prompts.len());
        if bs == 0 {
            break;
        }
        let mut st = core.open_batch(&key, opts.clone(), bs)?;
        let mut outcomes = Vec::new();
        for p in &prompts[..bs] {
            st.admit(p, None)?;
        }
        for _ in 0..cancel_block {
            if st.is_empty() {
                break;
            }
            st.step_cycle()?;
            outcomes.extend(st.take_finished().into_iter().map(|(_, o)| o));
        }
        let mut cancelled = 0u64;
        for lane in 0..st.capacity() {
            if let Some(o) = st.cancel_lane(lane) {
                cancelled += 1;
                outcomes.push(o);
            }
        }
        anyhow::ensure!(
            st.kv_in_use() == 0,
            "cancelled lanes must free every KV slot"
        );
        let tokens: usize = outcomes.iter().map(|o| o.gen_len).sum();
        let total_steps: u64 = outcomes.iter().map(|o| o.steps).sum();
        let total_calls: u64 = outcomes.iter().map(|o| o.model_calls).sum();
        println!(
            "{:<14} {:>6} cancel@{cancel_block}: cancelled {} of {}, \
             steps {}, calls {}",
            m.name(),
            bs,
            cancelled,
            outcomes.len(),
            total_steps,
            total_calls
        );
        results.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("batch", Json::num(bs as f64)),
            ("cancel_at_block", Json::num(cancel_block as f64)),
            ("cancelled_lanes", Json::num(cancelled as f64)),
            ("requests", Json::num(outcomes.len() as f64)),
            ("tokens", Json::num(tokens as f64)),
            ("total_steps", Json::num(total_steps as f64)),
            ("total_model_calls", Json::num(total_calls as f64)),
        ]));
    }
    // ---- routed shard-invariance cells: the same prompts driven
    // through the sharded router (replica count from --replicas),
    // closed-loop so every request decodes in a solo cohort. Per-lane
    // accounting in a cohort depends on the slowest cohort mate (the
    // lockstep refinement loop), so solo cohorts are the composition
    // every replica count reproduces exactly — these integers are
    // byte-identical whether the dispatcher ran 1 shard or 4, and the
    // CI matrix gates both against the same committed baseline.
    let replicas = args.get_usize("replicas", 1).max(1);
    // armed via --fault-seed/--fault-spec: the faulted CI leg kills a
    // worker mid-run and gates that the routed integers don't move (the
    // seeded plan panics pre-commit, so every victim is re-dispatchable)
    let fault_plan = fault_plan_from_args(args)?;
    if let Some(plan) = &fault_plan {
        println!("fault plan armed for routed cells: {}", plan.spec());
    }
    for m in &methods {
        let (requests, tokens, total_steps, total_calls) = routed_solo_cells(
            &prompts,
            &backbone,
            *m,
            replicas,
            opts.tau_conf,
            fault_plan.clone(),
        )?;
        println!(
            "{:<14} routed x{replicas}: requests {requests}, tokens {tokens}, \
             steps {total_steps}, calls {total_calls}",
            m.name(),
        );
        results.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("batch", Json::num(1.0)),
            // marks the cell as router-driven: keyed separately from the
            // direct batch-1 cell, identical accounting by construction
            ("routed", Json::num(1.0)),
            ("requests", Json::num(requests as f64)),
            ("tokens", Json::num(tokens as f64)),
            ("total_steps", Json::num(total_steps as f64)),
            ("total_model_calls", Json::num(total_calls as f64)),
        ]));
    }
    // ---- preempted-lane accounting cells: the same machine batch as
    // the cancel cells, but after the first block cycle every live lane
    // is suspended to the pool's cold tier and immediately resumed (a
    // full spill + reseat round trip). Preemption is required to be
    // invisible in the accounting: each run is checked byte-identical
    // to its uninterrupted twin right here, and the committed baseline
    // pins the integers under a separate "preempt": 1 cell identity so
    // any silent drift in the suspend/resume path fails the CI gate.
    for m in &methods {
        let key = GroupKey::new(backbone.clone(), *m);
        let bs = 4.min(prompts.len());
        if bs == 0 {
            break;
        }
        let (base, _) = machine_batch_outcomes(
            &mut core,
            &key,
            &opts,
            &prompts[..bs],
            false,
        )?;
        let (outs, preempts) = machine_batch_outcomes(
            &mut core,
            &key,
            &opts,
            &prompts[..bs],
            true,
        )?;
        for (b, o) in base.iter().zip(&outs) {
            anyhow::ensure!(
                b.gen == o.gen
                    && b.steps == o.steps
                    && b.model_calls == o.model_calls,
                "{}: preempted lane diverged from uninterrupted decode",
                m.name()
            );
        }
        let tokens: usize = outs.iter().map(|o| o.gen_len).sum();
        let total_steps: u64 = outs.iter().map(|o| o.steps).sum();
        let total_calls: u64 = outs.iter().map(|o| o.model_calls).sum();
        println!(
            "{:<14} {:>6} preempt: {} suspended, steps {}, calls {}",
            m.name(),
            bs,
            preempts,
            total_steps,
            total_calls
        );
        results.push(Json::obj(vec![
            ("method", Json::str(m.name())),
            ("batch", Json::num(bs as f64)),
            // marks the spill/resume round-trip cell: keyed separately
            // from the plain batch cells, accounting identical to an
            // uninterrupted run by the in-bench check above
            ("preempt", Json::num(1.0)),
            ("requests", Json::num(outs.len() as f64)),
            ("tokens", Json::num(tokens as f64)),
            ("total_steps", Json::num(total_steps as f64)),
            ("total_model_calls", Json::num(total_calls as f64)),
        ]));
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.decode/v1")),
        ("backend", Json::str(core.rt.backend_name())),
        ("platform", Json::str(core.rt.platform())),
        ("backbone", Json::str(backbone.as_str())),
        (
            "decode_threads",
            Json::num(
                cdlm::coordinator::scheduler::decode_threads(&core.rt) as f64,
            ),
        ),
        ("n", Json::num(n as f64)),
        ("gen_len", Json::num(geom.gen_len as f64)),
        ("block_size", Json::num(geom.block_size as f64)),
        // how many router shards the routed cells ran on — recorded for
        // the CI matrix logs, never part of the cell identity (the whole
        // point is that the cells don't change with it)
        ("replicas", Json::num(replicas as f64)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write(&out_path, doc.to_string())?;
    println!("results -> {out_path}");
    if let Some(baseline_path) = args.get("check-baseline") {
        let baseline = Json::parse(&std::fs::read_to_string(baseline_path)?)
            .map_err(|e| anyhow::anyhow!("bad baseline json: {e}"))?;
        cdlm::bench_support::check_baseline(&doc, &baseline).map_err(|e| {
            anyhow::anyhow!(
                "accounting drifted from {baseline_path}:\n{e}\n\
                 If the drift is intentional, regenerate the baseline \
                 (see rust/README.md, 'The accounting baseline gate')."
            )
        })?;
        println!("accounting matches {baseline_path}");
    }
    Ok(())
}

/// Run one machine batch of `prompts` to completion on a fully
/// provisioned pool, optionally suspending **and immediately
/// resuming** every live lane at the first block boundary (the
/// spill/reseat round trip the preempt accounting cells pin). Returns
/// outcomes in admission order plus the pool's lifetime preempt count.
fn machine_batch_outcomes(
    core: &mut ServingCore,
    key: &GroupKey,
    opts: &DecodeOpts,
    prompts: &[Vec<i32>],
    preempt_roundtrip: bool,
) -> anyhow::Result<(Vec<DecodeOutcome>, u64)> {
    let mut st = core.open_batch(key, opts.clone(), prompts.len())?;
    // lane -> admission index; resumes reseat on the first free lane,
    // so the map follows every suspend/resume round trip
    let mut orig = vec![usize::MAX; st.capacity()];
    let mut outs: Vec<Option<DecodeOutcome>> =
        prompts.iter().map(|_| None).collect();
    for (i, p) in prompts.iter().enumerate() {
        let lane = st.admit(p, None)?;
        orig[lane] = i;
    }
    let mut first = true;
    while !st.is_empty() {
        st.step_cycle()?;
        for (lane, o) in st.take_finished() {
            outs[orig[lane]] = Some(o);
        }
        if preempt_roundtrip && first {
            first = false;
            let mut parked: Vec<(SuspendedLane, usize)> = Vec::new();
            for lane in 0..st.capacity() {
                if let Some(s) = st.suspend_lane(lane) {
                    parked.push((s, orig[lane]));
                }
            }
            for (s, req) in parked {
                let lane = st.resume_lane(s).map_err(|_| {
                    anyhow::anyhow!(
                        "resume refused on a fully provisioned pool"
                    )
                })?;
                orig[lane] = req;
            }
        }
    }
    st.assert_kv_balanced();
    let preempts = st.kv_preempts();
    let outs = outs
        .into_iter()
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| anyhow::anyhow!("machine batch lost an outcome"))?;
    Ok((outs, preempts))
}

/// `--scenario preempt`: SLO-preemption pressure cooker on one
/// over-subscribed machine (schema `cdlm.bench.preempt/v1`). The pool
/// is built with a tail-page budget that one-owner contiguous-slot
/// provisioning could serve to only `contiguous_lane_cap` lanes; paged
/// on-demand allocation admits a full wave anyway, runs it through its
/// first block cycle, then trims the live set back to the contiguous
/// cap by suspending the over-admitted lanes to the cold tier (a
/// free-list watermark stays armed as safety net), survivors drain,
/// and the parked lanes resume (timed) and run out one at a time.
/// Hard gates, not trend data:
///   * `max_live_lanes > contiguous_lane_cap` (paged over-subscription
///     actually happened)
///   * every preempted request byte-identical to its uninterrupted
///     twin (gen ids, steps, model_calls)
///   * `resumes == preempts > 0`, `spilled_bytes > 0`, and the pool
///     balances after every wave
/// Resume-latency percentiles are advisory trend data (CI runners are
/// too noisy to gate on).
fn cmd_bench_preempt(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 16);
    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_preempt.json").to_string();
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown --method"))?;
    anyhow::ensure!(
        method.uses_kv_cache(),
        "--scenario preempt needs a KV-caching method (cache-less lanes \
         have no pages to spill)"
    );
    let mut core = ServingCore::load(&artifacts_dir(), 16)?;
    let geom = core.rt.manifest.geometry.clone();
    let opts = DecodeOpts::defaults(&geom);
    let key = GroupKey::new(backbone.clone(), method);

    let samples = workload::generate(Family::ChainArith, n, 0x9E21);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &core.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!prompts.is_empty(), "need at least one prompt");

    // the pressure cooker: tail pages for only TWO full gen regions
    // shared by four lanes — contiguous provisioning caps at 2 live
    // lanes, paged allocation runs all 4 and preempts to finish
    let tail_full = if geom.block_size > 0 {
        (geom.seq_len - geom.prompt_len).max(1).div_ceil(geom.block_size)
    } else {
        1
    };
    let mut st = core.open_batch_budgeted(
        &key,
        opts.clone(),
        4,
        4,
        2 * tail_full,
    )?;
    let lanes = st.capacity();
    let contiguous_cap = (st.kv_tail_page_budget() / st.kv_tail_pages_full())
        .min(st.kv_prompt_page_budget());

    // uninterrupted twins, on fully provisioned machines of the same
    // wave width
    let mut reference: Vec<DecodeOutcome> = Vec::with_capacity(prompts.len());
    for wave in prompts.chunks(lanes) {
        let (outs, _) =
            machine_batch_outcomes(&mut core, &key, &opts, wave, false)?;
        reference.extend(outs);
    }

    let mut resume_lat = Summary::new();
    let mut max_live = 0usize;
    let mut waves = 0usize;
    let mut outs: Vec<Option<DecodeOutcome>> =
        prompts.iter().map(|_| None).collect();
    let t0 = Instant::now();
    for (w, wave) in prompts.chunks(lanes).enumerate() {
        waves += 1;
        let base = w * lanes;
        let mut orig = vec![usize::MAX; st.capacity()];
        for (i, p) in wave.iter().enumerate() {
            let lane = st.admit(p, None)?;
            orig[lane] = base + i;
        }
        max_live = max_live.max(st.live_lanes());
        // phase 1: run the whole over-admitted wave through its first
        // block cycle, then trim back to the contiguous cap — the
        // lanes admitted beyond guaranteed capacity spill to the cold
        // tier (this is the SLO scheduler's over-admission paying its
        // debt). A free-list watermark stays armed as the safety net:
        // every unfinished lane may commit one tail page per cycle.
        let mut parked: Vec<(SuspendedLane, usize)> = Vec::new();
        let mut trimmed = false;
        while !st.is_empty() {
            while st.kv_tail_pages_free() < st.unfinished_lanes()
                || (trimmed && st.unfinished_lanes() > contiguous_cap)
            {
                let mut suspended = false;
                for lane in 0..st.capacity() {
                    if let Some(s) = st.suspend_lane(lane) {
                        parked.push((s, orig[lane]));
                        suspended = true;
                        break;
                    }
                }
                anyhow::ensure!(
                    suspended,
                    "page pressure with no suspendable lane"
                );
            }
            if st.is_empty() {
                break;
            }
            st.step_cycle()?;
            trimmed = true;
            for (lane, o) in st.take_finished() {
                outs[orig[lane]] = Some(o);
            }
        }
        // phase 2: resume each parked lane (timed) and run it out
        // solo — the drained pool always seats one full lane
        for (s, req) in parked {
            anyhow::ensure!(
                st.can_resume(&s),
                "drained machine must reseat a parked lane"
            );
            let tr = Instant::now();
            let lane = st
                .resume_lane(s)
                .map_err(|_| anyhow::anyhow!("resume refused"))?;
            resume_lat.push(tr.elapsed().as_secs_f64());
            orig[lane] = req;
            while !st.is_empty() {
                st.step_cycle()?;
                for (l, o) in st.take_finished() {
                    outs[orig[l]] = Some(o);
                }
            }
        }
        st.assert_kv_balanced();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let preempts = st.kv_preempts();
    let resumes = st.kv_resumes();
    let spilled_bytes = st.kv_spilled_bytes();

    // ---- the gates
    anyhow::ensure!(
        max_live > contiguous_cap,
        "paged pool must sustain more live lanes than the contiguous \
         slot cap (live {max_live} <= cap {contiguous_cap})"
    );
    anyhow::ensure!(
        preempts > 0 && resumes == preempts,
        "every preempt must resume (preempts {preempts}, resumes {resumes})"
    );
    anyhow::ensure!(spilled_bytes > 0, "preemption spilled no bytes");
    let outs: Vec<DecodeOutcome> = outs
        .into_iter()
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| anyhow::anyhow!("a request lost its outcome"))?;
    for (i, (o, r)) in outs.iter().zip(&reference).enumerate() {
        anyhow::ensure!(
            o.gen == r.gen
                && o.steps == r.steps
                && o.model_calls == r.model_calls,
            "request {i}: preempted decode diverged from its \
             uninterrupted twin"
        );
    }

    println!(
        "preempt: {} requests in {} waves of {} lanes  (tail budget {} \
         pages, contiguous cap {} lanes)",
        outs.len(),
        waves,
        lanes,
        st.kv_tail_page_budget(),
        contiguous_cap
    );
    println!(
        "  max live {}  preempts {}  resumes {}  spilled {} B  resume \
         p50 {:.3} ms  p95 {:.3} ms",
        max_live,
        preempts,
        resumes,
        spilled_bytes,
        resume_lat.percentile(50.0) * 1e3,
        resume_lat.percentile(95.0) * 1e3
    );
    println!(
        "  all {} outcomes byte-identical to uninterrupted twins",
        outs.len()
    );

    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.preempt/v1")),
        ("backend", Json::str(core.rt.backend_name())),
        ("platform", Json::str(core.rt.platform())),
        ("backbone", Json::str(backbone.as_str())),
        ("method", Json::str(method.name())),
        ("n", Json::num(outs.len() as f64)),
        ("lanes", Json::num(lanes as f64)),
        ("prompt_page_budget", Json::num(st.kv_prompt_page_budget() as f64)),
        ("tail_page_budget", Json::num(st.kv_tail_page_budget() as f64)),
        ("tail_pages_full", Json::num(st.kv_tail_pages_full() as f64)),
        ("contiguous_lane_cap", Json::num(contiguous_cap as f64)),
        ("max_live_lanes", Json::num(max_live as f64)),
        ("preempts", Json::num(preempts as f64)),
        ("resumes", Json::num(resumes as f64)),
        ("spilled_bytes", Json::num(spilled_bytes as f64)),
        (
            "resume_p50_ms",
            Json::num(resume_lat.percentile(50.0) * 1e3),
        ),
        (
            "resume_p95_ms",
            Json::num(resume_lat.percentile(95.0) * 1e3),
        ),
        ("byte_identical", Json::num(1.0)),
        ("wall_s", Json::num(wall_s)),
    ]);
    std::fs::write(&out_path, doc.to_string())?;
    println!("results -> {out_path}");
    Ok(())
}

/// Drive one open-loop arrival trace through a fresh router: submit
/// every prompt with an `arrival` gap, collect responses in arrival
/// order, snapshot `/healthz` *before* shutdown (retained machines
/// still hold their live counters), and return the wall time. Both
/// serving-style benches are built on this one driver.
fn drive_trace(
    cfg: RouterConfig,
    prompts: &[Vec<i32>],
    backbone: &str,
    method: Method,
    arrival: Duration,
) -> anyhow::Result<(Vec<cdlm::coordinator::GenerateResponse>, f64, Json)> {
    let router = Router::start(artifacts_dir(), cfg)?;
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(prompts.len());
    for p in prompts {
        handles.push(router.submit(GenerateRequest::new(
            backbone,
            method,
            p.clone(),
        ))?);
        std::thread::sleep(arrival);
    }
    let mut responses = Vec::with_capacity(handles.len());
    for h in handles {
        responses.push(h.wait().map_err(|e| anyhow::anyhow!("{e}"))?);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let health = router.health()?;
    router.shutdown();
    Ok((responses, wall_s, health))
}

/// Closed-loop solo decode of every prompt through a sharded router:
/// submit one request, wait for its terminal response, then the next —
/// each request therefore decodes in a cohort of one on whichever
/// replica the dispatcher picked, and its step/model-call accounting is
/// a pure function of the request. Returns the summed accounting cell
/// `(requests, tokens, total_steps, total_model_calls)`.
fn routed_solo_cells(
    prompts: &[Vec<i32>],
    backbone: &str,
    method: Method,
    replicas: usize,
    tau: f32,
    fault_plan: Option<Arc<FaultPlan>>,
) -> anyhow::Result<(usize, usize, u64, u64)> {
    let router = Router::start(
        artifacts_dir(),
        RouterConfig {
            max_queue: prompts.len().max(256),
            replicas,
            // repeated PAD-heavy prompts must not skip prefills: the
            // cell gates cold accounting
            prefix_cache: false,
            // solo cohorts make every in-flight victim of an injected
            // worker kill re-dispatchable with identical accounting
            fault_plan,
            ..RouterConfig::default()
        },
    )?;
    let (mut tokens, mut steps, mut calls) = (0usize, 0u64, 0u64);
    for p in prompts {
        let mut req = GenerateRequest::new(backbone, method, p.clone());
        req.tau_conf = Some(tau);
        let resp = router
            .submit(req)?
            .wait()
            .map_err(|e| anyhow::anyhow!("routed decode aborted: {e}"))?;
        tokens += resp.gen_len;
        steps += resp.steps;
        calls += resp.model_calls;
    }
    router.shutdown();
    Ok((prompts.len(), tokens, steps, calls))
}

/// Shard bench (`--scenario shard`): the same open-loop arrival trace
/// of templated traffic (`--distinct` unique prompts round-robined over
/// `--n` arrivals) at 1 replica vs `--replicas`, reporting TTFT
/// percentiles, per-replica admissions, affinity hit rate, and steal
/// counts; then a saturation burst against a deliberately tiny queue to
/// record the admission-control refusals (429s + `Retry-After` hints).
/// Schema `cdlm.bench.shard/v1`, run as a CI smoke with an artifact —
/// latency-shaped numbers stay unasserted, and the accounting-grade
/// shard invariance is gated by the routed cells of the decode bench.
fn cmd_bench_shard(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 24);
    let distinct = args.get_usize("distinct", 6).clamp(1, n.max(1));
    let replicas = args.get_usize("replicas", 4).max(1);
    let arrival =
        Duration::from_millis(args.get_usize("arrival-ms", 2) as u64);
    let max_batch = args.get_usize("max-batch", 2);
    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_shard.json").to_string();
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;

    let probe = ServingCore::load(&artifacts_dir(), 1)?;
    let geom = probe.rt.manifest.geometry.clone();
    let samples = workload::generate(Family::ChainArith, distinct, 0xE7A1);
    let base: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &probe.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;
    let prompts: Vec<Vec<i32>> =
        (0..n).map(|i| base[i % distinct].clone()).collect();
    let backend = probe.rt.backend_name();
    drop(probe);

    // ---- phase A: the same trace at 1 replica vs N
    println!(
        "{:<10} {:>11} {:>11} {:>9} {:>9} {:>7} {:>9}",
        "replicas", "ttft-p50", "ttft-p95", "affinity", "spill", "stolen",
        "wall(s)"
    );
    let mut variants = Vec::new();
    let mut counts = vec![1];
    if replicas > 1 {
        counts.push(replicas);
    }
    for r in counts {
        let (responses, wall_s, health) = drive_trace(
            RouterConfig {
                max_batch,
                max_queue: n.max(256),
                replicas: r,
                ..RouterConfig::default()
            },
            &prompts,
            &backbone,
            method,
            arrival,
        )?;
        let mut ttft = Summary::new();
        for resp in &responses {
            ttft.push(resp.ttft.as_secs_f64() * 1e3);
        }
        let stat =
            |k: &str| health.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let admitted = stat("admitted_requests");
        let affinity_rate = if admitted > 0.0 {
            stat("affinity_admissions") / admitted
        } else {
            0.0
        };
        // per-replica breakdown straight from the merged health's
        // "shards" array
        let per_replica: Vec<Json> = health
            .get("shards")
            .and_then(Json::as_arr)
            .map(|shards| {
                shards
                    .iter()
                    .map(|s| {
                        let g = |k: &str| {
                            s.get(k).and_then(Json::as_f64).unwrap_or(0.0)
                        };
                        Json::obj(vec![
                            ("replica", Json::num(g("replica"))),
                            (
                                "admitted_requests",
                                Json::num(g("admitted_requests")),
                            ),
                            (
                                "affinity_admissions",
                                Json::num(g("affinity_admissions")),
                            ),
                            ("stolen", Json::num(g("stolen"))),
                        ])
                    })
                    .collect()
            })
            .unwrap_or_default();
        println!(
            "{:<10} {:>11.2} {:>11.2} {:>8.0}% {:>9} {:>7} {:>9.2}",
            r,
            ttft.percentile(50.0),
            ttft.percentile(95.0),
            affinity_rate * 100.0,
            stat("routed_spill") as u64,
            stat("stolen") as u64,
            wall_s
        );
        variants.push(Json::obj(vec![
            ("replicas", Json::num(r as f64)),
            ("requests", Json::num(responses.len() as f64)),
            ("ttft_p50_ms", Json::num(ttft.percentile(50.0))),
            ("ttft_p95_ms", Json::num(ttft.percentile(95.0))),
            ("ttft_mean_ms", Json::num(ttft.mean())),
            ("wall_s", Json::num(wall_s)),
            ("admitted_requests", Json::num(admitted)),
            ("affinity_admissions", Json::num(stat("affinity_admissions"))),
            ("affinity_hit_rate", Json::num(affinity_rate)),
            ("routed_affinity", Json::num(stat("routed_affinity"))),
            ("routed_spill", Json::num(stat("routed_spill"))),
            ("stolen", Json::num(stat("stolen"))),
            ("per_replica", Json::Arr(per_replica)),
        ]));
    }

    // ---- phase B: saturation burst against a deliberately tiny queue.
    // step_delay holds lanes in flight so the burst meets a full queue;
    // the refusals and their Retry-After hints are the product here.
    let router = Router::start(
        artifacts_dir(),
        RouterConfig {
            max_batch: 1,
            max_active: 1,
            max_queue: 2,
            replicas,
            step_delay: Duration::from_millis(20),
            // tight on purpose: the two burst clients trip the fairness
            // cap as well as the full queue
            max_per_client: 2,
            ..RouterConfig::default()
        },
    )?;
    let mut handles = Vec::new();
    let (mut rejected_429, mut rejected_other) = (0u64, 0u64);
    let mut retry_hints = Summary::new();
    for (i, p) in prompts.iter().enumerate() {
        let mut req =
            GenerateRequest::new(backbone.as_str(), method, p.clone());
        req.client = Some(format!("burst-client-{}", i % 2));
        match router.submit(req) {
            Ok(h) => handles.push(h),
            Err(e) if e.status() == 429 => {
                rejected_429 += 1;
                if let Some(d) = e.retry_after() {
                    retry_hints.push(d.as_secs_f64());
                }
            }
            Err(_) => rejected_other += 1,
        }
    }
    let accepted = handles.len();
    for h in handles {
        h.wait().map_err(|e| anyhow::anyhow!("burst decode failed: {e}"))?;
    }
    let health = router.health()?;
    let stat = |k: &str| health.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let saturation = Json::obj(vec![
        ("submitted", Json::num(n as f64)),
        ("accepted", Json::num(accepted as f64)),
        ("rejected_429", Json::num(rejected_429 as f64)),
        ("rejected_other", Json::num(rejected_other as f64)),
        ("rejected_queue_full", Json::num(stat("rejected_queue_full"))),
        ("rejected_client_cap", Json::num(stat("rejected_client_cap"))),
        ("retry_after_mean_s", Json::num(retry_hints.mean())),
    ]);
    router.shutdown();
    println!(
        "saturation burst: {accepted}/{n} accepted, {rejected_429} x 429 \
         (queue_full {}, client_cap {}), mean Retry-After {:.1}s",
        stat("rejected_queue_full"),
        stat("rejected_client_cap"),
        retry_hints.mean()
    );

    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.shard/v1")),
        ("backend", Json::str(backend)),
        ("backbone", Json::str(backbone.as_str())),
        ("method", Json::str(method.name())),
        ("n", Json::num(n as f64)),
        ("distinct_prompts", Json::num(distinct as f64)),
        ("replicas", Json::num(replicas as f64)),
        ("arrival_ms", Json::num(arrival.as_millis() as f64)),
        ("max_batch", Json::num(max_batch as f64)),
        ("gen_len", Json::num(geom.gen_len as f64)),
        ("block_size", Json::num(geom.block_size as f64)),
        ("variants", Json::Arr(variants)),
        ("saturation", saturation),
    ]);
    std::fs::write(&out_path, doc.to_string())?;
    println!("results -> {out_path}");
    Ok(())
}

/// Chaos bench (`--scenario chaos`): the same open-loop arrival trace
/// run twice — clean, then with a seeded fault plan armed — gating the
/// supervision layer's recovery story end to end. The report's hard
/// invariants (violations fail the run, they are not just numbers):
/// every submitted request observes **exactly one terminal event**;
/// every request that finishes under faults returns **byte-identical**
/// text and token ids to its clean twin (per-lane decode traces are
/// pure functions of the request, so a re-dispatched replay must be
/// indistinguishable); any abort names a supervision reason; and the
/// armed plan actually fired. Recovery stats (panics, watchdog trips,
/// re-dispatches, respawn latency) come from the merged health
/// snapshot. Schema `cdlm.bench.chaos/v1`, run as a CI smoke with an
/// artifact.
fn cmd_bench_chaos(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 24);
    let distinct = args.get_usize("distinct", 6).clamp(1, n.max(1));
    let replicas = args.get_usize("replicas", 4).max(1);
    let arrival =
        Duration::from_millis(args.get_usize("arrival-ms", 2) as u64);
    let max_batch = args.get_usize("max-batch", 2);
    // a small per-step delay keeps lanes in flight long enough for the
    // plan's triggers to land mid-trace
    let step_delay =
        Duration::from_millis(args.get_usize("step-delay-ms", 2) as u64);
    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_chaos.json").to_string();
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let plan = match fault_plan_from_args(args)? {
        Some(p) => p,
        None => Arc::new(FaultPlan::from_seed(0xC4A05)),
    };

    let probe = ServingCore::load(&artifacts_dir(), 1)?;
    let geom = probe.rt.manifest.geometry.clone();
    let samples = workload::generate(Family::ChainArith, distinct, 0xE7A1);
    let base: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &probe.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;
    let prompts: Vec<Vec<i32>> =
        (0..n).map(|i| base[i % distinct].clone()).collect();
    let backend = probe.rt.backend_name();
    drop(probe);

    // one pass: submit the trace, drain every stream off-thread with
    // the terminal audit, snapshot health before shutdown
    let run = |fault: Option<Arc<FaultPlan>>| -> anyhow::Result<(
        Vec<Option<cdlm::bench_support::TerminalAudit>>,
        u64,
        f64,
        Json,
    )> {
        let router = Router::start(
            artifacts_dir(),
            RouterConfig {
                max_batch,
                max_queue: n.max(256),
                replicas,
                step_delay,
                prefix_cache: false,
                fault_plan: fault,
                ..RouterConfig::default()
            },
        )?;
        let t0 = Instant::now();
        let mut consumers = Vec::with_capacity(n);
        let mut rejected = 0u64;
        for p in &prompts {
            match router.submit(GenerateRequest::new(
                backbone.as_str(),
                method,
                p.clone(),
            )) {
                Ok(handle) => consumers.push(Some(std::thread::spawn(
                    move || cdlm::bench_support::drain_and_audit(&handle),
                ))),
                // a degraded router may refuse late arrivals after a
                // restart budget exhausts — legal, counted, not audited
                Err(_) => {
                    rejected += 1;
                    consumers.push(None);
                }
            }
            std::thread::sleep(arrival);
        }
        let audits: Vec<_> = consumers
            .into_iter()
            .map(|c| {
                c.map(|t| t.join().expect("chaos consumer panicked"))
            })
            .collect();
        let wall_s = t0.elapsed().as_secs_f64();
        let health = router.health()?;
        router.shutdown();
        Ok((audits, rejected, wall_s, health))
    };

    let (clean, clean_rejected, clean_wall_s, _clean_health) = run(None)?;
    let (faulted, faulted_rejected, faulted_wall_s, health) =
        run(Some(plan.clone()))?;

    let mut violations: Vec<String> = Vec::new();
    if clean_rejected > 0 {
        violations
            .push(format!("clean run rejected {clean_rejected} submits"));
    }
    let mut clean_finished = 0usize;
    for (i, a) in clean.iter().enumerate() {
        match a {
            Some(a) if a.terminals == 1 && a.finished.is_some() => {
                clean_finished += 1;
            }
            Some(a) => violations.push(format!(
                "clean request {i}: {} terminals, finished={}",
                a.terminals,
                a.finished.is_some()
            )),
            None => {}
        }
    }
    let (mut finished, mut aborted) = (0usize, 0usize);
    for (i, a) in faulted.iter().enumerate() {
        let Some(a) = a else { continue };
        if a.terminals != 1 {
            violations.push(format!(
                "faulted request {i}: {} terminal events (contract: \
                 exactly one)",
                a.terminals
            ));
            continue;
        }
        match (&a.finished, &a.abort_reason) {
            (Some(resp), None) => {
                finished += 1;
                let twin = clean[i].as_ref().and_then(|c| c.finished.as_ref());
                match twin {
                    Some(c)
                        if c.text == resp.text
                            && c.gen_ids == resp.gen_ids => {}
                    Some(_) => violations.push(format!(
                        "faulted request {i}: response diverged from its \
                         clean twin (re-dispatch must replay \
                         byte-identically)"
                    )),
                    None => {}
                }
            }
            (None, Some(reason)) => {
                aborted += 1;
                if !reason.starts_with("shard_failure")
                    && !reason.starts_with("worker_lost")
                {
                    violations.push(format!(
                        "faulted request {i}: abort reason {reason:?} is \
                         not a supervision outcome"
                    ));
                }
            }
            _ => violations.push(format!(
                "faulted request {i}: malformed terminal audit"
            )),
        }
    }
    if plan.fired_count() == 0 {
        violations.push(format!(
            "fault plan {:?} never fired — the trace missed every trigger",
            plan.spec()
        ));
    }

    let sup = health.get("supervision").cloned().unwrap_or(Json::Null);
    let stat = |k: &str| sup.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "chaos: clean {clean_finished}/{n} finished in {clean_wall_s:.2}s; \
         faulted {finished} finished + {aborted} aborted \
         ({faulted_rejected} rejected) in {faulted_wall_s:.2}s"
    );
    println!(
        "recovery: {} panics, {} watchdog trips, {} re-dispatched, \
         {} aborted(shard_failure), {} restarts, max respawn {:.0} ms \
         [plan {} -> {}/{} fired]",
        stat("shard_panics"),
        stat("watchdog_trips"),
        stat("redispatched_requests"),
        stat("aborted_shard_failure"),
        stat("restarts"),
        stat("recovery_max_ms"),
        plan.spec(),
        plan.fired_count(),
        plan.point_count(),
    );
    for v in &violations {
        eprintln!("VIOLATION: {v}");
    }

    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.chaos/v1")),
        ("backend", Json::str(backend)),
        ("backbone", Json::str(backbone.as_str())),
        ("method", Json::str(method.name())),
        ("n", Json::num(n as f64)),
        ("distinct_prompts", Json::num(distinct as f64)),
        ("replicas", Json::num(replicas as f64)),
        ("arrival_ms", Json::num(arrival.as_millis() as f64)),
        ("max_batch", Json::num(max_batch as f64)),
        ("step_delay_ms", Json::num(step_delay.as_millis() as f64)),
        ("gen_len", Json::num(geom.gen_len as f64)),
        ("block_size", Json::num(geom.block_size as f64)),
        ("fault_spec", Json::str(plan.spec())),
        ("points_fired", Json::num(plan.fired_count() as f64)),
        ("clean_finished", Json::num(clean_finished as f64)),
        ("clean_wall_s", Json::num(clean_wall_s)),
        ("faulted_finished", Json::num(finished as f64)),
        ("faulted_aborted", Json::num(aborted as f64)),
        ("faulted_rejected", Json::num(faulted_rejected as f64)),
        ("faulted_wall_s", Json::num(faulted_wall_s)),
        ("supervision", sup),
        ("degraded", health.get("degraded").cloned().unwrap_or(Json::Null)),
        (
            "violations",
            Json::arr(violations.iter().map(|v| Json::str(v.as_str()))),
        ),
    ]);
    std::fs::write(&out_path, doc.to_string())?;
    println!("results -> {out_path}");
    anyhow::ensure!(
        violations.is_empty(),
        "chaos invariants violated:\n{}",
        violations.join("\n")
    );
    Ok(())
}

/// One serving-bench pass: staggered arrivals through a fresh router.
struct ServingRun {
    ttft: Summary,
    ttlt: Summary,
    wall_s: f64,
    health: Json,
}

fn run_serving_mode(
    continuous: bool,
    prompts: &[Vec<i32>],
    backbone: &str,
    method: Method,
    arrival: Duration,
    max_batch: usize,
) -> anyhow::Result<ServingRun> {
    let (responses, wall_s, health) = drive_trace(
        RouterConfig {
            max_batch,
            max_queue: prompts.len().max(256),
            continuous,
            ..RouterConfig::default()
        },
        prompts,
        backbone,
        method,
        arrival,
    )?;
    let mut ttft = Summary::new();
    let mut ttlt = Summary::new();
    for resp in &responses {
        ttft.push(resp.ttft.as_secs_f64() * 1e3);
        ttlt.push(resp.ttlt.as_secs_f64() * 1e3);
    }
    Ok(ServingRun { ttft, ttlt, wall_s, health })
}

/// Serving bench: the same staggered open-loop arrival trace against
/// the continuous-batching worker and the closed-batch baseline. The
/// headline number is mean TTFT — iteration-level scheduling admits a
/// request at the next block boundary instead of parking it behind a
/// batching window + the slowest lane of the previous group.
fn cmd_bench_serving(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 32);
    let arrival = Duration::from_millis(args.get_usize("arrival-ms", 3) as u64);
    let max_batch = args.get_usize("max-batch", 4);
    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_serving.json").to_string();
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;

    // encode the workload once; both modes see identical prompts
    let probe = ServingCore::load(&artifacts_dir(), 1)?;
    let geom = probe.rt.manifest.geometry.clone();
    let samples = workload::generate(Family::ChainArith, n, 0xE7A1);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &probe.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;
    let backend = probe.rt.backend_name();
    drop(probe);

    println!(
        "{:<14} {:>11} {:>11} {:>11} {:>11} {:>11} {:>9}",
        "mode", "ttft-p50", "ttft-p95", "ttft-mean", "ttlt-p50", "ttlt-p95",
        "wall(s)"
    );
    let mut modes = Vec::new();
    let mut means = Vec::new();
    for (label, continuous) in
        [("continuous", true), ("closed_batch", false)]
    {
        let run = run_serving_mode(
            continuous, &prompts, &backbone, method, arrival, max_batch,
        )?;
        println!(
            "{:<14} {:>11.2} {:>11.2} {:>11.2} {:>11.2} {:>11.2} {:>9.2}",
            label,
            run.ttft.percentile(50.0),
            run.ttft.percentile(95.0),
            run.ttft.mean(),
            run.ttlt.percentile(50.0),
            run.ttlt.percentile(95.0),
            run.wall_s
        );
        let stat = |k: &str| {
            run.health.get(k).and_then(Json::as_f64).unwrap_or(0.0)
        };
        means.push(run.ttft.mean());
        modes.push(Json::obj(vec![
            ("mode", Json::str(label)),
            ("requests", Json::num(run.ttft.count() as f64)),
            ("ttft_p50_ms", Json::num(run.ttft.percentile(50.0))),
            ("ttft_p95_ms", Json::num(run.ttft.percentile(95.0))),
            ("ttft_mean_ms", Json::num(run.ttft.mean())),
            ("ttlt_p50_ms", Json::num(run.ttlt.percentile(50.0))),
            ("ttlt_p95_ms", Json::num(run.ttlt.percentile(95.0))),
            ("ttlt_mean_ms", Json::num(run.ttlt.mean())),
            ("wall_s", Json::num(run.wall_s)),
            ("admissions", Json::num(stat("total_admissions"))),
            (
                "mid_flight_admissions",
                Json::num(stat("mid_flight_admissions")),
            ),
            ("retired_early", Json::num(stat("retired_early"))),
        ]));
    }
    let speedup = if means[0] > 0.0 { means[1] / means[0] } else { 1.0 };
    println!("mean TTFT speedup (closed/continuous): x{speedup:.2}");
    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.serving/v1")),
        ("backend", Json::str(backend)),
        ("backbone", Json::str(backbone.as_str())),
        ("method", Json::str(method.name())),
        ("n", Json::num(n as f64)),
        ("arrival_ms", Json::num(arrival.as_millis() as f64)),
        ("max_batch", Json::num(max_batch as f64)),
        ("gen_len", Json::num(geom.gen_len as f64)),
        ("block_size", Json::num(geom.block_size as f64)),
        ("ttft_mean_speedup", Json::num(speedup)),
        ("modes", Json::Arr(modes)),
    ]);
    std::fs::write(&out_path, doc.to_string())?;
    println!("results -> {out_path}");
    Ok(())
}

/// One prefix-bench pass: a repeated-prompt arrival trace through the
/// continuous router with the prefix cache on or off.
struct PrefixRun {
    ttft: Summary,
    wall_s: f64,
    total_model_calls: u64,
    health: Json,
}

fn run_prefix_mode(
    prefix_on: bool,
    prompts: &[Vec<i32>],
    backbone: &str,
    method: Method,
    arrival: Duration,
    max_batch: usize,
) -> anyhow::Result<PrefixRun> {
    let (responses, wall_s, health) = drive_trace(
        RouterConfig {
            max_batch,
            max_queue: prompts.len().max(256),
            continuous: true,
            prefix_cache: prefix_on,
            ..RouterConfig::default()
        },
        prompts,
        backbone,
        method,
        arrival,
    )?;
    let mut ttft = Summary::new();
    let mut total_model_calls = 0u64;
    for resp in &responses {
        ttft.push(resp.ttft.as_secs_f64() * 1e3);
        total_model_calls += resp.model_calls;
    }
    Ok(PrefixRun { ttft, wall_s, total_model_calls, health })
}

/// Shared-prefix bench: the same repeated-prompt open-loop arrival
/// trace (templated serving traffic: `--distinct` unique prompts
/// round-robined over `--n` arrivals) against the continuous router
/// with the prefix cache on vs off. Warm full-prompt hits skip their
/// admission prefill, so total model calls drop by exactly the hit
/// count while decoded traces stay byte-identical; TTFT is reported
/// unasserted alongside.
fn cmd_bench_prefix(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 24);
    let distinct = args.get_usize("distinct", 6).clamp(1, n.max(1));
    let arrival =
        Duration::from_millis(args.get_usize("arrival-ms", 2) as u64);
    let max_batch = args.get_usize("max-batch", 4);
    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_prefix.json").to_string();
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;

    let probe = ServingCore::load(&artifacts_dir(), 1)?;
    let geom = probe.rt.manifest.geometry.clone();
    let samples = workload::generate(Family::ChainArith, distinct, 0xE7A1);
    let base: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &probe.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;
    // round-robin repetition: every arrival after the first `distinct`
    // is a repeat of a prompt the cache has already seen
    let prompts: Vec<Vec<i32>> =
        (0..n).map(|i| base[i % distinct].clone()).collect();
    let backend = probe.rt.backend_name();
    drop(probe);

    println!(
        "{:<14} {:>11} {:>11} {:>12} {:>7} {:>11} {:>9}",
        "mode", "ttft-p50", "ttft-mean", "model-calls", "hits", "hit-blocks",
        "wall(s)"
    );
    let mut modes = Vec::new();
    let mut calls = Vec::new();
    let mut warm_hits = 0.0f64;
    for (label, prefix_on) in [("prefix_cache", true), ("cold", false)] {
        let run = run_prefix_mode(
            prefix_on, &prompts, &backbone, method, arrival, max_batch,
        )?;
        let stat = |k: &str| {
            run.health.get(k).and_then(Json::as_f64).unwrap_or(0.0)
        };
        if prefix_on {
            warm_hits = stat("prefix_hits");
        }
        println!(
            "{:<14} {:>11.2} {:>11.2} {:>12} {:>7} {:>11} {:>9.2}",
            label,
            run.ttft.percentile(50.0),
            run.ttft.mean(),
            run.total_model_calls,
            stat("prefix_hits") as u64,
            stat("prefix_hit_blocks") as u64,
            run.wall_s
        );
        calls.push(run.total_model_calls);
        modes.push(Json::obj(vec![
            ("mode", Json::str(label)),
            ("requests", Json::num(run.ttft.count() as f64)),
            ("ttft_p50_ms", Json::num(run.ttft.percentile(50.0))),
            ("ttft_p95_ms", Json::num(run.ttft.percentile(95.0))),
            ("ttft_mean_ms", Json::num(run.ttft.mean())),
            ("wall_s", Json::num(run.wall_s)),
            (
                "total_model_calls",
                Json::num(run.total_model_calls as f64),
            ),
            ("prefix_hits", Json::num(stat("prefix_hits"))),
            ("prefix_hit_blocks", Json::num(stat("prefix_hit_blocks"))),
            ("prefix_evictions", Json::num(stat("prefix_evictions"))),
            ("kv_shared_slots", Json::num(stat("kv_shared_slots"))),
        ]));
    }
    let saved = calls[1].saturating_sub(calls[0]);
    println!("prefill model calls saved by the prefix cache: {saved}");
    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.prefix/v1")),
        ("backend", Json::str(backend)),
        ("backbone", Json::str(backbone.as_str())),
        ("method", Json::str(method.name())),
        ("n", Json::num(n as f64)),
        ("distinct_prompts", Json::num(distinct as f64)),
        ("arrival_ms", Json::num(arrival.as_millis() as f64)),
        ("max_batch", Json::num(max_batch as f64)),
        ("gen_len", Json::num(geom.gen_len as f64)),
        ("block_size", Json::num(geom.block_size as f64)),
        ("prefill_calls_saved", Json::num(saved as f64)),
        ("warm_hits", Json::num(warm_hits)),
        ("modes", Json::Arr(modes)),
    ]);
    std::fs::write(&out_path, doc.to_string())?;
    println!("results -> {out_path}");
    Ok(())
}

/// What one streaming client observed: event timings plus — for
/// cancelled requests — the work the server reported wasted.
#[derive(Default)]
struct StreamProbe {
    ttfb_ms: Option<f64>,
    gaps_ms: Vec<f64>,
    finished: bool,
    aborted: bool,
    wasted_steps: u64,
    wasted_calls: u64,
    wasted_tokens: u64,
}

/// Drain one request's event pipeline, recording time-to-first-block
/// and inter-block gaps; with `cancel_after` set, cancel the request
/// after that many block deltas and capture the terminal abort's
/// wasted-work accounting.
fn consume_stream(
    handle: &cdlm::coordinator::ResponseHandle,
    submitted: Instant,
    cancel_after: Option<usize>,
) -> StreamProbe {
    use cdlm::coordinator::LaneEvent;
    let mut probe = StreamProbe::default();
    let mut deltas = 0usize;
    let mut last_delta: Option<Instant> = None;
    while let Some(ev) = handle.next_event() {
        match ev {
            LaneEvent::Admitted => {}
            LaneEvent::Committed { .. } => {
                let now = Instant::now();
                if probe.ttfb_ms.is_none() {
                    probe.ttfb_ms =
                        Some((now - submitted).as_secs_f64() * 1e3);
                }
                if let Some(prev) = last_delta {
                    probe.gaps_ms.push((now - prev).as_secs_f64() * 1e3);
                }
                last_delta = Some(now);
                deltas += 1;
                if cancel_after.is_some_and(|k| deltas >= k) {
                    handle.cancel();
                }
            }
            LaneEvent::Finished(_) => {
                probe.finished = true;
                break;
            }
            LaneEvent::Aborted {
                steps,
                model_calls,
                committed_tokens,
                ..
            } => {
                probe.aborted = true;
                probe.wasted_steps = steps;
                probe.wasted_calls = model_calls;
                probe.wasted_tokens = committed_tokens as u64;
                break;
            }
        }
    }
    probe
}

/// Streaming bench: an open-loop arrival trace of streaming clients
/// against the continuous router. Headline numbers are
/// **time-to-first-block** (submit -> first `Committed` event — what a
/// streaming user actually waits for, a block instead of the whole
/// response) and the **inter-block gap** percentiles; every
/// `--cancel-every`-th client cancels after `--cancel-after-blocks`
/// deltas, and the bench records how much work those cancelled lanes
/// wasted (the number end-to-end cancellation exists to keep small).
/// Schema `cdlm.bench.stream/v1`, run as a CI smoke with an artifact.
fn cmd_bench_stream(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 16);
    let arrival =
        Duration::from_millis(args.get_usize("arrival-ms", 2) as u64);
    let max_batch = args.get_usize("max-batch", 4);
    let cancel_every = args.get_usize("cancel-every", 4);
    let cancel_after = args.get_usize("cancel-after-blocks", 1);
    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_stream.json").to_string();
    let method = Method::from_name(args.get_or("method", "cdlm"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;

    let probe_core = ServingCore::load(&artifacts_dir(), 1)?;
    let geom = probe_core.rt.manifest.geometry.clone();
    let samples = workload::generate(Family::ChainArith, n, 0xE7A1);
    let prompts: Vec<Vec<i32>> = samples
        .iter()
        .map(|s| {
            workload::encode_example(
                &probe_core.tokenizer,
                Family::ChainArith,
                s,
                geom.prompt_len,
                geom.gen_len,
            )
            .map(|e| e.prompt_ids)
        })
        .collect::<anyhow::Result<_>>()?;
    let backend = probe_core.rt.backend_name();
    drop(probe_core);

    let router = Router::start(
        artifacts_dir(),
        RouterConfig {
            max_batch,
            max_queue: n.max(256),
            ..RouterConfig::default()
        },
    )?;
    let t0 = Instant::now();
    let mut consumers = Vec::with_capacity(n);
    for (i, p) in prompts.iter().enumerate() {
        let victim = cancel_every > 0 && (i + 1) % cancel_every == 0;
        let submitted = Instant::now();
        let handle = router.submit(GenerateRequest::new(
            backbone.as_str(),
            method,
            p.clone(),
        ))?;
        consumers.push(std::thread::spawn(move || {
            consume_stream(
                &handle,
                submitted,
                victim.then_some(cancel_after),
            )
        }));
        std::thread::sleep(arrival);
    }
    let probes: Vec<StreamProbe> = consumers
        .into_iter()
        .map(|c| c.join().expect("stream consumer panicked"))
        .collect();
    let wall_s = t0.elapsed().as_secs_f64();
    let health = router.health()?;
    router.shutdown();

    let mut ttfb = Summary::new();
    let mut gaps = Summary::new();
    let (mut completed, mut cancelled) = (0usize, 0usize);
    let mut wasted_tokens = Summary::new();
    let mut wasted_steps = Summary::new();
    let mut wasted_calls = Summary::new();
    for p in &probes {
        if let Some(t) = p.ttfb_ms {
            ttfb.push(t);
        }
        for &g in &p.gaps_ms {
            gaps.push(g);
        }
        completed += usize::from(p.finished);
        if p.aborted {
            cancelled += 1;
            wasted_tokens.push(p.wasted_tokens as f64);
            wasted_steps.push(p.wasted_steps as f64);
            wasted_calls.push(p.wasted_calls as f64);
        }
    }
    anyhow::ensure!(
        completed + cancelled == n,
        "every stream must end in exactly one terminal event \
         ({completed} finished + {cancelled} aborted != {n})"
    );
    let stat = |k: &str| health.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "streamed {n} requests: ttfb p50 {:.2} ms / p95 {:.2} ms, \
         inter-block gap p50 {:.2} ms / p95 {:.2} ms",
        ttfb.percentile(50.0),
        ttfb.percentile(95.0),
        gaps.percentile(50.0),
        gaps.percentile(95.0),
    );
    println!(
        "cancelled {cancelled} (every {cancel_every}th after \
         {cancel_after} block(s)): mean wasted tokens {:.1}, steps {:.1}, \
         model calls {:.1}",
        wasted_tokens.mean(),
        wasted_steps.mean(),
        wasted_calls.mean(),
    );
    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.stream/v1")),
        ("backend", Json::str(backend)),
        ("backbone", Json::str(backbone.as_str())),
        ("method", Json::str(method.name())),
        ("n", Json::num(n as f64)),
        ("arrival_ms", Json::num(arrival.as_millis() as f64)),
        ("max_batch", Json::num(max_batch as f64)),
        ("gen_len", Json::num(geom.gen_len as f64)),
        ("block_size", Json::num(geom.block_size as f64)),
        ("cancel_every", Json::num(cancel_every as f64)),
        ("cancel_after_blocks", Json::num(cancel_after as f64)),
        ("completed", Json::num(completed as f64)),
        ("cancelled", Json::num(cancelled as f64)),
        ("ttfb_p50_ms", Json::num(ttfb.percentile(50.0))),
        ("ttfb_p95_ms", Json::num(ttfb.percentile(95.0))),
        ("ttfb_mean_ms", Json::num(ttfb.mean())),
        ("gap_p50_ms", Json::num(gaps.percentile(50.0))),
        ("gap_p95_ms", Json::num(gaps.percentile(95.0))),
        ("wasted_tokens_per_cancel", Json::num(wasted_tokens.mean())),
        ("wasted_steps_per_cancel", Json::num(wasted_steps.mean())),
        (
            "wasted_model_calls_per_cancel",
            Json::num(wasted_calls.mean()),
        ),
        ("aborted_inflight", Json::num(stat("aborted_inflight"))),
        ("aborted_queued", Json::num(stat("aborted_queued"))),
        ("wall_s", Json::num(wall_s)),
    ]);
    std::fs::write(&out_path, doc.to_string())?;
    println!("results -> {out_path}");
    Ok(())
}

/// Steady-state decode-step microbench (`--scenario hotpath`): drives
/// each method's block-step-machine policy functions directly through
/// `cdlm::hotpath`, measuring gated ns/step + tokens/s and counting
/// heap acquisitions inside the gated windows with this binary's
/// counting allocator. Emits `BENCH_hotpath.json` (schema
/// `cdlm.bench.hotpath/v2`: the v1 per-method rows plus per-kernel
/// GB/s cells and the selected `util::kernels` ISA path), writing the
/// artifact *before* gating so a violation still leaves the evidence
/// on disk, then hard-fails unless every steady-state cell performed
/// zero allocations. Latency and throughput fields are advisory trend
/// data — only the allocation count gates.
fn cmd_bench_hotpath(args: &Args) -> anyhow::Result<()> {
    use analysis::intensity::{IntensityModel, Workload};
    use analysis::roofline::A100;
    use cdlm::hotpath;
    use cdlm::runtime::{ModelWeights, Programs};
    use cdlm::util::alloc_count;

    anyhow::ensure!(
        alloc_count::counting_enabled(),
        "counting allocator is not installed in this binary; the \
         allocation gate would read zero vacuously"
    );

    let backbone = args.get_or("backbone", "dream").to_string();
    let out_path = args.get_or("out", "BENCH_hotpath.json").to_string();
    let repeats = args.get_usize("repeats", 6).max(2);
    let tau = args.get_f64("tau", 0.9) as f32;
    let methods: Vec<Method> = match args.get("methods") {
        None | Some("all") => ALL_METHODS.to_vec(),
        Some(s) => s.split(',').filter_map(Method::from_name).collect(),
    };
    anyhow::ensure!(!methods.is_empty(), "no valid methods selected");
    let batches: Vec<usize> = args
        .get("batches")
        .map(|s| {
            s.split(',')
                .filter_map(|x| x.parse().ok())
                .filter(|&b| b > 0)
                .collect()
        })
        // 1 and 4 are both exported buckets: the gate covers the
        // single-lane and padded-cohort shapes without chunk splitting
        .unwrap_or_else(|| vec![1, 4]);
    anyhow::ensure!(!batches.is_empty(), "no valid batch sizes selected");
    let max_bs = *batches.iter().max().expect("batches nonempty");

    let core = ServingCore::load(&artifacts_dir(), max_bs.max(4))?;
    let geom = core.rt.manifest.geometry.clone();
    let mut buckets = core.rt.manifest.buckets.clone();
    buckets.sort_unstable();

    // analytic context: the decode schedule's FLOPs/bytes per step in
    // the §5.4 intensity model, evaluated at the reference geometry
    let model = IntensityModel::new(
        hotpath::reference_arch(&geom),
        Workload { prompt_len: geom.prompt_len, gen_len: geom.gen_len },
    );

    println!(
        "{:<14} {:>6} {:>14} {:>14} {:>12} {:>12} {:>12}",
        "method", "batch", "ns/step p50", "ns/step p95", "tokens/s",
        "allocs", "model KB/st"
    );
    let mut rows = Vec::new();
    let mut violations: Vec<String> = Vec::new();
    for m in &methods {
        let weights =
            ModelWeights::load(&core.rt.manifest, &m.weights_for(&backbone))?;
        weights.upload(&core.rt)?;
        let progs = Programs::new(&core.rt, &weights);
        for &bs in &batches {
            let cell = hotpath::run_cell(
                &progs, &geom, &buckets, *m, bs, repeats, tau,
            )?;
            let mode = hotpath::decode_mode_for(*m, geom.block_size);
            let cost = model.step_cost(mode, bs);
            let point = A100.simulate(cost);
            println!(
                "{:<14} {:>6} {:>14.0} {:>14.0} {:>12.1} {:>12} {:>12.1}",
                m.name(),
                bs,
                cell.ns_per_step_p50,
                cell.ns_per_step_p95,
                cell.tokens_per_s,
                cell.steady_allocs,
                cost.bytes / 1e3,
            );
            if cell.steady_allocs > 0 {
                violations.push(format!(
                    "{} bs={}: {} heap allocations across {} steady-state \
                     steps (want 0)",
                    m.name(),
                    bs,
                    cell.steady_allocs,
                    cell.steps
                ));
            }
            rows.push(Json::obj(vec![
                ("method", Json::str(m.name())),
                ("batch", Json::num(bs as f64)),
                ("steady_repeats", Json::num(cell.steady_repeats as f64)),
                ("steps", Json::num(cell.steps as f64)),
                ("tokens", Json::num(cell.tokens as f64)),
                ("gated_s", Json::num(cell.gated_s)),
                ("ns_per_step_p50", Json::num(cell.ns_per_step_p50)),
                ("ns_per_step_p95", Json::num(cell.ns_per_step_p95)),
                ("tokens_per_s", Json::num(cell.tokens_per_s)),
                ("allocs_per_step", Json::num(cell.allocs_per_step())),
                ("steady_allocs", Json::num(cell.steady_allocs as f64)),
                ("warm_allocs", Json::num(cell.warm_allocs as f64)),
                (
                    "analytic",
                    Json::obj(vec![
                        ("mode", Json::str(mode.label())),
                        ("flops_per_step", Json::num(cost.flops)),
                        ("bytes_per_step", Json::num(cost.bytes)),
                        ("ai_flop_per_byte", Json::num(cost.ai())),
                        (
                            "a100_step_latency_s",
                            Json::num(point.step_latency_s),
                        ),
                        ("memory_bound", Json::Bool(point.memory_bound)),
                    ]),
                ),
            ]));
        }
    }

    // per-kernel throughput cells: the util::kernels primitives every
    // slab walk now funnels through, measured at the block/page/slot
    // size classes. Advisory trend data (GB/s per kernel per size).
    let isa = cdlm::util::kernels::active_isa().label();
    println!(
        "\n{:<12} {:>6} {:>8} {:>12} {:>10} {:>8}",
        "kernel", "class", "elems", "ns p50", "GB/s", "isa"
    );
    let mut kernel_rows = Vec::new();
    for c in hotpath::run_kernel_cells(&geom, repeats) {
        println!(
            "{:<12} {:>6} {:>8} {:>12.0} {:>10.2} {:>8}",
            c.kernel, c.size_class, c.elems, c.ns_p50, c.gbps, c.isa
        );
        kernel_rows.push(Json::obj(vec![
            ("kernel", Json::str(c.kernel)),
            ("size_class", Json::str(c.size_class)),
            ("elems", Json::num(c.elems as f64)),
            ("bytes_per_call", Json::num(c.bytes_per_call as f64)),
            ("ns_p50", Json::num(c.ns_p50)),
            ("gbps", Json::num(c.gbps)),
            ("isa", Json::str(c.isa)),
        ]));
    }

    let doc = Json::obj(vec![
        ("schema", Json::str("cdlm.bench.hotpath/v2")),
        ("isa", Json::str(isa)),
        ("backend", Json::str(core.rt.backend_name())),
        ("backbone", Json::str(backbone.as_str())),
        ("tau", Json::num(tau as f64)),
        ("repeats", Json::num(repeats as f64)),
        (
            "geometry",
            Json::obj(vec![
                ("prompt_len", Json::num(geom.prompt_len as f64)),
                ("gen_len", Json::num(geom.gen_len as f64)),
                ("block_size", Json::num(geom.block_size as f64)),
            ]),
        ),
        (
            "alloc_gate",
            Json::str(
                "steady-state gated windows must perform 0 heap \
                 allocations; latency fields are advisory trend data",
            ),
        ),
        (
            "roofline",
            Json::obj(vec![
                ("device", Json::str("A100-SXM4-80GB")),
                ("ridge_flop_per_byte", Json::num(A100.ridge())),
                ("peak_tflops", Json::num(A100.peak_flops / 1e12)),
                ("bandwidth_gbps", Json::num(A100.bandwidth / 1e9)),
            ]),
        ),
        ("results", Json::Arr(rows)),
        ("kernels", Json::Arr(kernel_rows)),
    ]);
    // artifact first, gate second: a violation must still leave the
    // measurement on disk for the CI upload (chaos-gate convention)
    std::fs::write(&out_path, doc.to_string())?;
    println!("wrote {out_path}");
    anyhow::ensure!(
        violations.is_empty(),
        "hotpath allocation gate failed:\n  {}",
        violations.join("\n  ")
    );
    println!(
        "hotpath gate: all steady-state decode steps allocation-free \
         ({} cells)",
        methods.len() * batches.len()
    );
    Ok(())
}

fn cmd_analysis(args: &Args) -> anyhow::Result<()> {
    use analysis::intensity::{
        ArchConfig, DecodeMode, IntensityModel, Workload, PAPER_BATCH_SIZES,
    };
    use analysis::roofline::A100;
    let fig = args.get_usize("fig", 4);
    let ar = IntensityModel::new(ArchConfig::llama31_8b(), Workload::paper());
    let dlm = IntensityModel::new(ArchConfig::llada_8b(), Workload::paper());
    let modes = [
        ("AR (LLaMA-3.1-8B)", &ar, DecodeMode::Ar),
        ("Vanilla DLM (LLaDA-8B)", &dlm, DecodeMode::VanillaDlm),
        ("Block DLM B=4", &dlm, DecodeMode::BlockDlm { block: 4 }),
        ("Block DLM B=16", &dlm, DecodeMode::BlockDlm { block: 16 }),
        ("Block DLM B=32", &dlm, DecodeMode::BlockDlm { block: 32 }),
    ];
    if fig == 4 {
        println!("Arithmetic intensity vs batch size (ridge {:.1} FLOP/B)",
                 A100.ridge());
        print!("{:<24}", "mode");
        for bs in PAPER_BATCH_SIZES {
            print!("{bs:>9}");
        }
        println!();
        for (name, m, mode) in modes {
            print!("{name:<24}");
            for bs in PAPER_BATCH_SIZES {
                print!("{:>9.1}", m.ai(mode, bs));
            }
            println!();
        }
    } else {
        println!(
            "Roofline (A100: peak {:.1} TF/s, bw {:.0} GB/s, ridge {:.1})",
            A100.peak_flops / 1e12,
            A100.bandwidth / 1e9,
            A100.ridge()
        );
        for (name, m, mode) in modes {
            print!("{name:<24}");
            for bs in PAPER_BATCH_SIZES {
                let p = A100.simulate_mode(m, mode, bs);
                print!("{:>9.1}", p.attainable_tflops);
            }
            println!();
        }
    }
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let dir = artifacts_dir();
    let core = ServingCore::load(&dir, 1)?;
    let m = &core.rt.manifest;
    let g = &m.geometry;
    println!("artifacts:   {}", dir.display());
    println!("backend:     {}", core.rt.backend_name());
    println!("platform:    {}", core.rt.platform());
    println!(
        "geometry:    d={} L={} H={} P={} Lg={} B={} V={}",
        g.d_model, g.n_layers, g.n_heads, g.prompt_len, g.gen_len,
        g.block_size, g.vocab_size
    );
    println!("programs:    {}", m.programs.len());
    println!("buckets:     {:?}  sweep blocks: {:?}", m.buckets, m.sweep_blocks);
    println!("fast mode:   {}", m.fast_mode);
    println!("models:");
    for (k, v) in &m.models {
        println!("  {k:<16} {v}");
    }
    Ok(())
}
