//! Hot-path measurement driver: `cdlm bench --scenario hotpath`.
//!
//! Drives each method's block-step-machine policy functions (the same
//! `machine_prefill` / `machine_step` / `machine_commit` calls
//! [`BatchState::step_cycle`] dispatches) directly, with the gated
//! region wrapped in a wall-clock + allocation-counter window:
//!
//! * **gated** — the policy-function calls themselves: every program
//!   execution, KV view construction, slab write, and finalization
//!   scan. This is the steady-state decode step, and once the shared
//!   [`StepScratch`] arena is warm it must perform **zero** heap
//!   allocations (the bench hard-fails otherwise).
//! * **outside the gate** — per-block cohort assembly (`Vec`s of lane
//!   borrows, the continuing-lane item list) and per-repeat sequence
//!   construction. The machine pays the same per-block bookkeeping;
//!   it is O(lanes) pointer pushes per *block*, not per step, and is
//!   deliberately excluded so the gate pins the per-step contract.
//!
//! Repeat 0 of every cell warms the arena (first-shape `reuse` calls
//! size the buffers) and is excluded from all reported numbers; repeats
//! >= 1 are the steady state. Reported per-step latency divides the
//! gated wall time by the §A.3 refinement-step count, so cells are
//! comparable to `BENCH_decode.json` accounting.
//!
//! The allocation counter only counts when the driving binary installs
//! [`CountingAlloc`](crate::util::alloc_count::CountingAlloc); callers
//! gate on [`alloc_count::counting_enabled`] first.
//!
//! [`BatchState::step_cycle`]: crate::coordinator::methods::machine::BatchState::step_cycle

use std::time::Instant;

use anyhow::Result;

use crate::analysis::intensity::{ArchConfig, DecodeMode};
use crate::coordinator::kv_cache::{KvLease, KvPool};
use crate::coordinator::methods::{
    ar, bidirectional, cached_teacher, cdlm, DecodeOpts, Method, StepScratch,
};
use crate::coordinator::sequence::SequenceState;
use crate::runtime::{Geometry, Programs};
use crate::util::alloc_count;
use crate::util::kernels;
use crate::util::stats::{self, Summary};

/// One measured (method, batch) cell. All perf fields cover steady
/// repeats only (repeat 0 warms the arena); `warm_allocs` records what
/// arena sizing cost so the artifact shows the one-time price too.
#[derive(Debug, Clone)]
pub struct HotpathCell {
    pub method: Method,
    pub batch: usize,
    /// Measured repeats (total repeats minus the warm-up).
    pub steady_repeats: usize,
    /// §A.3 refinement steps summed over steady repeats.
    pub steps: u64,
    /// §A.3 generated tokens (pre-`<eos>`) summed over steady repeats.
    pub tokens: u64,
    /// Wall seconds inside the gated windows, steady repeats.
    pub gated_s: f64,
    /// Per-repeat (gated ns / steps), 50th / 95th percentile.
    pub ns_per_step_p50: f64,
    pub ns_per_step_p95: f64,
    pub tokens_per_s: f64,
    /// Heap acquisitions inside the gated windows on steady repeats —
    /// the hard-gated quantity (must be 0).
    pub steady_allocs: u64,
    /// Heap acquisitions inside the gated windows on repeat 0.
    pub warm_allocs: u64,
}

impl HotpathCell {
    pub fn allocs_per_step(&self) -> f64 {
        self.steady_allocs as f64 / self.steps.max(1) as f64
    }
}

/// One per-kernel throughput cell for the cdlm.bench.hotpath/v2
/// artifact: a fixed geometry-derived input measured over repeated
/// calls of one `util::kernels` primitive. `bytes_per_call` counts the
/// bytes the kernel logically moves (reads + writes), `ns_p50` is the
/// median per-call wall time, and `gbps` is the derived throughput —
/// the advisory trend number SIMD wins show up in PR-over-PR.
#[derive(Debug, Clone)]
pub struct KernelCell {
    pub kernel: &'static str,
    /// Input size class: a generated-block region (`block`), a prompt
    /// page (`page`), or a full lane slot (`slot`).
    pub size_class: &'static str,
    /// f32 elements in the cell's working set.
    pub elems: usize,
    /// Bytes logically moved per call (reads + writes).
    pub bytes_per_call: u64,
    pub ns_p50: f64,
    pub gbps: f64,
    /// ISA path the dispatched call executed on.
    pub isa: &'static str,
}

fn kernel_cell(
    kernel: &'static str,
    size_class: &'static str,
    elems: usize,
    bytes_per_call: u64,
    st: &Summary,
    isa: &'static str,
) -> KernelCell {
    let ns_p50 = st.percentile(50.0) * 1e9;
    KernelCell {
        kernel,
        size_class,
        elems,
        bytes_per_call,
        ns_p50,
        // bytes per nanosecond == decimal GB/s
        gbps: bytes_per_call as f64 / ns_p50.max(1e-3),
        isa,
    }
}

/// Measure every `util::kernels` primitive at the three slab-walk size
/// classes the KV hot path actually moves: one generated-block region
/// (`[L, H, B, dh]`), one prompt page (`[L, H, P, dh]`), and one full
/// lane slot (`[L, H, S, dh]`). All buffers are allocated up front, so
/// the measured calls are allocation-free like their hot-path call
/// sites; `repeats` scales the per-cell iteration count.
pub fn run_kernel_cells(geom: &Geometry, repeats: usize) -> Vec<KernelCell> {
    let isa = kernels::active_isa().label();
    let (l_n, h_n, dh, s_n) =
        (geom.n_layers, geom.n_heads, geom.d_head, geom.seq_len);
    let classes: [(&'static str, usize); 3] = [
        ("block", geom.block_size),
        ("page", geom.prompt_len),
        ("slot", geom.seq_len),
    ];
    let warm = 8;
    let iters = repeats.max(2) * 32;
    let mut cells = Vec::new();
    for (class, len) in classes {
        let n = l_n * h_n * len * dh;
        let row = h_n * len * dh;
        let src: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.5).collect();
        let mut dst = vec![0.0f32; n];

        let st = stats::bench(warm, iters, || {
            kernels::copy(&mut dst, &src);
            std::hint::black_box(&dst);
        });
        cells.push(kernel_cell("copy", class, n, 8 * n as u64, &st, isa));

        let st = stats::bench(warm, iters, || {
            kernels::fill(&mut dst, 0.0);
            std::hint::black_box(&dst);
        });
        cells.push(kernel_cell("fill", class, n, 4 * n as u64, &st, isa));

        // the pjrt-seam widening shape: L*H rows of len*dh scattered
        // into an S-strided slot layout
        let run = len * dh;
        let mut slot = vec![0.0f32; l_n * h_n * s_n * dh];
        let st = stats::bench(warm, iters, || {
            kernels::copy_2d(
                &mut slot,
                0,
                s_n * dh,
                &src,
                0,
                run,
                l_n * h_n,
                run,
            );
            std::hint::black_box(&slot);
        });
        cells.push(kernel_cell("copy_2d", class, n, 8 * n as u64, &st, isa));

        // the replicate_ctx shape: one lane's layer-0 row fanned across
        // all layers of both slabs (bs=1, so lstride == row)
        let mut kf = src.clone();
        let mut vf = vec![0.0f32; n];
        let st = stats::bench(warm, iters, || {
            kernels::fanout_rows(&mut kf, &mut vf, 0, row, l_n, row);
            std::hint::black_box((&kf, &vf));
        });
        let fan_bytes = (8 * l_n * row) as u64;
        cells.push(kernel_cell("fanout_rows", class, n, fan_bytes, &st, isa));

        // cold-tier widening scatter/gather (suspend/resume spills)
        let mut bytes = Vec::with_capacity(4 * n);
        let st = stats::bench(warm, iters, || {
            bytes.clear();
            kernels::spill_f32_le(&mut bytes, &src);
            std::hint::black_box(&bytes);
        });
        cells.push(kernel_cell("spill", class, n, 8 * n as u64, &st, isa));
        let st = stats::bench(warm, iters, || {
            kernels::unspill_f32_le(&bytes, &mut dst);
            std::hint::black_box(&dst);
        });
        cells.push(kernel_cell("unspill", class, n, 8 * n as u64, &st, isa));
    }
    cells
}

/// Deterministic full-length synthetic prompt (no padding, all ids in
/// the reference token range), varied per lane so batched lanes do not
/// collapse into identical traces.
pub fn synth_prompt(geom: &Geometry, lane: usize) -> Vec<i32> {
    (0..geom.prompt_len)
        .map(|i| 4 + ((lane * 31 + i * 7) % 50) as i32)
        .collect()
}

/// Map a decode method onto the §5.4 arithmetic-intensity mode used for
/// the analytic context attached to each bench cell. `dllm-cache`
/// approximates to block mode (its steady step recomputes one block;
/// periodic full refreshes push its true traffic toward vanilla).
pub fn decode_mode_for(method: Method, block: usize) -> DecodeMode {
    match method {
        Method::Ar => DecodeMode::Ar,
        Method::Vanilla | Method::FastDllmPar => DecodeMode::VanillaDlm,
        Method::DllmCache | Method::FastDllmDc | Method::Cdlm => {
            DecodeMode::BlockDlm { block }
        }
    }
}

/// The reference geometry viewed as a transformer [`ArchConfig`] so the
/// intensity model can attach analytic FLOPs/bytes-per-step to each
/// cell. The reference backend is a hash-chain mock, not a transformer
/// — these numbers contextualize the measured ns/step against what the
/// same decode schedule would move on real hardware; they are a model,
/// not a measurement. MHA (`n_kv_heads = n_heads`) and a classic
/// two-matrix MLP are the assumptions.
pub fn reference_arch(geom: &Geometry) -> ArchConfig {
    ArchConfig {
        name: "reference",
        n_layers: geom.n_layers,
        d_model: geom.d_model,
        n_q_heads: geom.n_heads,
        n_kv_heads: geom.n_heads,
        d_head: geom.d_head,
        d_ff: geom.d_ff,
        vocab: geom.vocab_size,
        mlp_mats: 2,
    }
}

/// Smallest exported bucket covering `n` lanes (callers pass sorted
/// buckets; past the largest bucket the raw count is used, matching
/// the machine's cohort padding).
fn pad_of(buckets: &[usize], n: usize) -> usize {
    buckets.iter().copied().find(|&b| b >= n).unwrap_or(n)
}

/// Accumulated gated window: wall ns + thread-local heap acquisitions
/// across every `run` call.
struct Gate {
    ns: u64,
    allocs: u64,
}

impl Gate {
    fn new() -> Self {
        Gate { ns: 0, allocs: 0 }
    }

    /// Run `f` inside the window. `Instant` reads and the counter reads
    /// do not allocate, so the window measures exactly `f`.
    fn run<T>(&mut self, f: impl FnOnce() -> Result<T>) -> Result<T> {
        let a0 = alloc_count::thread_allocs();
        let t0 = Instant::now();
        let out = f();
        self.ns += t0.elapsed().as_nanos() as u64;
        self.allocs += alloc_count::thread_allocs().saturating_sub(a0);
        out
    }
}

/// Decode `prompts` once through `method`'s machine policy functions,
/// mirroring `step_cohort`'s drive pattern for a single cohort, gating
/// only the policy calls. Returns (§A.3 steps, §A.3 gen tokens).
#[allow(clippy::too_many_arguments)]
fn run_repeat(
    progs: &Programs,
    geom: &Geometry,
    method: Method,
    opts: &DecodeOpts,
    pool: &mut KvPool,
    prompts: &[Vec<i32>],
    taus: &[f32],
    buckets: &[usize],
    scratch: &mut StepScratch,
    gate: &mut Gate,
) -> Result<(u64, u64)> {
    let bs = prompts.len();
    let (g_len, blk) = (geom.gen_len, opts.block_size);
    let num_blocks = g_len / blk;
    let pad_to = pad_of(buckets, bs);
    let pre_pad = pad_of(buckets, 1);

    let mut seqs: Vec<SequenceState> =
        prompts.iter().map(|p| SequenceState::new(geom, p)).collect();

    match method {
        Method::Vanilla | Method::FastDllmPar => {
            let policy = if method == Method::Vanilla {
                bidirectional::Policy::TopM
            } else {
                bidirectional::Policy::Threshold
            };
            for b in 0..num_blocks {
                let lo = b * blk;
                let mut refs: Vec<&mut SequenceState> =
                    seqs.iter_mut().collect();
                gate.run(|| {
                    bidirectional::machine_step(
                        progs, geom, opts, policy, &mut refs, taus, lo, blk,
                        pad_to, scratch,
                    )
                })?;
            }
        }
        Method::DllmCache | Method::FastDllmDc => {
            let variant = if method == Method::DllmCache {
                cached_teacher::Variant::DllmCache
            } else {
                cached_teacher::Variant::DualCache
            };
            let leases: Vec<KvLease> =
                (0..bs).map(|_| pool.alloc()).collect::<Result<_>>()?;
            // lease refs are assembled outside the gated windows (like
            // the cohort-assembly Vecs): O(lanes) pushes per repeat
            let lrefs: Vec<&KvLease> = leases.iter().collect();
            let mut ssr = usize::MAX; // force a refresh on the first pass
            for b in 0..num_blocks {
                let lo = b * blk;
                let mut refs: Vec<&mut SequenceState> =
                    seqs.iter_mut().collect();
                ssr = gate.run(|| {
                    cached_teacher::machine_step(
                        progs, geom, opts, variant, pool, &mut refs, taus,
                        &lrefs, ssr, lo, blk, pad_to, scratch,
                    )
                })?;
            }
            drop(lrefs);
            for lease in leases {
                pool.release(lease);
            }
        }
        Method::Cdlm => {
            let mut leases: Vec<KvLease> = Vec::with_capacity(bs);
            for seq in seqs.iter_mut() {
                leases.push(cdlm::machine_prefill(
                    progs, pool, seq, pre_pad, None, scratch,
                )?);
            }
            // lease refs are assembled outside the gated windows (like
            // the cohort-assembly Vecs): O(lanes) pushes per repeat
            let lrefs: Vec<&KvLease> = leases.iter().collect();
            for b in 0..num_blocks {
                let lo = b * blk;
                if seqs.iter().all(|s| s.done) {
                    break;
                }
                {
                    let mut refs: Vec<&mut SequenceState> =
                        seqs.iter_mut().collect();
                    gate.run(|| {
                        cdlm::machine_step(
                            progs, geom, pool, &mut refs, taus, &lrefs, lo,
                            blk, pad_to, scratch,
                        )
                    })?;
                }
                // commit only for lanes continuing past the boundary,
                // re-padded to the continuing-lane bucket (machine
                // semantics)
                if b + 1 < num_blocks {
                    let mut cseqs: Vec<&mut SequenceState> =
                        Vec::with_capacity(bs);
                    let mut cleases: Vec<&KvLease> = Vec::with_capacity(bs);
                    for (s, l) in seqs.iter_mut().zip(lrefs.iter()) {
                        if !s.done {
                            cseqs.push(s);
                            cleases.push(l);
                        }
                    }
                    if !cseqs.is_empty() {
                        let cpad = pad_of(buckets, cseqs.len());
                        gate.run(|| {
                            cdlm::machine_commit(
                                progs, geom, pool, &mut cseqs, &cleases, lo,
                                blk, cpad, scratch,
                            )
                        })?;
                    }
                }
            }
            drop(lrefs);
            for lease in leases {
                pool.release(lease);
            }
        }
        Method::Ar => {
            let mut leases: Vec<KvLease> = Vec::with_capacity(bs);
            let mut cur = vec![0i32; bs];
            for (r, seq) in seqs.iter_mut().enumerate() {
                let (lease, tok) = ar::machine_prefill(
                    progs, pool, seq, pre_pad, None, scratch,
                )?;
                leases.push(lease);
                cur[r] = tok;
            }
            // lease refs are assembled outside the gated windows (like
            // the cohort-assembly Vecs): O(lanes) pushes per repeat
            let lrefs: Vec<&KvLease> = leases.iter().collect();
            let mut pos = 0usize;
            while pos < g_len {
                if seqs.iter().all(|s| s.done) {
                    break;
                }
                let mut refs: Vec<&mut SequenceState> =
                    seqs.iter_mut().collect();
                gate.run(|| {
                    ar::machine_step(
                        progs, geom, pool, &mut refs, &mut cur, &lrefs, pos,
                        blk, pad_to, scratch,
                    )
                })?;
                pos += blk;
            }
            drop(lrefs);
            for lease in leases {
                pool.release(lease);
            }
        }
    }

    let (mut steps, mut tokens) = (0u64, 0u64);
    for s in seqs {
        let o = s.into_outcome();
        steps += o.steps;
        tokens += o.gen_len as u64;
    }
    Ok((steps, tokens))
}

/// Measure one (method, batch) cell: `repeats` full decodes sharing one
/// [`StepScratch`] and one [`KvPool`], repeat 0 excluded as warm-up.
/// The same synthetic prompts decode every repeat, so steady repeats
/// are trace-identical and per-repeat ns/step is a clean latency
/// sample.
pub fn run_cell(
    progs: &Programs,
    geom: &Geometry,
    buckets: &[usize],
    method: Method,
    batch: usize,
    repeats: usize,
    tau: f32,
) -> Result<HotpathCell> {
    anyhow::ensure!(batch >= 1, "batch must be >= 1");
    anyhow::ensure!(
        repeats >= 2,
        "need >= 2 repeats: repeat 0 only warms the arena"
    );
    let mut opts = DecodeOpts::defaults(geom);
    opts.tau_conf = tau;
    anyhow::ensure!(
        geom.gen_len % opts.block_size == 0,
        "block size must divide gen_len"
    );

    let prompts: Vec<Vec<i32>> =
        (0..batch).map(|lane| synth_prompt(geom, lane)).collect();
    let taus = vec![tau; batch];
    let mut pool = KvPool::new(
        geom,
        if method.uses_kv_cache() { batch } else { 0 },
    );
    let mut scratch = StepScratch::new();

    let mut samples = Summary::new();
    let (mut steps, mut tokens, mut gated_ns) = (0u64, 0u64, 0u64);
    let (mut steady_allocs, mut warm_allocs) = (0u64, 0u64);
    for rep in 0..repeats {
        let mut gate = Gate::new();
        let (s, t) = run_repeat(
            progs, geom, method, &opts, &mut pool, &prompts, &taus, buckets,
            &mut scratch, &mut gate,
        )?;
        if rep == 0 {
            warm_allocs = gate.allocs;
            continue;
        }
        steps += s;
        tokens += t;
        gated_ns += gate.ns;
        steady_allocs += gate.allocs;
        samples.push(gate.ns as f64 / s.max(1) as f64);
    }
    let gated_s = gated_ns as f64 / 1e9;
    Ok(HotpathCell {
        method,
        batch,
        steady_repeats: repeats - 1,
        steps,
        tokens,
        gated_s,
        ns_per_step_p50: samples.percentile(50.0),
        ns_per_step_p95: samples.percentile(95.0),
        tokens_per_s: tokens as f64 / gated_s.max(1e-12),
        steady_allocs,
        warm_allocs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::methods::ALL_METHODS;
    use crate::runtime::{ModelWeights, Programs, Runtime};

    // NOTE: the library test binary does not install the counting
    // allocator, so steady_allocs reads 0 here regardless of behavior;
    // tests/hot_path.rs (which installs it) owns the allocation
    // assertions. These tests pin the driver itself.

    fn sorted_buckets(rt: &Runtime) -> Vec<usize> {
        let mut b = rt.manifest.buckets.clone();
        b.sort_unstable();
        b
    }

    #[test]
    fn every_method_completes_and_accounts() {
        let rt = Runtime::reference(0x5EED_0042);
        let geom = rt.manifest.geometry.clone();
        let buckets = sorted_buckets(&rt);
        for m in ALL_METHODS {
            let weights =
                ModelWeights::load(&rt.manifest, &m.weights_for("dream"))
                    .expect("weights");
            let progs = Programs::new(&rt, &weights);
            let cell = run_cell(&progs, &geom, &buckets, m, 2, 2, 0.9)
                .expect("cell");
            assert!(cell.steps > 0, "{}: no steps recorded", m.name());
            assert!(cell.tokens > 0, "{}: no tokens recorded", m.name());
            assert!(cell.gated_s > 0.0, "{}: empty gated window", m.name());
            assert_eq!(cell.steady_repeats, 1);
        }
    }

    #[test]
    fn steady_repeats_are_trace_deterministic() {
        // fresh sequence state per repeat + deterministic backend =>
        // identical steps/tokens across cells and across repeats
        let rt = Runtime::reference(0x5EED_0042);
        let geom = rt.manifest.geometry.clone();
        let buckets = sorted_buckets(&rt);
        let m = Method::Cdlm;
        let weights =
            ModelWeights::load(&rt.manifest, &m.weights_for("dream"))
                .expect("weights");
        let progs = Programs::new(&rt, &weights);
        let c3 = run_cell(&progs, &geom, &buckets, m, 2, 4, 0.9).expect("c3");
        let c1 = run_cell(&progs, &geom, &buckets, m, 2, 2, 0.9).expect("c1");
        assert_eq!(c3.steps % c3.steady_repeats as u64, 0);
        assert_eq!(c3.steps / c3.steady_repeats as u64, c1.steps);
        assert_eq!(c3.tokens / c3.steady_repeats as u64, c1.tokens);
    }

    #[test]
    fn mode_mapping_matches_cache_columns() {
        assert_eq!(decode_mode_for(Method::Ar, 8), DecodeMode::Ar);
        assert_eq!(
            decode_mode_for(Method::Vanilla, 8),
            DecodeMode::VanillaDlm
        );
        assert_eq!(
            decode_mode_for(Method::FastDllmPar, 8),
            DecodeMode::VanillaDlm
        );
        for m in [Method::DllmCache, Method::FastDllmDc, Method::Cdlm] {
            assert_eq!(
                decode_mode_for(m, 8),
                DecodeMode::BlockDlm { block: 8 }
            );
        }
    }

    #[test]
    fn reference_arch_mirrors_geometry() {
        let rt = Runtime::reference(1);
        let g = rt.manifest.geometry.clone();
        let a = reference_arch(&g);
        assert_eq!(a.n_layers, g.n_layers);
        assert_eq!(a.n_q_heads, g.n_heads);
        assert_eq!(a.n_kv_heads, g.n_heads);
        assert_eq!(a.vocab, g.vocab_size);
        assert!(a.params() > 0.0);
    }

    #[test]
    fn kernel_cells_cover_all_primitives_and_sizes() {
        let rt = Runtime::reference(1);
        let g = rt.manifest.geometry.clone();
        let cells = run_kernel_cells(&g, 2);
        // 6 kernels x 3 size classes
        assert_eq!(cells.len(), 18);
        let isa = kernels::active_isa().label();
        for c in &cells {
            assert!(c.elems > 0 && c.bytes_per_call > 0, "{}", c.kernel);
            assert!(c.gbps > 0.0, "{}: empty throughput", c.kernel);
            assert_eq!(c.isa, isa, "{}: wrong ISA label", c.kernel);
        }
        for class in ["block", "page", "slot"] {
            assert_eq!(
                cells.iter().filter(|c| c.size_class == class).count(),
                6,
                "{class}: missing kernels"
            );
        }
    }

    #[test]
    fn synth_prompts_are_full_length_valid_ids() {
        let rt = Runtime::reference(1);
        let g = rt.manifest.geometry.clone();
        for lane in 0..4 {
            let p = synth_prompt(&g, lane);
            assert_eq!(p.len(), g.prompt_len);
            assert!(p.iter().all(|&t| t >= 4 && (t as usize) < g.vocab_size));
        }
        assert_ne!(synth_prompt(&g, 0), synth_prompt(&g, 1));
    }
}
