//! HLO artifact smoke test: load one exported `student_block_step`
//! program plus its weights npz, execute it through PJRT, and compare
//! logits against the python-exported expectation.
//!
//! Only meaningful with the `pjrt` feature and an artifacts directory;
//! in every other configuration it prints why and exits 0 so CI can
//! invoke it unconditionally.

#[cfg(not(feature = "pjrt"))]
fn main() {
    eprintln!(
        "hlo_smoke: built without the `pjrt` feature — no PJRT runtime to \
         smoke-test; skipping (ok)"
    );
}

#[cfg(feature = "pjrt")]
fn main() -> anyhow::Result<()> {
    use xla::FromRawBytes;

    let dir = cdlm::artifacts_dir().join("smoke");
    let hlo = dir.join("sbs_test.hlo.txt");
    let npz = dir.join("sbs_weights.npz");
    let expected_npy = dir.join("sbs_expected_logits.npy");
    if !hlo.exists() || !npz.exists() {
        eprintln!(
            "hlo_smoke: no smoke artifacts under {} — run `make artifacts` \
             first; skipping (ok)",
            dir.display()
        );
        return Ok(());
    }

    let client = xla::PjRtClient::cpu()?;
    let proto =
        xla::HloModuleProto::from_text_file(hlo.to_str().expect("utf8 path"))?;
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
    let mut weights = xla::Literal::read_npz(&npz, &())?;
    weights.sort_by(|a, b| a.0.cmp(&b.0));

    let g = cdlm::runtime::Manifest::load_or_reference(&cdlm::artifacts_dir())?
        .geometry;
    let (l, bs, h, s, dh, b) =
        (g.n_layers, 2usize, g.n_heads, g.seq_len, g.d_head, g.block_size);
    let kc = xla::Literal::vec1(&vec![0f32; l * bs * h * s * dh]).reshape(&[
        l as i64, bs as i64, h as i64, s as i64, dh as i64,
    ])?;
    let vc = xla::Literal::vec1(&vec![0f32; l * bs * h * s * dh]).reshape(&[
        l as i64, bs as i64, h as i64, s as i64, dh as i64,
    ])?;
    let cl = xla::Literal::scalar(g.prompt_len as i32);
    let vf = xla::Literal::vec1(&[10i32, 0i32]);
    let blk = xla::Literal::vec1(&vec![1i32; bs * b])
        .reshape(&[bs as i64, b as i64])?;
    let pos0 = xla::Literal::scalar(g.prompt_len as i32);
    let mut args: Vec<&xla::Literal> = weights.iter().map(|(_, l)| l).collect();
    args.push(&kc);
    args.push(&vc);
    args.push(&cl);
    args.push(&vf);
    args.push(&blk);
    args.push(&pos0);

    let t0 = std::time::Instant::now();
    let res = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
    println!("exec time {:?}", t0.elapsed());
    let outs = res.to_tuple()?;
    println!("n outs {}", outs.len());
    let logits = outs[0].to_vec::<f32>()?;
    if expected_npy.exists() {
        let expected =
            xla::Literal::read_npy(&expected_npy, &())?.to_vec::<f32>()?;
        let max_err = logits
            .iter()
            .zip(&expected)
            .map(|(a, e)| (a - e).abs())
            .fold(0f32, f32::max);
        println!("logits sum {} max_err {}", logits.iter().sum::<f32>(), max_err);
        anyhow::ensure!(max_err < 1e-4, "logits diverge from python export");
    }
    let t0 = std::time::Instant::now();
    for _ in 0..10 {
        exe.execute::<&xla::Literal>(&args)?;
    }
    println!("per-exec {:?}", t0.elapsed() / 10);
    println!("SMOKE OK");
    Ok(())
}
