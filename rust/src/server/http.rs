//! HTTP/1.1 API over std::net — one handler thread per connection.
//! Handlers never touch XLA state: they tokenize, submit to the router
//! (whose worker thread owns the PJRT runtime), and wait on a channel.
//!
//!   POST /generate   {"prompt": str, "backbone": str?, "method": str?,
//!                     "tau_conf": num?} -> text + §A.3 counters +
//!                     ttft_ms/ttlt_ms (queueing included)
//!   GET  /metrics    per-(backbone, method) §A.3 aggregates
//!   GET  /healthz    liveness + platform info + continuous-batching
//!                    state (in_flight_lanes, active_batches,
//!                    total/mid-flight admissions, retired_early)

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{GenerateRequest, Method, Router};
use crate::tokenizer::{Tokenizer, BOS, PAD};
use crate::util::json::Json;
use crate::workload;

pub struct ServerConfig {
    pub addr: String,
    pub default_backbone: String,
    /// Per-socket read/write timeout. The handler pool is 8 threads;
    /// without this, 8 idle or slow-loris connections pin the whole
    /// server — every blocking socket syscall must be able to give up.
    /// `Duration::ZERO` disables the timeouts (blocking sockets).
    pub io_timeout: Duration,
}

/// Request-size guards: a drip-feeding (slow-loris) client that stays
/// under the per-syscall io_timeout could otherwise stream one header
/// byte at a time forever. Together with the per-connection `budget`
/// deadline they bound how long any handler thread can be pinned.
const MAX_HEADERS: usize = 64;
const MAX_LINE_BYTES: usize = 8 * 1024;
const MAX_BODY_BYTES: usize = 1024 * 1024;

fn in_budget(deadline: &Option<std::time::Instant>) -> bool {
    match deadline {
        Some(d) => std::time::Instant::now() <= *d,
        None => true,
    }
}

/// Read one `\n`-terminated line, enforcing the length cap and the
/// wall-clock deadline *between underlying reads* — a client dripping
/// one byte per (sub-timeout) interval is cut off at the deadline
/// instead of stretching a single `read_line` indefinitely.
fn read_line_within(
    reader: &mut impl BufRead,
    deadline: &Option<std::time::Instant>,
    out: &mut String,
) -> Result<()> {
    loop {
        anyhow::ensure!(in_budget(deadline), "request read budget exceeded");
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            return Ok(()); // EOF: caller sees a short/empty line
        }
        let nl = buf.iter().position(|&b| b == b'\n');
        let take = nl.map(|i| i + 1).unwrap_or(buf.len());
        out.push_str(&String::from_utf8_lossy(&buf[..take]));
        reader.consume(take);
        anyhow::ensure!(out.len() <= MAX_LINE_BYTES, "line too long");
        if nl.is_some() {
            return Ok(());
        }
    }
}

/// Parse one HTTP request (method, path, body). `budget` is the total
/// wall-clock allowance for reading the request; the socket's own
/// read timeout bounds each syscall, this bounds their sum.
fn read_request(
    stream: &mut TcpStream,
    budget: Option<std::time::Duration>,
) -> Result<(String, String, String)> {
    let deadline = budget.map(|b| std::time::Instant::now() + b);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    read_line_within(&mut reader, &deadline, &mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    let mut headers = 0usize;
    loop {
        headers += 1;
        anyhow::ensure!(headers <= MAX_HEADERS, "too many headers");
        let mut h = String::new();
        read_line_within(&mut reader, &deadline, &mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:")
        {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    anyhow::ensure!(content_len <= MAX_BODY_BYTES, "body too large");
    let mut body = vec![0u8; content_len];
    let mut got = 0usize;
    while got < content_len {
        anyhow::ensure!(in_budget(&deadline), "request read budget exceeded");
        let n = reader.read(&mut body[got..])?;
        anyhow::ensure!(n > 0, "connection closed mid-body");
        got += n;
    }
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// Encode a user prompt to the fixed left-padded geometry.
pub fn encode_user_prompt(
    tok: &Tokenizer,
    prompt: &str,
    prompt_len: usize,
) -> Result<Vec<i32>> {
    let mut ids = vec![BOS];
    ids.extend(tok.encode(&format!("{prompt}a:"))?);
    anyhow::ensure!(ids.len() <= prompt_len, "prompt too long");
    let mut out = vec![PAD; prompt_len - ids.len()];
    out.extend(ids);
    Ok(out)
}

fn handle_generate(
    tok: &Tokenizer,
    router: &Router,
    default_backbone: &str,
    body: &str,
) -> (u16, String) {
    let req = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return (400, err_json(&format!("bad json: {e}"))),
    };
    let Some(prompt) = req.get("prompt").and_then(Json::as_str) else {
        return (400, err_json("missing 'prompt'"));
    };
    let backbone = req
        .get("backbone")
        .and_then(Json::as_str)
        .unwrap_or(default_backbone)
        .to_string();
    let method = match req.get("method").and_then(Json::as_str) {
        None => Method::Cdlm,
        Some(m) => match Method::from_name(m) {
            Some(m) => m,
            None => return (400, err_json(&format!("unknown method '{m}'"))),
        },
    };
    let prompt_ids =
        match encode_user_prompt(tok, prompt, router.geometry.prompt_len) {
            Ok(ids) => ids,
            Err(e) => return (400, err_json(&format!("{e:#}"))),
        };
    let tau_conf = req.get("tau_conf").and_then(Json::as_f64).map(|f| f as f32);
    let rx = match router.submit(GenerateRequest {
        backbone,
        method,
        prompt_ids,
        tau_conf,
    }) {
        Ok(rx) => rx,
        Err(e) => return (429, err_json(&format!("{e:#}"))),
    };
    match rx.recv() {
        Ok(Ok(resp)) => {
            let final_answer = workload::extract_final(&resp.text)
                .map(Json::str)
                .unwrap_or(Json::Null);
            let j = Json::obj(vec![
                ("text", Json::str(resp.text.clone())),
                ("final", final_answer),
                ("steps", Json::num(resp.steps as f64)),
                ("model_calls", Json::num(resp.model_calls as f64)),
                ("gen_len", Json::num(resp.gen_len as f64)),
                ("latency_ms", Json::num(resp.latency.as_secs_f64() * 1e3)),
                ("ttft_ms", Json::num(resp.ttft.as_secs_f64() * 1e3)),
                ("ttlt_ms", Json::num(resp.ttlt.as_secs_f64() * 1e3)),
                ("method", Json::str(method.name())),
            ]);
            (200, j.to_string())
        }
        Ok(Err(e)) => (500, err_json(&e)),
        Err(_) => (500, err_json("worker dropped the request")),
    }
}

/// Serve until the process is killed.
pub fn serve(router: Router, cfg: ServerConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[cdlm] serving on http://{}", listener.local_addr()?);
    serve_on(listener, router, cfg)
}

/// Serve on an already-bound listener (tests bind an ephemeral port
/// themselves and pass it in).
pub fn serve_on(
    listener: TcpListener,
    router: Router,
    cfg: ServerConfig,
) -> Result<()> {
    let router = Arc::new(router);
    // bounded connection-handler pool (decode concurrency is separately
    // bounded by the router worker + batcher)
    let pool = crate::util::threadpool::ThreadPool::new(8);
    let io_timeout = if cfg.io_timeout.is_zero() {
        None
    } else {
        Some(cfg.io_timeout)
    };
    for stream in listener.incoming() {
        let Ok(mut stream) = stream else { continue };
        // an unresponsive peer must release its handler thread: every
        // read/write syscall on the socket gives up after io_timeout
        // and the handler returns (read_request propagates the error)
        let _ = stream.set_read_timeout(io_timeout);
        let _ = stream.set_write_timeout(io_timeout);
        let router = router.clone();
        let backbone = cfg.default_backbone.clone();
        pool.execute(move || {
            let tok = Tokenizer::new();
            // the whole request must arrive within one io_timeout of
            // the handler starting — a drip-feed that beats every
            // per-syscall timeout still cannot hold the thread longer
            let (method, path, body) =
                match read_request(&mut stream, io_timeout) {
                    Ok(r) => r,
                    Err(_) => return,
                };
            let (status, body) = match (method.as_str(), path.as_str()) {
                ("POST", "/generate") => {
                    handle_generate(&tok, &router, &backbone, &body)
                }
                ("GET", "/metrics") => match router.metrics() {
                    Ok(j) => (200, j.to_string()),
                    Err(e) => (500, err_json(&format!("{e:#}"))),
                },
                ("GET", "/healthz") => match router.health() {
                    Ok(j) => (200, j.to_string()),
                    Err(e) => (500, err_json(&format!("{e:#}"))),
                },
                _ => (404, err_json("not found")),
            };
            respond(&mut stream, status, &body);
        });
    }
    Ok(())
}
