//! HTTP/1.1 API over std::net. The default front door is a
//! **nonblocking event loop**: one thread multiplexes every connection
//! (accept burst, incremental request parsing, `try_next_event` polling
//! of lane pipelines, buffered writes), so hundreds of concurrent
//! streaming clients cost file descriptors, not threads. The legacy
//! thread-per-connection pool (`ServerConfig::blocking`, pool size
//! `http_threads`) is kept for comparison and as a fallback. Handlers
//! never touch XLA state: they tokenize, submit to the router (whose
//! shard workers own the runtime), and relay lane events.
//!
//!   POST /v1/generate {"prompt": str, "backbone": str?, "method": str?,
//!                     "tau_conf": num?, "timeout_ms": num?,
//!                     "max_new_tokens": num?, "stream": bool?,
//!                     "client_id": str?, "priority": num?}
//!                    -> text + §A.3 counters + ttft_ms/ttlt_ms
//!                    (queueing included); with "stream": true the
//!                    response is chunked NDJSON, one lane event per
//!                    line (see rust/README.md "The streaming wire
//!                    protocol"). `POST /generate` is a legacy alias
//!                    with the identical contract. `priority` feeds
//!                    SLO-aware preemption: higher-priority queued work
//!                    may suspend a lower-priority live lane at a block
//!                    boundary (its KV spills host-side and resumes
//!                    byte-identically later).
//!   GET  /metrics    per-(backbone, method) §A.3 aggregates + wasted
//!                    work of aborted lanes, merged across replicas
//!   GET  /healthz    liveness + platform info + continuous-batching
//!                    state, summed across replicas, with the
//!                    per-replica breakdown under "shards" and the
//!                    dispatcher's routing/rejection counters
//!
//! Admission refusals map straight from [`SubmitError`]: 400 for
//! malformed requests, 429 (+ `Retry-After`) for a full queue or a
//! client over its fairness cap, 503 (+ `Retry-After`) while draining.
//! `client_id` (default: peer IP) names the fairness bucket. Every
//! 4xx/5xx carries the typed body `{"code", "message",
//! "retry_after_ms"}` (see [`err_json`]).
//!
//! Streaming cancellation: a failed or stalled-past-`io_timeout` write
//! marks the client gone, cancels the lane through the request handle,
//! and the worker frees its KV slot + prefix-chain pin at the next
//! block boundary.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::router::TryEvent;
use crate::coordinator::{
    FaultPlan, GenerateRequest, LaneEvent, Method, ResponseHandle, Router,
};
use crate::tokenizer::{Tokenizer, BOS, PAD};
use crate::util::json::Json;
use crate::workload;

pub struct ServerConfig {
    pub addr: String,
    pub default_backbone: String,
    /// Connection inactivity budget. Event loop: a connection that has
    /// not delivered a full request within this budget of its accept is
    /// dropped, and a streaming peer that stalls writes this long is
    /// treated as gone. Blocking pool: per-socket read/write timeout.
    /// `Duration::ZERO` disables the timeouts.
    pub io_timeout: Duration,
    /// Handler threads for the legacy blocking front door (it used to
    /// be hardcoded to 8). Ignored by the event loop, which multiplexes
    /// every connection on one thread.
    pub http_threads: usize,
    /// `true` selects the legacy thread-per-connection front door;
    /// default is the nonblocking event loop.
    pub blocking: bool,
    /// Deterministic fault injection (`None` in production): its
    /// `sockreset@req<K>` points kill the connection of the K-th
    /// accepted `/generate` right after admission — the client sees a
    /// reset mid-response, exercising the disconnect-cancel path.
    /// Usually the same plan handed to `RouterConfig::fault_plan`.
    pub fault_plan: Option<Arc<FaultPlan>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".into(),
            default_backbone: "dream".into(),
            io_timeout: Duration::from_secs(10),
            http_threads: 8,
            blocking: false,
            fault_plan: None,
        }
    }
}

/// Serial number of the next `/generate` admission, shared by both
/// front doors' handlers — the ordinal the fault plan's
/// `sockreset@req<K>` triggers match against.
struct ReqCounter(AtomicU64);

impl ReqCounter {
    fn next(&self) -> u64 {
        self.0.fetch_add(1, Ordering::SeqCst)
    }
}

/// `true` when the fault plan wants this request's socket reset.
fn sock_reset_due(plan: Option<&Arc<FaultPlan>>, ordinal: u64) -> bool {
    match plan {
        Some(p) => p.at_request(ordinal),
        None => false,
    }
}

/// Request-size guards: a drip-feeding (slow-loris) client that stays
/// under the per-syscall io_timeout could otherwise stream one header
/// byte at a time forever. Together with the per-connection `budget`
/// deadline they bound how long any handler thread can be pinned.
const MAX_HEADERS: usize = 64;
const MAX_LINE_BYTES: usize = 8 * 1024;
const MAX_BODY_BYTES: usize = 1024 * 1024;
/// Lane events relayed per connection per event-loop sweep (bounds how
/// long one busy stream can monopolize the loop).
const MAX_EVENTS_PER_SWEEP: usize = 64;

fn in_budget(deadline: &Option<std::time::Instant>) -> bool {
    match deadline {
        Some(d) => std::time::Instant::now() <= *d,
        None => true,
    }
}

/// Read one `\n`-terminated line, enforcing the length cap and the
/// wall-clock deadline *between underlying reads* — a client dripping
/// one byte per (sub-timeout) interval is cut off at the deadline
/// instead of stretching a single `read_line` indefinitely.
fn read_line_within(
    reader: &mut impl BufRead,
    deadline: &Option<std::time::Instant>,
    out: &mut String,
) -> Result<()> {
    loop {
        anyhow::ensure!(in_budget(deadline), "request read budget exceeded");
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            return Ok(()); // EOF: caller sees a short/empty line
        }
        let nl = buf.iter().position(|&b| b == b'\n');
        let take = nl.map(|i| i + 1).unwrap_or(buf.len());
        out.push_str(&String::from_utf8_lossy(&buf[..take]));
        reader.consume(take);
        anyhow::ensure!(out.len() <= MAX_LINE_BYTES, "line too long");
        if nl.is_some() {
            return Ok(());
        }
    }
}

/// Parse one HTTP request (method, path, body). `budget` is the total
/// wall-clock allowance for reading the request; the socket's own
/// read timeout bounds each syscall, this bounds their sum. (Blocking
/// front door only; the event loop parses incrementally with
/// `try_parse_request`.)
fn read_request(
    stream: &mut TcpStream,
    budget: Option<std::time::Duration>,
) -> Result<(String, String, String)> {
    let deadline = budget.map(|b| std::time::Instant::now() + b);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    read_line_within(&mut reader, &deadline, &mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    let mut headers = 0usize;
    loop {
        headers += 1;
        anyhow::ensure!(headers <= MAX_HEADERS, "too many headers");
        let mut h = String::new();
        read_line_within(&mut reader, &deadline, &mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:")
        {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    anyhow::ensure!(content_len <= MAX_BODY_BYTES, "body too large");
    let mut body = vec![0u8; content_len];
    let mut got = 0usize;
    while got < content_len {
        anyhow::ensure!(in_budget(&deadline), "request read budget exceeded");
        let n = reader.read(&mut body[got..])?;
        anyhow::ensure!(n > 0, "connection closed mid-body");
        got += n;
    }
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

/// Serialize one response. `retry_after` adds the `Retry-After` header
/// (whole seconds, floor 1) on 429/503 admission refusals.
fn response_bytes(
    status: u16,
    retry_after: Option<Duration>,
    body: &str,
) -> Vec<u8> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    };
    let retry = retry_after
        .map(|d| format!("Retry-After: {}\r\n", d.as_secs().max(1)))
        .unwrap_or_default();
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n{retry}Connection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    retry_after: Option<Duration>,
    body: &str,
) {
    let _ = stream.write_all(&response_bytes(status, retry_after, body));
}

/// Typed error body: every 4xx/5xx on both front doors answers with
/// `{"code", "message", "retry_after_ms"}` — `code` is a stable
/// machine-readable token, `message` is human-readable detail, and
/// `retry_after_ms` mirrors the `Retry-After` header (null when a
/// retry cannot help). `/generate` and `/v1/generate` share the same
/// contract.
fn err_json(
    code: &str,
    msg: &str,
    retry_after: Option<Duration>,
) -> String {
    let retry = retry_after
        .map(|d| Json::num(d.as_millis() as f64))
        .unwrap_or(Json::Null);
    Json::obj(vec![
        ("code", Json::str(code)),
        ("message", Json::str(msg)),
        ("retry_after_ms", retry),
    ])
    .to_string()
}

/// Error code for a terminal `Aborted` reason, aligned with
/// [`abort_status`]: deadline expiries are `deadline_exceeded` (504),
/// shard losses are retryable `shard_failure` (503), everything else
/// surfaces as `decode_failed` (500).
fn abort_code(reason: &str) -> &'static str {
    if reason.contains("deadline") {
        "deadline_exceeded"
    } else if reason.starts_with("shard_failure")
        || reason.starts_with("worker_lost")
    {
        "shard_failure"
    } else {
        "decode_failed"
    }
}

/// Encode a user prompt to the fixed left-padded geometry.
pub fn encode_user_prompt(
    tok: &Tokenizer,
    prompt: &str,
    prompt_len: usize,
) -> Result<Vec<i32>> {
    let mut ids = vec![BOS];
    ids.extend(tok.encode(&format!("{prompt}a:"))?);
    anyhow::ensure!(ids.len() <= prompt_len, "prompt too long");
    let mut out = vec![PAD; prompt_len - ids.len()];
    out.extend(ids);
    Ok(out)
}

/// Parse a `/generate` body into a router request plus the stream flag.
/// `peer_ip` seeds the fairness identity when the body carries no
/// `client_id`.
fn parse_generate(
    tok: &Tokenizer,
    router: &Router,
    default_backbone: &str,
    body: &str,
    peer_ip: Option<&str>,
) -> Result<(GenerateRequest, bool), (u16, String)> {
    let req = Json::parse(body)
        .map_err(|e| (400, err_json("invalid_request", &format!("bad json: {e}"), None)))?;
    let Some(prompt) = req.get("prompt").and_then(Json::as_str) else {
        return Err((400, err_json("invalid_request", "missing 'prompt'", None)));
    };
    let backbone = req
        .get("backbone")
        .and_then(Json::as_str)
        .unwrap_or(default_backbone)
        .to_string();
    let method = match req.get("method").and_then(Json::as_str) {
        None => Method::Cdlm,
        Some(m) => Method::from_name(m).ok_or_else(|| {
            (400, err_json("invalid_request", &format!("unknown method '{m}'"), None))
        })?,
    };
    let prompt_ids =
        encode_user_prompt(tok, prompt, router.geometry.prompt_len)
            .map_err(|e| (400, err_json("invalid_request", &format!("{e:#}"), None)))?;
    let tau_conf =
        req.get("tau_conf").and_then(Json::as_f64).map(|f| f as f32);
    let timeout = req
        .get("timeout_ms")
        .and_then(Json::as_f64)
        .filter(|&ms| ms > 0.0 && ms.is_finite())
        // f64 seconds, not `as u64` millis: a sub-millisecond budget
        // must stay a real (tiny) budget, not truncate to
        // already-expired
        .map(|ms| Duration::from_secs_f64(ms / 1e3));
    let max_new_tokens = req
        .get("max_new_tokens")
        .and_then(Json::as_usize)
        .filter(|&n| n > 0);
    let stream =
        req.get("stream").and_then(Json::as_bool).unwrap_or(false);
    let client = req
        .get("client_id")
        .and_then(Json::as_str)
        .map(str::to_string)
        .or_else(|| peer_ip.map(str::to_string));
    let priority = req
        .get("priority")
        .and_then(Json::as_f64)
        .filter(|p| p.is_finite())
        .map(|p| p.clamp(i32::MIN as f64, i32::MAX as f64) as i32)
        .unwrap_or(0);
    Ok((
        GenerateRequest {
            backbone,
            method,
            prompt_ids,
            tau_conf,
            timeout,
            max_new_tokens,
            client,
            priority,
        },
        stream,
    ))
}

/// The terminal JSON object shared by the one-shot response body and
/// the streamed `finished` event. `ttft_ms` is overridable: a streaming
/// client's observed TTFT is the first delta chunk actually written to
/// its socket, not the worker-side first-token stamp.
fn finished_json(
    resp: &crate::coordinator::GenerateResponse,
    method: Method,
    ttft_ms: f64,
) -> Vec<(&'static str, Json)> {
    let final_answer = workload::extract_final(&resp.text)
        .map(Json::str)
        .unwrap_or(Json::Null);
    vec![
        ("text", Json::str(resp.text.clone())),
        ("final", final_answer),
        ("steps", Json::num(resp.steps as f64)),
        ("model_calls", Json::num(resp.model_calls as f64)),
        ("gen_len", Json::num(resp.gen_len as f64)),
        ("latency_ms", Json::num(resp.latency.as_secs_f64() * 1e3)),
        ("ttft_ms", Json::num(ttft_ms)),
        ("ttlt_ms", Json::num(resp.ttlt.as_secs_f64() * 1e3)),
        ("method", Json::str(method.name())),
    ]
}

/// Map a terminal `Aborted` reason to a status: deadline expiries are
/// the client's budget (504); a request lost to a shard failure is a
/// retryable 503 (the service recovered or degraded — either way a
/// fresh submit can succeed elsewhere); everything else is a
/// server-side 500.
fn abort_status(reason: &str) -> u16 {
    if reason.contains("deadline") {
        504
    } else if reason.starts_with("shard_failure")
        || reason.starts_with("worker_lost")
    {
        503
    } else {
        500
    }
}

/// `Retry-After` hint for a terminal abort: only the 503s above are
/// worth an immediate client retry (a re-submit reroutes to a live
/// shard; a respawn typically completes within a second).
fn abort_retry_after(reason: &str) -> Option<Duration> {
    if reason.starts_with("shard_failure")
        || reason.starts_with("worker_lost")
    {
        Some(Duration::from_secs(1))
    } else {
        None
    }
}

/// One-shot `/generate`: drain the event pipeline to its terminal
/// event (blocking front door).
fn handle_generate(
    handle: &ResponseHandle,
    method: Method,
) -> (u16, Option<Duration>, String) {
    match handle.wait() {
        Ok(resp) => {
            let j = Json::obj(finished_json(
                &resp,
                method,
                resp.ttft.as_secs_f64() * 1e3,
            ));
            (200, None, j.to_string())
        }
        Err(reason) => (
            abort_status(&reason),
            abort_retry_after(&reason),
            err_json(abort_code(&reason), &reason, abort_retry_after(&reason)),
        ),
    }
}

/// Serialize one lane event to its NDJSON wire line; returns the line
/// and whether it is terminal. `first_delta` feeds the streamed
/// `finished` event's socket-observed TTFT.
fn event_line(
    event: LaneEvent,
    method: Method,
    arrived: Instant,
    first_delta: Option<Instant>,
) -> (String, bool) {
    match event {
        LaneEvent::Admitted => (
            Json::obj(vec![("event", Json::str("admitted"))]).to_string(),
            false,
        ),
        LaneEvent::Committed { block, text, tokens } => (
            Json::obj(vec![
                ("event", Json::str("delta")),
                ("block", Json::num(block as f64)),
                ("text", Json::str(text)),
                ("tokens", Json::num(tokens as f64)),
            ])
            .to_string(),
            false,
        ),
        LaneEvent::Finished(resp) => {
            // satellite fix (PR 5): a streamed client's TTFT is the
            // first delta chunk it actually received, not the
            // worker-side first-token stamp (which ignores socket
            // delivery)
            let ttft_ms = first_delta
                .map(|t| (t - arrived).as_secs_f64() * 1e3)
                .unwrap_or(resp.ttft.as_secs_f64() * 1e3);
            let mut fields = vec![("event", Json::str("finished"))];
            fields.extend(finished_json(&resp, method, ttft_ms));
            (Json::obj(fields).to_string(), true)
        }
        LaneEvent::Aborted { reason, steps, model_calls, committed_tokens } => (
            Json::obj(vec![
                ("event", Json::str("aborted")),
                ("reason", Json::str(reason)),
                ("steps", Json::num(steps as f64)),
                ("model_calls", Json::num(model_calls as f64)),
                (
                    "committed_tokens",
                    Json::num(committed_tokens as f64),
                ),
            ])
            .to_string(),
            true,
        ),
    }
}

const STREAM_HEADER: &[u8] =
    b"HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
      Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n";

/// Append one chunked-transfer chunk (a single NDJSON event line).
fn push_chunk(out: &mut Vec<u8>, line: &str) {
    // each event is one chunk: "<hex len>\r\n<json>\n\r\n"
    out.extend_from_slice(
        format!("{:x}\r\n{line}\n\r\n", line.len() + 1).as_bytes(),
    );
}

/// Write one chunked-transfer chunk (blocking front door).
fn write_chunk(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n{line}\n\r\n", line.len() + 1)?;
    stream.flush()
}

/// Streaming `/generate` (`"stream": true`), blocking front door:
/// chunked transfer, one JSON event per line, written as each lane
/// event arrives — `admitted`, `delta` per finalized block, then
/// exactly one terminal `finished`/`aborted` line followed by the
/// chunked-transfer terminator. A failed chunk write (disconnect, or a
/// peer stalled past `io_timeout` — the per-chunk write budget) cancels
/// the lane so the worker reclaims its KV at the next block boundary.
fn handle_generate_stream(
    stream: &mut TcpStream,
    handle: &ResponseHandle,
    method: Method,
    arrived: Instant,
) {
    if stream.write_all(STREAM_HEADER).is_err() {
        handle.cancel();
        return;
    }
    let mut first_delta: Option<Instant> = None;
    loop {
        let Some(event) = handle.next_event() else {
            // worker died without a terminal event
            let line = Json::obj(vec![
                ("event", Json::str("aborted")),
                ("reason", Json::str("worker dropped the request")),
            ])
            .to_string();
            let _ = write_chunk(stream, &line);
            break;
        };
        let is_delta = matches!(&event, LaneEvent::Committed { .. });
        let (line, terminal) =
            event_line(event, method, arrived, first_delta);
        if write_chunk(stream, &line).is_err() {
            // client gone: cancel the lane and stop relaying. The
            // dropped handle double-covers this (Committed sends fail),
            // but the explicit cancel reacts one block sooner.
            handle.cancel();
            return;
        }
        if is_delta && first_delta.is_none() {
            first_delta = Some(Instant::now());
        }
        if terminal {
            break;
        }
    }
    // chunked-transfer terminator
    let _ = stream.write_all(b"0\r\n\r\n");
}

// ---------------------------------------------------------------------------
// Nonblocking event-loop front door (default)
// ---------------------------------------------------------------------------

/// Scan `buf` for one complete HTTP request.
///
/// Returns `Ok(Some((method, path, body)))` once the head and the full
/// `Content-Length` body have arrived, `Ok(None)` when more bytes are
/// needed, and `Err(message)` for malformed or oversized requests.
fn try_parse_request(
    buf: &[u8],
) -> Result<Option<(String, String, String)>, String> {
    let Some(head_end) =
        buf.windows(4).position(|w| w == b"\r\n\r\n")
    else {
        if buf.len() > 2 * MAX_LINE_BYTES {
            return Err("headers too large".into());
        }
        return Ok(None);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]);
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    if request_line.len() > MAX_LINE_BYTES {
        return Err("line too long".into());
    }
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    let mut headers = 0usize;
    for h in lines {
        headers += 1;
        if headers > MAX_HEADERS {
            return Err("too many headers".into());
        }
        if let Some(v) =
            h.to_ascii_lowercase().strip_prefix("content-length:")
        {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    if content_len > MAX_BODY_BYTES {
        return Err("body too large".into());
    }
    let body_start = head_end + 4;
    if buf.len() < body_start + content_len {
        return Ok(None);
    }
    let body =
        String::from_utf8_lossy(&buf[body_start..body_start + content_len])
            .into_owned();
    Ok(Some((method, path, body)))
}

/// Where one multiplexed connection is in its life.
enum ConnState {
    /// Accumulating request bytes.
    Reading,
    /// One-shot `/generate`: polling the lane pipeline for its terminal
    /// event.
    Waiting { handle: ResponseHandle, method: Method },
    /// Streaming `/generate`: relaying lane events as chunked NDJSON.
    Streaming {
        handle: ResponseHandle,
        method: Method,
        arrived: Instant,
        first_delta: Option<Instant>,
    },
    /// Response fully queued; flush `out`, then close.
    Closing,
    /// Drop the connection now (deadline, dead peer, flushed close).
    Dead,
}

/// One multiplexed connection.
struct Conn {
    stream: TcpStream,
    peer_ip: Option<String>,
    /// Request bytes accumulated so far (Reading).
    buf: Vec<u8>,
    /// Response bytes queued but not yet accepted by the socket.
    out: Vec<u8>,
    state: ConnState,
    /// The full request must arrive by here (accept + io_timeout) —
    /// the event-loop analogue of the blocking path's loris budget.
    read_deadline: Option<Instant>,
    /// Since when `out` has failed to make progress (stalled peer).
    stalled_since: Option<Instant>,
}

/// Cancel the connection's lane, if it holds one (dead-peer paths).
fn cancel_lane(state: &ConnState) {
    if let ConnState::Waiting { handle, .. }
    | ConnState::Streaming { handle, .. } = state
    {
        handle.cancel();
    }
}

/// Pump one connection: socket reads (request bytes + disconnect
/// detection), state transitions, event polling, and buffered writes.
/// Sets `progress` if anything moved. Returns `false` once the
/// connection should be dropped.
fn step_conn(
    conn: &mut Conn,
    router: &Router,
    tok: &Tokenizer,
    default_backbone: &str,
    io_timeout: Option<Duration>,
    fault_plan: Option<&Arc<FaultPlan>>,
    req_counter: &ReqCounter,
    progress: &mut bool,
) -> bool {
    let now = Instant::now();
    // ---- socket reads
    let mut read_buf = [0u8; 4096];
    loop {
        match conn.stream.read(&mut read_buf) {
            Ok(0) => match conn.state {
                // peer hung up: a mid-request close is silent; a
                // mid-decode close cancels the lane so the worker
                // reclaims it at the next block boundary
                ConnState::Reading | ConnState::Dead => return false,
                ConnState::Waiting { .. } | ConnState::Streaming { .. } => {
                    cancel_lane(&conn.state);
                    return false;
                }
                // half-close while flushing: keep writing the response
                ConnState::Closing => break,
            },
            Ok(n) => {
                *progress = true;
                if matches!(conn.state, ConnState::Reading) {
                    conn.buf.extend_from_slice(&read_buf[..n]);
                    if conn.buf.len() > MAX_BODY_BYTES + 2 * MAX_LINE_BYTES {
                        conn.out.extend_from_slice(&response_bytes(
                            400,
                            None,
                            &err_json("invalid_request", "request too large", None),
                        ));
                        conn.state = ConnState::Closing;
                        break;
                    }
                }
                // pipelined bytes past the request are ignored
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => break,
            Err(_) => {
                cancel_lane(&conn.state);
                return false;
            }
        }
    }
    // ---- state machine
    let state = std::mem::replace(&mut conn.state, ConnState::Dead);
    conn.state = match state {
        ConnState::Reading => {
            if conn.read_deadline.is_some_and(|d| now > d) {
                // idle / loris connection: hang up silently
                ConnState::Dead
            } else {
                match try_parse_request(&conn.buf) {
                    Err(msg) => {
                        conn.out.extend_from_slice(&response_bytes(
                            400,
                            None,
                            &err_json("invalid_request", &msg, None),
                        ));
                        ConnState::Closing
                    }
                    Ok(None) => ConnState::Reading,
                    Ok(Some((method, path, body))) => {
                        *progress = true;
                        dispatch(
                            conn,
                            router,
                            tok,
                            default_backbone,
                            fault_plan,
                            req_counter,
                            &method,
                            &path,
                            &body,
                        )
                    }
                }
            }
        }
        ConnState::Waiting { handle, method } => {
            let mut next = None;
            for _ in 0..MAX_EVENTS_PER_SWEEP {
                match handle.try_next_event() {
                    TryEvent::Event(LaneEvent::Finished(resp)) => {
                        let j = Json::obj(finished_json(
                            &resp,
                            method,
                            resp.ttft.as_secs_f64() * 1e3,
                        ));
                        conn.out.extend_from_slice(&response_bytes(
                            200,
                            None,
                            &j.to_string(),
                        ));
                        next = Some(ConnState::Closing);
                        *progress = true;
                        break;
                    }
                    TryEvent::Event(LaneEvent::Aborted {
                        reason, ..
                    }) => {
                        conn.out.extend_from_slice(&response_bytes(
                            abort_status(&reason),
                            abort_retry_after(&reason),
                            &err_json(abort_code(&reason), &reason, abort_retry_after(&reason)),
                        ));
                        next = Some(ConnState::Closing);
                        *progress = true;
                        break;
                    }
                    // one-shot clients only see the terminal event
                    TryEvent::Event(_) => continue,
                    TryEvent::Empty => break,
                    TryEvent::Closed => {
                        conn.out.extend_from_slice(&response_bytes(
                            500,
                            None,
                            &err_json("internal", "worker dropped the request", None),
                        ));
                        next = Some(ConnState::Closing);
                        *progress = true;
                        break;
                    }
                }
            }
            next.unwrap_or(ConnState::Waiting { handle, method })
        }
        ConnState::Streaming { handle, method, arrived, mut first_delta } => {
            let mut next = None;
            for _ in 0..MAX_EVENTS_PER_SWEEP {
                match handle.try_next_event() {
                    TryEvent::Event(event) => {
                        *progress = true;
                        let is_delta =
                            matches!(&event, LaneEvent::Committed { .. });
                        let (line, terminal) =
                            event_line(event, method, arrived, first_delta);
                        push_chunk(&mut conn.out, &line);
                        if is_delta && first_delta.is_none() {
                            first_delta = Some(Instant::now());
                        }
                        if terminal {
                            conn.out.extend_from_slice(b"0\r\n\r\n");
                            next = Some(ConnState::Closing);
                            break;
                        }
                    }
                    TryEvent::Empty => break,
                    TryEvent::Closed => {
                        let line = Json::obj(vec![
                            ("event", Json::str("aborted")),
                            (
                                "reason",
                                Json::str("worker dropped the request"),
                            ),
                        ])
                        .to_string();
                        push_chunk(&mut conn.out, &line);
                        conn.out.extend_from_slice(b"0\r\n\r\n");
                        next = Some(ConnState::Closing);
                        *progress = true;
                        break;
                    }
                }
            }
            next.unwrap_or(ConnState::Streaming {
                handle,
                method,
                arrived,
                first_delta,
            })
        }
        other => other,
    };
    // ---- buffered writes
    if !conn.out.is_empty() && !matches!(conn.state, ConnState::Dead) {
        match conn.stream.write(&conn.out) {
            Ok(0) => {
                cancel_lane(&conn.state);
                conn.state = ConnState::Dead;
            }
            Ok(n) => {
                conn.out.drain(..n);
                conn.stalled_since = None;
                *progress = true;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                // a peer that stops reading its stream for io_timeout is
                // as gone as one that disconnected: cancel the lane so
                // its KV slot frees at the next block boundary
                let since = *conn.stalled_since.get_or_insert(now);
                if io_timeout
                    .is_some_and(|t| now.duration_since(since) > t)
                {
                    cancel_lane(&conn.state);
                    conn.state = ConnState::Dead;
                }
            }
            Err(_) => {
                cancel_lane(&conn.state);
                conn.state = ConnState::Dead;
            }
        }
    }
    if matches!(conn.state, ConnState::Closing) && conn.out.is_empty() {
        conn.state = ConnState::Dead;
    }
    !matches!(conn.state, ConnState::Dead)
}

/// Route one parsed request; returns the connection's next state.
fn dispatch(
    conn: &mut Conn,
    router: &Router,
    tok: &Tokenizer,
    default_backbone: &str,
    fault_plan: Option<&Arc<FaultPlan>>,
    req_counter: &ReqCounter,
    method: &str,
    path: &str,
    body: &str,
) -> ConnState {
    match (method, path) {
        ("POST", "/v1/generate" | "/generate") => {
            let arrived = Instant::now();
            match parse_generate(
                tok,
                router,
                default_backbone,
                body,
                conn.peer_ip.as_deref(),
            ) {
                Err((status, body)) => {
                    conn.out.extend_from_slice(&response_bytes(
                        status, None, &body,
                    ));
                    ConnState::Closing
                }
                Ok((req, stream_mode)) => {
                    let gen_method = req.method;
                    let ordinal = req_counter.next();
                    match router.submit(req) {
                        Err(e) => {
                            conn.out.extend_from_slice(&response_bytes(
                                e.status(),
                                e.retry_after(),
                                &err_json(e.code(), &e.to_string(), e.retry_after()),
                            ));
                            ConnState::Closing
                        }
                        Ok(handle)
                            if sock_reset_due(fault_plan, ordinal) =>
                        {
                            // injected socket reset: the client's
                            // connection dies right after admission;
                            // the cancel mirrors what the write-failure
                            // path would do a block later
                            handle.cancel();
                            ConnState::Dead
                        }
                        Ok(handle) if stream_mode => {
                            conn.out.extend_from_slice(STREAM_HEADER);
                            ConnState::Streaming {
                                handle,
                                method: gen_method,
                                arrived,
                                first_delta: None,
                            }
                        }
                        Ok(handle) => ConnState::Waiting {
                            handle,
                            method: gen_method,
                        },
                    }
                }
            }
        }
        ("GET", "/metrics") => {
            let (status, body) = match router.metrics() {
                Ok(j) => (200, j.to_string()),
                Err(e) => (500, err_json("internal", &format!("{e:#}"), None)),
            };
            conn.out
                .extend_from_slice(&response_bytes(status, None, &body));
            ConnState::Closing
        }
        ("GET", "/healthz") => {
            let (status, body) = match router.health() {
                Ok(j) => (200, j.to_string()),
                Err(e) => (500, err_json("internal", &format!("{e:#}"), None)),
            };
            conn.out
                .extend_from_slice(&response_bytes(status, None, &body));
            ConnState::Closing
        }
        _ => {
            conn.out.extend_from_slice(&response_bytes(
                404,
                None,
                &err_json("not_found", "not found", None),
            ));
            ConnState::Closing
        }
    }
}

/// The nonblocking event loop: accept burst, then one pump pass over
/// every connection, sleeping ~500µs only when nothing moved. Once
/// `stop` is observed the loop stops accepting, begins the router's
/// graceful drain (new submits answer 503), keeps pumping until every
/// open connection has flushed its terminal event, then joins the shard
/// workers and returns.
fn serve_event_loop(
    listener: TcpListener,
    router: Router,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let tok = Tokenizer::new();
    let io_timeout = if cfg.io_timeout.is_zero() {
        None
    } else {
        Some(cfg.io_timeout)
    };
    let req_counter = ReqCounter(AtomicU64::new(0));
    let mut conns: Vec<Conn> = Vec::new();
    let mut draining = false;
    loop {
        let mut progress = false;
        if !draining && stop.load(Ordering::SeqCst) {
            draining = true;
            router.begin_drain();
        }
        if !draining {
            loop {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        let _ = stream.set_nonblocking(true);
                        conns.push(Conn {
                            stream,
                            peer_ip: Some(peer.ip().to_string()),
                            buf: Vec::new(),
                            out: Vec::new(),
                            state: ConnState::Reading,
                            read_deadline: io_timeout
                                .map(|t| Instant::now() + t),
                            stalled_since: None,
                        });
                        progress = true;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }
        }
        let mut i = 0;
        while i < conns.len() {
            let alive = step_conn(
                &mut conns[i],
                &router,
                &tok,
                &cfg.default_backbone,
                io_timeout,
                cfg.fault_plan.as_ref(),
                &req_counter,
                &mut progress,
            );
            if alive {
                i += 1;
            } else {
                conns.swap_remove(i);
                progress = true;
            }
        }
        if draining && conns.is_empty() {
            // every connection answered; drain the shard workers too
            router.shutdown();
            return Ok(());
        }
        if !progress {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
}

// ---------------------------------------------------------------------------
// Entry points
// ---------------------------------------------------------------------------

/// Serve until the process is killed.
pub fn serve(router: Router, cfg: ServerConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[cdlm] serving on http://{}", listener.local_addr()?);
    serve_on(listener, router, cfg)
}

/// Serve on an already-bound listener (tests bind an ephemeral port
/// themselves and pass it in).
pub fn serve_on(
    listener: TcpListener,
    router: Router,
    cfg: ServerConfig,
) -> Result<()> {
    serve_on_until(listener, router, cfg, Arc::new(AtomicBool::new(false)))
}

/// Serve until `stop` becomes true, then drain gracefully: accepts
/// cease, in-flight requests finish (queued ones answer their terminal
/// `Aborted{"shutdown"}`, new submits answer 503 + `Retry-After`), the
/// shard workers join, and the call returns. The blocking front door
/// checks `stop` between accepted connections only.
pub fn serve_on_until(
    listener: TcpListener,
    router: Router,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    if !cfg.blocking {
        return serve_event_loop(listener, router, cfg, stop);
    }
    let router = Arc::new(router);
    // bounded connection-handler pool (decode concurrency is separately
    // bounded by the shard workers + batchers). Pool size was hardcoded
    // to 8; `http_threads` owns it now.
    let pool =
        crate::util::threadpool::ThreadPool::new(cfg.http_threads.max(1));
    let io_timeout = if cfg.io_timeout.is_zero() {
        None
    } else {
        Some(cfg.io_timeout)
    };
    let req_counter = Arc::new(ReqCounter(AtomicU64::new(0)));
    for stream in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(mut stream) = stream else { continue };
        // an unresponsive peer must release its handler thread: every
        // read/write syscall on the socket gives up after io_timeout
        // and the handler returns (read_request propagates the error)
        let _ = stream.set_read_timeout(io_timeout);
        let _ = stream.set_write_timeout(io_timeout);
        let router = router.clone();
        let backbone = cfg.default_backbone.clone();
        let fault_plan = cfg.fault_plan.clone();
        let req_counter = req_counter.clone();
        pool.execute(move || {
            let tok = Tokenizer::new();
            let peer_ip =
                stream.peer_addr().ok().map(|a| a.ip().to_string());
            // the whole request must arrive within one io_timeout of
            // the handler starting — a drip-feed that beats every
            // per-syscall timeout still cannot hold the thread longer
            let (method, path, body) =
                match read_request(&mut stream, io_timeout) {
                    Ok(r) => r,
                    Err(_) => return,
                };
            let (status, retry, body) = match (method.as_str(), path.as_str())
            {
                ("POST", "/v1/generate" | "/generate") => {
                    let arrived = Instant::now();
                    match parse_generate(
                        &tok,
                        &router,
                        &backbone,
                        &body,
                        peer_ip.as_deref(),
                    ) {
                        Err((status, body)) => (status, None, body),
                        Ok((req, stream_mode)) => {
                            let gen_method = req.method;
                            let ordinal = req_counter.next();
                            match router.submit(req) {
                                Err(e) => (
                                    e.status(),
                                    e.retry_after(),
                                    err_json(e.code(), &e.to_string(), e.retry_after()),
                                ),
                                Ok(handle)
                                    if sock_reset_due(
                                        fault_plan.as_ref(),
                                        ordinal,
                                    ) =>
                                {
                                    // injected socket reset: drop the
                                    // connection right after admission
                                    handle.cancel();
                                    return;
                                }
                                Ok(handle) if stream_mode => {
                                    // the chunked event relay owns the
                                    // socket from here on
                                    handle_generate_stream(
                                        &mut stream,
                                        &handle,
                                        gen_method,
                                        arrived,
                                    );
                                    return;
                                }
                                Ok(handle) => {
                                    handle_generate(&handle, gen_method)
                                }
                            }
                        }
                    }
                }
                ("GET", "/metrics") => match router.metrics() {
                    Ok(j) => (200, None, j.to_string()),
                    Err(e) => (500, None, err_json("internal", &format!("{e:#}"), None)),
                },
                ("GET", "/healthz") => match router.health() {
                    Ok(j) => (200, None, j.to_string()),
                    Err(e) => (500, None, err_json("internal", &format!("{e:#}"), None)),
                },
                _ => (404, None, err_json("not_found", "not found", None)),
            };
            respond(&mut stream, status, retry, &body);
        });
    }
    // drain on the blocking path too, so `stop` means the same thing on
    // both front doors: joining the pool first lets every in-flight
    // handler release its Arc, so the unwrap cannot miss the shutdown
    drop(pool);
    if let Ok(router) = Arc::try_unwrap(router) {
        router.shutdown();
    }
    Ok(())
}
