//! HTTP/1.1 API over std::net — one handler thread per connection.
//! Handlers never touch XLA state: they tokenize, submit to the router
//! (whose worker thread owns the PJRT runtime), and wait on a channel.
//!
//!   POST /generate   {"prompt": str, "backbone": str?, "method": str?,
//!                     "tau_conf": num?} -> text + §A.3 counters +
//!                     ttft_ms/ttlt_ms (queueing included)
//!   GET  /metrics    per-(backbone, method) §A.3 aggregates
//!   GET  /healthz    liveness + platform info + continuous-batching
//!                    state (in_flight_lanes, active_batches,
//!                    total/mid-flight admissions, retired_early)

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{GenerateRequest, Method, Router};
use crate::tokenizer::{Tokenizer, BOS, PAD};
use crate::util::json::Json;
use crate::workload;

pub struct ServerConfig {
    pub addr: String,
    pub default_backbone: String,
}

/// Parse one HTTP request (method, path, body).
fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:")
        {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// Encode a user prompt to the fixed left-padded geometry.
pub fn encode_user_prompt(
    tok: &Tokenizer,
    prompt: &str,
    prompt_len: usize,
) -> Result<Vec<i32>> {
    let mut ids = vec![BOS];
    ids.extend(tok.encode(&format!("{prompt}a:"))?);
    anyhow::ensure!(ids.len() <= prompt_len, "prompt too long");
    let mut out = vec![PAD; prompt_len - ids.len()];
    out.extend(ids);
    Ok(out)
}

fn handle_generate(
    tok: &Tokenizer,
    router: &Router,
    default_backbone: &str,
    body: &str,
) -> (u16, String) {
    let req = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return (400, err_json(&format!("bad json: {e}"))),
    };
    let Some(prompt) = req.get("prompt").and_then(Json::as_str) else {
        return (400, err_json("missing 'prompt'"));
    };
    let backbone = req
        .get("backbone")
        .and_then(Json::as_str)
        .unwrap_or(default_backbone)
        .to_string();
    let method = match req.get("method").and_then(Json::as_str) {
        None => Method::Cdlm,
        Some(m) => match Method::from_name(m) {
            Some(m) => m,
            None => return (400, err_json(&format!("unknown method '{m}'"))),
        },
    };
    let prompt_ids =
        match encode_user_prompt(tok, prompt, router.geometry.prompt_len) {
            Ok(ids) => ids,
            Err(e) => return (400, err_json(&format!("{e:#}"))),
        };
    let tau_conf = req.get("tau_conf").and_then(Json::as_f64).map(|f| f as f32);
    let rx = match router.submit(GenerateRequest {
        backbone,
        method,
        prompt_ids,
        tau_conf,
    }) {
        Ok(rx) => rx,
        Err(e) => return (429, err_json(&format!("{e:#}"))),
    };
    match rx.recv() {
        Ok(Ok(resp)) => {
            let final_answer = workload::extract_final(&resp.text)
                .map(Json::str)
                .unwrap_or(Json::Null);
            let j = Json::obj(vec![
                ("text", Json::str(resp.text.clone())),
                ("final", final_answer),
                ("steps", Json::num(resp.steps as f64)),
                ("model_calls", Json::num(resp.model_calls as f64)),
                ("gen_len", Json::num(resp.gen_len as f64)),
                ("latency_ms", Json::num(resp.latency.as_secs_f64() * 1e3)),
                ("ttft_ms", Json::num(resp.ttft.as_secs_f64() * 1e3)),
                ("ttlt_ms", Json::num(resp.ttlt.as_secs_f64() * 1e3)),
                ("method", Json::str(method.name())),
            ]);
            (200, j.to_string())
        }
        Ok(Err(e)) => (500, err_json(&e)),
        Err(_) => (500, err_json("worker dropped the request")),
    }
}

/// Serve until the process is killed.
pub fn serve(router: Router, cfg: ServerConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[cdlm] serving on http://{}", listener.local_addr()?);
    let router = Arc::new(router);
    // bounded connection-handler pool (decode concurrency is separately
    // bounded by the router worker + batcher)
    let pool = crate::util::threadpool::ThreadPool::new(8);
    for stream in listener.incoming() {
        let Ok(mut stream) = stream else { continue };
        let router = router.clone();
        let backbone = cfg.default_backbone.clone();
        pool.execute(move || {
            let tok = Tokenizer::new();
            let (method, path, body) = match read_request(&mut stream) {
                Ok(r) => r,
                Err(_) => return,
            };
            let (status, body) = match (method.as_str(), path.as_str()) {
                ("POST", "/generate") => {
                    handle_generate(&tok, &router, &backbone, &body)
                }
                ("GET", "/metrics") => match router.metrics() {
                    Ok(j) => (200, j.to_string()),
                    Err(e) => (500, err_json(&format!("{e:#}"))),
                },
                ("GET", "/healthz") => match router.health() {
                    Ok(j) => (200, j.to_string()),
                    Err(e) => (500, err_json(&format!("{e:#}"))),
                },
                _ => (404, err_json("not found")),
            };
            respond(&mut stream, status, &body);
        });
    }
    Ok(())
}
