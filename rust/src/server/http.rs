//! HTTP/1.1 API over std::net — one handler thread per connection.
//! Handlers never touch XLA state: they tokenize, submit to the router
//! (whose worker thread owns the PJRT runtime), and relay lane events.
//!
//!   POST /generate   {"prompt": str, "backbone": str?, "method": str?,
//!                     "tau_conf": num?, "timeout_ms": num?,
//!                     "max_new_tokens": num?, "stream": bool?}
//!                    -> text + §A.3 counters + ttft_ms/ttlt_ms
//!                    (queueing included); with "stream": true the
//!                    response is chunked NDJSON, one lane event per
//!                    line (see rust/README.md "The streaming wire
//!                    protocol")
//!   GET  /metrics    per-(backbone, method) §A.3 aggregates + wasted
//!                    work of aborted lanes
//!   GET  /healthz    liveness + platform info + continuous-batching
//!                    state (in_flight_lanes, active_batches,
//!                    total/mid-flight admissions, retired_early,
//!                    aborted_queued/aborted_inflight)
//!
//! Streaming cancellation: every chunk write runs under the socket's
//! `io_timeout`; a failed or timed-out write marks the client gone,
//! cancels the lane through the request handle, and the worker frees
//! its KV slot + prefix-chain pin at the next block boundary.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{
    GenerateRequest, LaneEvent, Method, ResponseHandle, Router,
};
use crate::tokenizer::{Tokenizer, BOS, PAD};
use crate::util::json::Json;
use crate::workload;

pub struct ServerConfig {
    pub addr: String,
    pub default_backbone: String,
    /// Per-socket read/write timeout. The handler pool is 8 threads;
    /// without this, 8 idle or slow-loris connections pin the whole
    /// server — every blocking socket syscall must be able to give up.
    /// `Duration::ZERO` disables the timeouts (blocking sockets).
    pub io_timeout: Duration,
}

/// Request-size guards: a drip-feeding (slow-loris) client that stays
/// under the per-syscall io_timeout could otherwise stream one header
/// byte at a time forever. Together with the per-connection `budget`
/// deadline they bound how long any handler thread can be pinned.
const MAX_HEADERS: usize = 64;
const MAX_LINE_BYTES: usize = 8 * 1024;
const MAX_BODY_BYTES: usize = 1024 * 1024;

fn in_budget(deadline: &Option<std::time::Instant>) -> bool {
    match deadline {
        Some(d) => std::time::Instant::now() <= *d,
        None => true,
    }
}

/// Read one `\n`-terminated line, enforcing the length cap and the
/// wall-clock deadline *between underlying reads* — a client dripping
/// one byte per (sub-timeout) interval is cut off at the deadline
/// instead of stretching a single `read_line` indefinitely.
fn read_line_within(
    reader: &mut impl BufRead,
    deadline: &Option<std::time::Instant>,
    out: &mut String,
) -> Result<()> {
    loop {
        anyhow::ensure!(in_budget(deadline), "request read budget exceeded");
        let buf = reader.fill_buf()?;
        if buf.is_empty() {
            return Ok(()); // EOF: caller sees a short/empty line
        }
        let nl = buf.iter().position(|&b| b == b'\n');
        let take = nl.map(|i| i + 1).unwrap_or(buf.len());
        out.push_str(&String::from_utf8_lossy(&buf[..take]));
        reader.consume(take);
        anyhow::ensure!(out.len() <= MAX_LINE_BYTES, "line too long");
        if nl.is_some() {
            return Ok(());
        }
    }
}

/// Parse one HTTP request (method, path, body). `budget` is the total
/// wall-clock allowance for reading the request; the socket's own
/// read timeout bounds each syscall, this bounds their sum.
fn read_request(
    stream: &mut TcpStream,
    budget: Option<std::time::Duration>,
) -> Result<(String, String, String)> {
    let deadline = budget.map(|b| std::time::Instant::now() + b);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    read_line_within(&mut reader, &deadline, &mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    let mut headers = 0usize;
    loop {
        headers += 1;
        anyhow::ensure!(headers <= MAX_HEADERS, "too many headers");
        let mut h = String::new();
        read_line_within(&mut reader, &deadline, &mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:")
        {
            content_len = v.trim().parse().unwrap_or(0);
        }
    }
    anyhow::ensure!(content_len <= MAX_BODY_BYTES, "body too large");
    let mut body = vec![0u8; content_len];
    let mut got = 0usize;
    while got < content_len {
        anyhow::ensure!(in_budget(&deadline), "request read budget exceeded");
        let n = reader.read(&mut body[got..])?;
        anyhow::ensure!(n > 0, "connection closed mid-body");
        got += n;
    }
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn respond(stream: &mut TcpStream, status: u16, body: &str) {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).to_string()
}

/// Encode a user prompt to the fixed left-padded geometry.
pub fn encode_user_prompt(
    tok: &Tokenizer,
    prompt: &str,
    prompt_len: usize,
) -> Result<Vec<i32>> {
    let mut ids = vec![BOS];
    ids.extend(tok.encode(&format!("{prompt}a:"))?);
    anyhow::ensure!(ids.len() <= prompt_len, "prompt too long");
    let mut out = vec![PAD; prompt_len - ids.len()];
    out.extend(ids);
    Ok(out)
}

/// Parse a `/generate` body into a router request plus the stream flag.
fn parse_generate(
    tok: &Tokenizer,
    router: &Router,
    default_backbone: &str,
    body: &str,
) -> Result<(GenerateRequest, bool), (u16, String)> {
    let req = Json::parse(body)
        .map_err(|e| (400, err_json(&format!("bad json: {e}"))))?;
    let Some(prompt) = req.get("prompt").and_then(Json::as_str) else {
        return Err((400, err_json("missing 'prompt'")));
    };
    let backbone = req
        .get("backbone")
        .and_then(Json::as_str)
        .unwrap_or(default_backbone)
        .to_string();
    let method = match req.get("method").and_then(Json::as_str) {
        None => Method::Cdlm,
        Some(m) => Method::from_name(m).ok_or_else(|| {
            (400, err_json(&format!("unknown method '{m}'")))
        })?,
    };
    let prompt_ids =
        encode_user_prompt(tok, prompt, router.geometry.prompt_len)
            .map_err(|e| (400, err_json(&format!("{e:#}"))))?;
    let tau_conf =
        req.get("tau_conf").and_then(Json::as_f64).map(|f| f as f32);
    let timeout = req
        .get("timeout_ms")
        .and_then(Json::as_f64)
        .filter(|&ms| ms > 0.0 && ms.is_finite())
        // f64 seconds, not `as u64` millis: a sub-millisecond budget
        // must stay a real (tiny) budget, not truncate to
        // already-expired
        .map(|ms| Duration::from_secs_f64(ms / 1e3));
    let max_new_tokens = req
        .get("max_new_tokens")
        .and_then(Json::as_usize)
        .filter(|&n| n > 0);
    let stream =
        req.get("stream").and_then(Json::as_bool).unwrap_or(false);
    Ok((
        GenerateRequest {
            backbone,
            method,
            prompt_ids,
            tau_conf,
            timeout,
            max_new_tokens,
        },
        stream,
    ))
}

/// The terminal JSON object shared by the one-shot response body and
/// the streamed `finished` event. `ttft_ms` is overridable: a streaming
/// client's observed TTFT is the first delta chunk actually written to
/// its socket, not the worker-side first-token stamp.
fn finished_json(
    resp: &crate::coordinator::GenerateResponse,
    method: Method,
    ttft_ms: f64,
) -> Vec<(&'static str, Json)> {
    let final_answer = workload::extract_final(&resp.text)
        .map(Json::str)
        .unwrap_or(Json::Null);
    vec![
        ("text", Json::str(resp.text.clone())),
        ("final", final_answer),
        ("steps", Json::num(resp.steps as f64)),
        ("model_calls", Json::num(resp.model_calls as f64)),
        ("gen_len", Json::num(resp.gen_len as f64)),
        ("latency_ms", Json::num(resp.latency.as_secs_f64() * 1e3)),
        ("ttft_ms", Json::num(ttft_ms)),
        ("ttlt_ms", Json::num(resp.ttlt.as_secs_f64() * 1e3)),
        ("method", Json::str(method.name())),
    ]
}

/// One-shot `/generate`: drain the event pipeline to its terminal
/// event. An aborted deadline maps to 504 so clients can tell a budget
/// expiry from a server fault.
fn handle_generate(
    handle: &ResponseHandle,
    method: Method,
) -> (u16, String) {
    match handle.wait() {
        Ok(resp) => {
            let j = Json::obj(finished_json(
                &resp,
                method,
                resp.ttft.as_secs_f64() * 1e3,
            ));
            (200, j.to_string())
        }
        Err(reason) if reason.contains("deadline") => {
            (504, err_json(&reason))
        }
        Err(reason) => (500, err_json(&reason)),
    }
}

/// Write one chunked-transfer chunk (a single NDJSON event line).
fn write_chunk(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    // each event is one chunk: "<hex len>\r\n<json>\n\r\n"
    write!(stream, "{:x}\r\n{line}\n\r\n", line.len() + 1)?;
    stream.flush()
}

/// Streaming `/generate` (`"stream": true`): chunked transfer, one
/// JSON event per line, written as each lane event arrives —
/// `admitted`, `delta` per finalized block, then exactly one terminal
/// `finished`/`aborted` line followed by the chunked-transfer
/// terminator. A failed chunk write (disconnect, or a peer stalled past
/// `io_timeout` — the per-chunk write budget) cancels the lane so the
/// worker reclaims its KV at the next block boundary.
fn handle_generate_stream(
    stream: &mut TcpStream,
    handle: &ResponseHandle,
    method: Method,
    arrived: Instant,
) {
    let header = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\n\
                  Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(header.as_bytes()).is_err() {
        handle.cancel();
        return;
    }
    let mut first_delta: Option<Instant> = None;
    loop {
        let Some(event) = handle.next_event() else {
            // worker died without a terminal event
            let line = Json::obj(vec![
                ("event", Json::str("aborted")),
                ("reason", Json::str("worker dropped the request")),
            ])
            .to_string();
            let _ = write_chunk(stream, &line);
            break;
        };
        let is_delta = matches!(&event, LaneEvent::Committed { .. });
        let (line, terminal) = match event {
            LaneEvent::Admitted => (
                Json::obj(vec![("event", Json::str("admitted"))])
                    .to_string(),
                false,
            ),
            LaneEvent::Committed { block, text, tokens } => (
                Json::obj(vec![
                    ("event", Json::str("delta")),
                    ("block", Json::num(block as f64)),
                    ("text", Json::str(text)),
                    ("tokens", Json::num(tokens as f64)),
                ])
                .to_string(),
                false,
            ),
            LaneEvent::Finished(resp) => {
                // satellite fix: a streamed client's TTFT is the first
                // delta chunk it actually received, not the worker-side
                // first-token stamp (which ignores socket delivery)
                let ttft_ms = first_delta
                    .map(|t| (t - arrived).as_secs_f64() * 1e3)
                    .unwrap_or(resp.ttft.as_secs_f64() * 1e3);
                let mut fields = vec![("event", Json::str("finished"))];
                fields.extend(finished_json(&resp, method, ttft_ms));
                (Json::obj(fields).to_string(), true)
            }
            LaneEvent::Aborted { reason, steps, model_calls, committed_tokens } => (
                Json::obj(vec![
                    ("event", Json::str("aborted")),
                    ("reason", Json::str(reason)),
                    ("steps", Json::num(steps as f64)),
                    ("model_calls", Json::num(model_calls as f64)),
                    (
                        "committed_tokens",
                        Json::num(committed_tokens as f64),
                    ),
                ])
                .to_string(),
                true,
            ),
        };
        if write_chunk(stream, &line).is_err() {
            // client gone: cancel the lane and stop relaying. The
            // dropped handle double-covers this (Committed sends fail),
            // but the explicit cancel reacts one block sooner.
            handle.cancel();
            return;
        }
        if is_delta && first_delta.is_none() {
            first_delta = Some(Instant::now());
        }
        if terminal {
            break;
        }
    }
    // chunked-transfer terminator
    let _ = stream.write_all(b"0\r\n\r\n");
}

/// Serve until the process is killed.
pub fn serve(router: Router, cfg: ServerConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    eprintln!("[cdlm] serving on http://{}", listener.local_addr()?);
    serve_on(listener, router, cfg)
}

/// Serve on an already-bound listener (tests bind an ephemeral port
/// themselves and pass it in).
pub fn serve_on(
    listener: TcpListener,
    router: Router,
    cfg: ServerConfig,
) -> Result<()> {
    let router = Arc::new(router);
    // bounded connection-handler pool (decode concurrency is separately
    // bounded by the router worker + batcher)
    let pool = crate::util::threadpool::ThreadPool::new(8);
    let io_timeout = if cfg.io_timeout.is_zero() {
        None
    } else {
        Some(cfg.io_timeout)
    };
    for stream in listener.incoming() {
        let Ok(mut stream) = stream else { continue };
        // an unresponsive peer must release its handler thread: every
        // read/write syscall on the socket gives up after io_timeout
        // and the handler returns (read_request propagates the error)
        let _ = stream.set_read_timeout(io_timeout);
        let _ = stream.set_write_timeout(io_timeout);
        let router = router.clone();
        let backbone = cfg.default_backbone.clone();
        pool.execute(move || {
            let tok = Tokenizer::new();
            // the whole request must arrive within one io_timeout of
            // the handler starting — a drip-feed that beats every
            // per-syscall timeout still cannot hold the thread longer
            let (method, path, body) =
                match read_request(&mut stream, io_timeout) {
                    Ok(r) => r,
                    Err(_) => return,
                };
            let (status, body) = match (method.as_str(), path.as_str()) {
                ("POST", "/generate") => {
                    let arrived = Instant::now();
                    match parse_generate(&tok, &router, &backbone, &body) {
                        Err((status, body)) => (status, body),
                        Ok((req, stream_mode)) => {
                            let gen_method = req.method;
                            match router.submit(req) {
                                Err(e) => (429, err_json(&format!("{e:#}"))),
                                Ok(handle) if stream_mode => {
                                    // the chunked event relay owns the
                                    // socket from here on
                                    handle_generate_stream(
                                        &mut stream,
                                        &handle,
                                        gen_method,
                                        arrived,
                                    );
                                    return;
                                }
                                Ok(handle) => {
                                    handle_generate(&handle, gen_method)
                                }
                            }
                        }
                    }
                }
                ("GET", "/metrics") => match router.metrics() {
                    Ok(j) => (200, j.to_string()),
                    Err(e) => (500, err_json(&format!("{e:#}"))),
                },
                ("GET", "/healthz") => match router.health() {
                    Ok(j) => (200, j.to_string()),
                    Err(e) => (500, err_json(&format!("{e:#}"))),
                },
                _ => (404, err_json("not found")),
            };
            respond(&mut stream, status, &body);
        });
    }
    Ok(())
}
