//! Minimal HTTP front-end (std::net; no external HTTP stack offline).

pub mod http;

pub use http::{serve, serve_on, serve_on_until};
