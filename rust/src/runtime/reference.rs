//! Deterministic pure-Rust reference backend.
//!
//! A stand-in "model" that gives the serving stack real shapes, real
//! control flow, and fully reproducible outputs with zero artifacts:
//! every proposal is a pure function of (backend seed, model weights
//! seed, decode history), so the same seed yields an identical decode
//! trace on any machine — the property the golden tests pin.
//!
//! Decode history flows through the KV cache exactly like in the real
//! model: each cached position `p` stores a 24-bit *context hash* of
//! the token prefix that produced it at `k[l, lane, 0, p, 0]` (f32
//! holds 24-bit integers exactly). Prefill seeds the chain from the
//! prompt; block/step programs read the hash at `cache_len - 1`
//! straight out of the borrowed [`KvView`] (zero-copy — no staging
//! buffer ever exists on this path), extend it over their input tokens,
//! and emit it in their block KV — so KV pool bugs (wrong lane offsets,
//! missed commits, stale views) change decoded tokens and are caught by
//! the parity tests rather than silently ignored. Consequences
//! engineered into the proposals:
//!
//! * `teacher_denoise` ≡ `teacher_full_cache` on identical inputs
//!   (the dLLM-Cache `refresh_every = 1` anchor);
//! * per-lane outputs depend only on that lane's content
//!   (batched == solo decode);
//! * `ar_prefill`/`ar_step`/`ar_verify` share one next-token chain
//!   (speculative decoding is lossless vs AR greedy);
//! * the student's confidence distribution is sharper than the
//!   teacher's (CDLM finalizes multiple tokens per step, reproducing
//!   the paper's step-reduction shape).
//!
//! The backend holds no mutable state, so it is trivially `Send + Sync`
//! and reports full host parallelism to the chunk executor.
//!
//! Hot-path shape: every program writes into caller-owned (arena)
//! buffers via `reuse` — steady-state calls with stable shapes never
//! allocate. Proposal logits cross the seam as sparse
//! [`ProposalLogits`] peaks, and context hashes are written with a
//! batched per-lane pass: one contiguous layer-0 walk per lane, then a
//! cache-blocked SIMD fan-out across layers (`replicate_ctx`, backed
//! by [`crate::util::kernels::fanout_rows`]) that moves contiguous
//! lane rows instead of the old one-element-per-layer scatter that
//! recomputed the full 5-d index for every (layer, position) pair.
#![allow(clippy::too_many_arguments)]

use anyhow::Result;

use super::backend::Backend;
use super::kv::KvView;
use super::manifest::Geometry;
use super::programs::{
    ArPrefillOut, ArStepOut, BlockStepOut, DenoiseOut, FullCacheOut,
    PrefillOut, ProposalLogits,
};
use super::tensor::{TensorF32, TensorI32};
use crate::util::kernels;
use super::weights::ModelWeights;

/// Fixed default seed (override per-process with `CDLM_REF_SEED`).
pub const DEFAULT_SEED: u64 = 0xCD1A_2026;

/// Context hashes are truncated to 24 bits so they round-trip exactly
/// through f32 KV cache entries.
const CTX_MASK: u64 = 0x00FF_FFFF;

/// First printable (non-special) token id and the printable range size
/// (ids 4..57 carry characters in the compiled-in vocab).
const TOK_BASE: i32 = 4;
const TOK_RANGE: u64 = 53;

/// Every proposal peak crosses the seam with this logit value (the
/// reference head is a hard one-hot).
const PEAK_LOGIT: f32 = 5.0;

pub struct ReferenceBackend {
    geom: Geometry,
    seed: u64,
}

/// SplitMix64-style avalanche mix of two words.
fn mix(a: u64, b: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform f64 in [0, 1) from a hash.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Order-sensitive content hash of a token slice.
fn token_hash(ids: &[i32]) -> u64 {
    let mut h = 0x6A09_E667_F3BC_C908;
    for &t in ids {
        h = mix(h, t as u32 as u64);
    }
    h
}

/// Extend a 24-bit context hash by one committed token.
fn ctx_step(prev: u64, tok: i32) -> u64 {
    mix(prev, tok as u32 as u64) & CTX_MASK
}

/// Read the context hash stored at `(lane, pos)` of a KV view
/// (layer 0, head 0, feature 0) — a single zero-copy slab read.
fn view_ctx(kv: &KvView<'_>, lane: usize, pos: usize) -> u64 {
    kv.k_at(lane, 0, 0, pos, 0) as u64 & CTX_MASK
}

/// Replicate one lane's layer-0 context row across all layers of both
/// batch-major `[L, bs, H, len, dh]` stacks (head 0, feature 0), and
/// mirror it into `v`. The producer writes layer 0 of `k` with a
/// contiguous per-lane walk first; this pass fans the whole contiguous
/// `H*len*dh` lane row out with the cache-blocked SIMD kernel instead
/// of an `lstride`-strided single-element scatter. Byte-identity with
/// the scalar scatter holds because producers only ever write the
/// (head 0, feature 0) context slots of these arena buffers and every
/// other element is zero in both source and destination (zero-filled
/// at `reuse` shape changes, never dirtied afterwards).
fn replicate_ctx(
    k: &mut [f32],
    v: &mut [f32],
    l_n: usize,
    bs: usize,
    h_n: usize,
    len: usize,
    dh: usize,
    lane: usize,
) {
    let row = h_n * len * dh;
    kernels::fanout_rows(k, v, lane * row, row, l_n, bs * row);
}

impl ReferenceBackend {
    pub fn new(geom: Geometry, seed: u64) -> Self {
        Self { geom, seed }
    }

    fn model_seed(&self, w: &ModelWeights) -> u64 {
        mix(self.seed, w.seed)
    }

    /// DLM proposal for one position: token + confidence. The student
    /// head is sharper (multi-token finalization clears tau=0.9 often);
    /// the un-retrained teacher rarely does (top-1 per step in practice).
    fn dlm_propose(&self, ms: u64, h_pos: u64, student: bool) -> (i32, f32) {
        let r = mix(ms, h_pos);
        let tok = if r % 16 == 0 {
            self.geom.eos
        } else {
            TOK_BASE + (r % TOK_RANGE) as i32
        };
        let u = unit(mix(r, 0x5EED_C0DE));
        let conf = if student { 1.0 - 0.25 * u } else { 1.0 - 0.6 * u };
        (tok, conf as f32)
    }

    /// AR greedy continuation after the context `ctx`.
    fn ar_next(&self, ms: u64, ctx: u64) -> (i32, f32) {
        let r = mix(mix(ms, 0xA12_57E9), ctx);
        let tok = if r % 12 == 0 {
            self.geom.eos
        } else {
            TOK_BASE + (r % TOK_RANGE) as i32
        };
        let conf = (0.5 + 0.5 * unit(mix(r, 0xC0FF))) as f32;
        (tok, conf)
    }

    /// Chain start for a fresh sequence under model seed `ms`.
    fn ctx_root(&self, ms: u64) -> u64 {
        mix(ms, 0xB10C_CACE) & CTX_MASK
    }

    /// Walk one lane's committed-token chain over a borrowed id row,
    /// writing the per-position context hashes into layer 0 of the
    /// batch-major stacks with a contiguous stride walk, then fanning
    /// them out via [`replicate_ctx`]. Returns the final context hash.
    fn chain_lane(
        &self,
        ms: u64,
        ids: &[i32],
        lane: usize,
        bs: usize,
        len: usize,
        k: &mut [f32],
        v: &mut [f32],
    ) -> u64 {
        let g = &self.geom;
        let (l_n, h_n, dh) = (g.n_layers, g.n_heads, g.d_head);
        let mut ctx = self.ctx_root(ms);
        let mut off = lane * h_n * len * dh; // (l=0, lane, h=0, p=0, d=0)
        for &t in ids {
            ctx = ctx_step(ctx, t);
            k[off] = ctx as f32;
            off += dh;
        }
        replicate_ctx(k, v, l_n, bs, h_n, len, dh, lane);
        ctx
    }

    /// Committed-token context chains over all lanes of a `[bs, len]`
    /// id buffer (borrowed slices — no per-lane clones), emitted as KV
    /// stacks of the given position length into the reusable outputs.
    fn chain_kv(
        &self,
        ms: u64,
        bs: usize,
        len: usize,
        ids: &[i32],
        k: &mut TensorF32,
        v: &mut TensorF32,
    ) {
        let g = &self.geom;
        let (l_n, h_n, dh) = (g.n_layers, g.n_heads, g.d_head);
        k.reuse(&[l_n, bs, h_n, len, dh]);
        v.reuse(&[l_n, bs, h_n, len, dh]);
        for lane in 0..bs {
            self.chain_lane(
                ms,
                &ids[lane * len..(lane + 1) * len],
                lane,
                bs,
                len,
                &mut k.data,
                &mut v.data,
            );
        }
    }

    /// Full-sequence proposal shared by `teacher_denoise` and
    /// `teacher_full_cache` — both must emit identical tokens and
    /// confidences for identical inputs (the refresh_every=1 anchor).
    fn full_seq_propose(
        &self,
        w: &ModelWeights,
        bs: usize,
        ids: &TensorI32,
        logits: &mut ProposalLogits,
        tok: &mut TensorI32,
        conf: &mut TensorF32,
    ) -> Result<()> {
        let (s, v) = (self.geom.seq_len, self.geom.vocab_size);
        anyhow::ensure!(
            ids.data.len() == bs * s,
            "teacher ids must be [bs={bs}, S={s}], got {} elements",
            ids.data.len()
        );
        let ms = self.model_seed(w);
        logits.reuse(bs * s, v);
        tok.reuse(&[bs, s]);
        conf.reuse(&[bs, s]);
        for lane in 0..bs {
            let row = &ids.data[lane * s..(lane + 1) * s];
            let lh = token_hash(row);
            for p in 0..s {
                let (t, c) = self.dlm_propose(ms, mix(lh, p as u64), false);
                tok.data[lane * s + p] = t;
                conf.data[lane * s + p] = c;
                logits.set(lane * s + p, t, PEAK_LOGIT);
            }
        }
        Ok(())
    }

    /// Shared implementation of the two DLM block programs: a batched
    /// per-lane pass (proposals + layer-0 context chain in one walk,
    /// then the stride-walk layer replication).
    fn dlm_block_step(
        &self,
        w: &ModelWeights,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        ctx_pos: usize,
        blk_ids: &TensorI32,
        pos0: i32,
        student: bool,
        out: &mut BlockStepOut,
    ) -> Result<()> {
        let g = &self.geom;
        let (l_n, h_n, dh, v) =
            (g.n_layers, g.n_heads, g.d_head, g.vocab_size);
        anyhow::ensure!(
            blk_ids.data.len() == bs * block,
            "block ids must be [bs={bs}, B={block}]"
        );
        anyhow::ensure!(
            kv.bs() == bs,
            "KV view has {} lanes, batch is {bs}",
            kv.bs()
        );
        let ms = self.model_seed(w);
        out.logits.reuse(bs * block, v);
        out.tok.reuse(&[bs, block]);
        out.conf.reuse(&[bs, block]);
        out.k_blk.reuse(&[l_n, bs, h_n, block, dh]);
        out.v_blk.reuse(&[l_n, bs, h_n, block, dh]);
        for lane in 0..bs {
            let row = &blk_ids.data[lane * block..(lane + 1) * block];
            let ctx_prev = view_ctx(kv, lane, ctx_pos);
            let bh = mix(token_hash(row), ctx_prev);
            let mut ctx = ctx_prev;
            let mut off = lane * h_n * block * dh; // layer-0 walk
            for (i, &t_in) in row.iter().enumerate() {
                let h_pos = mix(bh, (pos0 as u64) + i as u64);
                let (t, c) = self.dlm_propose(ms, h_pos, student);
                out.tok.data[lane * block + i] = t;
                out.conf.data[lane * block + i] = c;
                out.logits.set(lane * block + i, t, PEAK_LOGIT);
                // commit chain over the *input* tokens: when the engine
                // re-runs this program on final tokens, the emitted KV is
                // the exact committed-prefix chain
                ctx = ctx_step(ctx, t_in);
                out.k_blk.data[off] = ctx as f32;
                off += dh;
            }
            replicate_ctx(
                &mut out.k_blk.data,
                &mut out.v_blk.data,
                l_n,
                bs,
                h_n,
                block,
                dh,
                lane,
            );
        }
        Ok(())
    }
}

impl Backend for ReferenceBackend {
    fn platform(&self) -> String {
        "cpu".to_string()
    }

    fn name(&self) -> &'static str {
        "reference"
    }

    fn max_concurrency(&self) -> usize {
        // stateless host execution: safe at any parallelism (the
        // executors pick a useful default from the machine size)
        usize::MAX
    }

    fn teacher_denoise(
        &self,
        w: &ModelWeights,
        bs: usize,
        ids: &TensorI32,
        _valid_from: &TensorI32,
        out: &mut DenoiseOut,
    ) -> Result<()> {
        self.full_seq_propose(
            w,
            bs,
            ids,
            &mut out.logits,
            &mut out.tok,
            &mut out.conf,
        )
    }

    fn teacher_full_cache(
        &self,
        w: &ModelWeights,
        bs: usize,
        ids: &TensorI32,
        _valid_from: &TensorI32,
        out: &mut FullCacheOut,
    ) -> Result<()> {
        self.full_seq_propose(
            w,
            bs,
            ids,
            &mut out.logits,
            &mut out.tok,
            &mut out.conf,
        )?;
        let s = self.geom.seq_len;
        let ms = self.model_seed(w);
        self.chain_kv(ms, bs, s, &ids.data, &mut out.k, &mut out.v);
        Ok(())
    }

    fn teacher_block_approx(
        &self,
        w: &ModelWeights,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        _valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()> {
        anyhow::ensure!(pos0 >= 1, "block cannot start at position 0");
        self.dlm_block_step(
            w,
            bs,
            block,
            kv,
            (pos0 - 1) as usize,
            blk_ids,
            pos0,
            false,
            out,
        )
    }

    fn student_prefill(
        &self,
        w: &ModelWeights,
        bs: usize,
        prompt_ids: &TensorI32,
        _valid_from: &TensorI32,
        out: &mut PrefillOut,
    ) -> Result<()> {
        let p = self.geom.prompt_len;
        anyhow::ensure!(
            prompt_ids.data.len() == bs * p,
            "prompt ids must be [bs={bs}, P={p}]"
        );
        let ms = self.model_seed(w);
        self.chain_kv(ms, bs, p, &prompt_ids.data, &mut out.k, &mut out.v);
        Ok(())
    }

    fn student_block_step(
        &self,
        w: &ModelWeights,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        _valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()> {
        let cache_len = kv.cache_len();
        anyhow::ensure!(cache_len >= 1, "student cache cannot be empty");
        self.dlm_block_step(
            w,
            bs,
            block,
            kv,
            cache_len - 1,
            blk_ids,
            pos0,
            true,
            out,
        )
    }

    fn ar_verify(
        &self,
        w: &ModelWeights,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        _valid_from: &TensorI32,
        blk_ids: &TensorI32,
        _pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()> {
        let g = &self.geom;
        let (l_n, h_n, dh, v) =
            (g.n_layers, g.n_heads, g.d_head, g.vocab_size);
        let cache_len = kv.cache_len();
        anyhow::ensure!(cache_len >= 1, "AR cache cannot be empty");
        anyhow::ensure!(
            blk_ids.data.len() == bs * block,
            "block ids must be [bs={bs}, B={block}]"
        );
        anyhow::ensure!(kv.bs() == bs, "KV view lane count mismatch");
        let ms = self.model_seed(w);
        out.logits.reuse(bs * block, v);
        out.tok.reuse(&[bs, block]);
        out.conf.reuse(&[bs, block]);
        out.k_blk.reuse(&[l_n, bs, h_n, block, dh]);
        out.v_blk.reuse(&[l_n, bs, h_n, block, dh]);
        for lane in 0..bs {
            let row = &blk_ids.data[lane * block..(lane + 1) * block];
            let mut ctx = view_ctx(kv, lane, cache_len - 1);
            let mut off = lane * h_n * block * dh; // layer-0 walk
            for (i, &t_in) in row.iter().enumerate() {
                // teacher-forced: extend the chain by draft token i, then
                // emit AR's greedy continuation *after* it
                ctx = ctx_step(ctx, t_in);
                let (t, c) = self.ar_next(ms, ctx);
                out.tok.data[lane * block + i] = t;
                out.conf.data[lane * block + i] = c;
                out.logits.set(lane * block + i, t, PEAK_LOGIT);
                out.k_blk.data[off] = ctx as f32;
                off += dh;
            }
            replicate_ctx(
                &mut out.k_blk.data,
                &mut out.v_blk.data,
                l_n,
                bs,
                h_n,
                block,
                dh,
                lane,
            );
        }
        Ok(())
    }

    fn ar_prefill(
        &self,
        w: &ModelWeights,
        bs: usize,
        prompt_ids: &TensorI32,
        _valid_from: &TensorI32,
        out: &mut ArPrefillOut,
    ) -> Result<()> {
        let g = &self.geom;
        let (p, v) = (g.prompt_len, g.vocab_size);
        let (l_n, h_n, dh) = (g.n_layers, g.n_heads, g.d_head);
        anyhow::ensure!(
            prompt_ids.data.len() == bs * p,
            "prompt ids must be [bs={bs}, P={p}]"
        );
        let ms = self.model_seed(w);
        out.k.reuse(&[l_n, bs, h_n, p, dh]);
        out.v.reuse(&[l_n, bs, h_n, p, dh]);
        out.logits.reuse(bs, v);
        out.tok.reuse(&[bs]);
        out.conf.reuse(&[bs]);
        for lane in 0..bs {
            let last = self.chain_lane(
                ms,
                &prompt_ids.data[lane * p..(lane + 1) * p],
                lane,
                bs,
                p,
                &mut out.k.data,
                &mut out.v.data,
            );
            let (t, c) = self.ar_next(ms, last);
            out.tok.data[lane] = t;
            out.conf.data[lane] = c;
            out.logits.set(lane, t, PEAK_LOGIT);
        }
        Ok(())
    }

    fn ar_step(
        &self,
        w: &ModelWeights,
        bs: usize,
        kv: &KvView<'_>,
        _valid_from: &TensorI32,
        tok_ids: &TensorI32,
        out: &mut ArStepOut,
    ) -> Result<()> {
        let g = &self.geom;
        let (l_n, h_n, dh, v) =
            (g.n_layers, g.n_heads, g.d_head, g.vocab_size);
        let cache_len = kv.cache_len();
        anyhow::ensure!(cache_len >= 1, "AR cache cannot be empty");
        anyhow::ensure!(tok_ids.data.len() == bs, "tok ids must be [bs]");
        anyhow::ensure!(kv.bs() == bs, "KV view lane count mismatch");
        let ms = self.model_seed(w);
        out.logits.reuse(bs, v);
        out.tok.reuse(&[bs]);
        out.conf.reuse(&[bs]);
        out.k1.reuse(&[l_n, bs, h_n, 1, dh]);
        out.v1.reuse(&[l_n, bs, h_n, 1, dh]);
        for lane in 0..bs {
            let prev = view_ctx(kv, lane, cache_len - 1);
            let ctx = ctx_step(prev, tok_ids.data[lane]);
            let (t, c) = self.ar_next(ms, ctx);
            out.tok.data[lane] = t;
            out.conf.data[lane] = c;
            out.logits.set(lane, t, PEAK_LOGIT);
            out.k1.data[lane * h_n * dh] = ctx as f32;
            replicate_ctx(
                &mut out.k1.data,
                &mut out.v1.data,
                l_n,
                bs,
                h_n,
                1,
                dh,
                lane,
            );
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::path::Path;

    use crate::runtime::kv::KvDims;
    use crate::runtime::Manifest;

    fn backend() -> ReferenceBackend {
        let m = Manifest::reference(Path::new("ref"));
        ReferenceBackend::new(m.geometry, 7)
    }

    fn weights() -> ModelWeights {
        let m = Manifest::reference(Path::new("ref"));
        ModelWeights::load(&m, "cdlm_dream").unwrap()
    }

    #[test]
    fn denoise_equals_full_cache_proposals() {
        let b = backend();
        let w = weights();
        let g = Manifest::reference(Path::new("ref")).geometry;
        let ids = TensorI32::from_vec(
            &[1, g.seq_len],
            (0..g.seq_len as i32).map(|i| i % 50).collect(),
        );
        let vf = TensorI32::from_vec(&[1], vec![0]);
        let mut d = DenoiseOut::default();
        b.teacher_denoise(&w, 1, &ids, &vf, &mut d).unwrap();
        let mut f = FullCacheOut::default();
        b.teacher_full_cache(&w, 1, &ids, &vf, &mut f).unwrap();
        assert_eq!(d.tok.data, f.tok.data);
        assert_eq!(d.conf.data, f.conf.data);
        assert_eq!(d.logits, f.logits);
    }

    #[test]
    fn lanes_are_independent() {
        let b = backend();
        let w = weights();
        let g = Manifest::reference(Path::new("ref")).geometry;
        let s = g.seq_len;
        let row_a: Vec<i32> = (0..s as i32).map(|i| 4 + i % 40).collect();
        let row_b: Vec<i32> = (0..s as i32).map(|i| 4 + (i * 7) % 40).collect();
        let vf1 = TensorI32::from_vec(&[1], vec![0]);
        let vf2 = TensorI32::from_vec(&[2], vec![0, 0]);
        let mut solo = DenoiseOut::default();
        b.teacher_denoise(
            &w,
            1,
            &TensorI32::from_vec(&[1, s], row_b.clone()),
            &vf1,
            &mut solo,
        )
        .unwrap();
        let mut both_ids = row_a.clone();
        both_ids.extend_from_slice(&row_b);
        let mut both = DenoiseOut::default();
        b.teacher_denoise(
            &w,
            2,
            &TensorI32::from_vec(&[2, s], both_ids),
            &vf2,
            &mut both,
        )
        .unwrap();
        assert_eq!(&both.tok.data[s..], &solo.tok.data[..]);
    }

    #[test]
    fn prefill_chain_is_readable_by_block_step() {
        let b = backend();
        let w = weights();
        let g = Manifest::reference(Path::new("ref")).geometry;
        let (p, blk) = (g.prompt_len, g.block_size);
        let prompt = TensorI32::from_vec(&[1, p], vec![5; p]);
        let vf = TensorI32::from_vec(&[1], vec![0]);
        let mut pre = PrefillOut::default();
        b.student_prefill(&w, 1, &prompt, &vf, &mut pre).unwrap();
        // the last prompt position carries a nonzero context hash
        // (prefill output is batch-major [L, 1, H, P, dh]; the hash
        // lives at layer 0, head 0, feature 0)
        let ctx = pre.k.data[(p - 1) * g.d_head] as u64 & CTX_MASK;
        assert_ne!(ctx, 0);
        // widen prompt KV into a lane-major [L, H, S, dh] slot and view
        // it: each (l, h) row is a contiguous P*dh run in the prefill
        // output and an S*dh-strided run in the slot, so the whole
        // widening is one uniform-stride 2-D kernel copy
        let dims = KvDims::of(&g);
        let mut k_slab = vec![0.0f32; dims.slot_elems()];
        kernels::copy_2d(
            &mut k_slab,
            0,
            g.seq_len * g.d_head,
            &pre.k.data,
            0,
            p * g.d_head,
            g.n_layers * g.n_heads,
            p * g.d_head,
        );
        let v_slab = k_slab.clone();
        let view = KvView::new(&k_slab, &v_slab, &[0], dims, p);
        let blk_ids = TensorI32::from_vec(&[1, blk], vec![1; blk]);
        let mut out = BlockStepOut::default();
        b.student_block_step(&w, 1, blk, &view, &vf, &blk_ids, p as i32, &mut out)
            .unwrap();
        assert_eq!(out.tok.data.len(), blk);
        // deterministic: same call, same outputs — including into a
        // dirty reused output struct
        let mut again = BlockStepOut::default();
        b.student_block_step(
            &w, 1, blk, &view, &vf, &blk_ids, p as i32, &mut again,
        )
        .unwrap();
        assert_eq!(out.tok.data, again.tok.data);
        assert_eq!(out.conf.data, again.conf.data);
        b.student_block_step(&w, 1, blk, &view, &vf, &blk_ids, p as i32, &mut out)
            .unwrap();
        assert_eq!(out.tok.data, again.tok.data);
        assert_eq!(out.k_blk.data, again.k_blk.data);
        assert_eq!(out.v_blk.data, again.v_blk.data);
    }

    #[test]
    fn sparse_logits_peak_matches_proposal() {
        let b = backend();
        let w = weights();
        let g = Manifest::reference(Path::new("ref")).geometry;
        let ids = TensorI32::from_vec(
            &[1, g.seq_len],
            (0..g.seq_len as i32).map(|i| 4 + i % 40).collect(),
        );
        let vf = TensorI32::from_vec(&[1], vec![0]);
        let mut d = DenoiseOut::default();
        b.teacher_denoise(&w, 1, &ids, &vf, &mut d).unwrap();
        for (row, &t) in d.tok.data.iter().enumerate() {
            assert_eq!(d.logits.peak(row), (t, 5.0));
        }
        // dense materialization stays one-hot
        let dense = d.logits.to_dense();
        assert_eq!(
            dense.data.iter().filter(|&&x| x != 0.0).count(),
            g.seq_len
        );
    }

    #[test]
    fn dirty_output_reuse_across_batch_shapes_is_clean() {
        // bs=2 fills wider buffers; a following bs=1 call into the same
        // (dirty) output struct must be byte-identical to a fresh one —
        // the arena-reuse contract the hot path relies on
        let b = backend();
        let w = weights();
        let g = Manifest::reference(Path::new("ref")).geometry;
        let s = g.seq_len;
        let row: Vec<i32> = (0..s as i32).map(|i| 4 + i % 37).collect();
        let mut two_ids = row.clone();
        two_ids.extend((0..s as i32).map(|i| 4 + (i * 3) % 37));
        let vf1 = TensorI32::from_vec(&[1], vec![0]);
        let vf2 = TensorI32::from_vec(&[2], vec![0, 0]);
        let mut dirty = FullCacheOut::default();
        b.teacher_full_cache(
            &w,
            2,
            &TensorI32::from_vec(&[2, s], two_ids),
            &vf2,
            &mut dirty,
        )
        .unwrap();
        b.teacher_full_cache(
            &w,
            1,
            &TensorI32::from_vec(&[1, s], row.clone()),
            &vf1,
            &mut dirty,
        )
        .unwrap();
        let mut fresh = FullCacheOut::default();
        b.teacher_full_cache(
            &w,
            1,
            &TensorI32::from_vec(&[1, s], row),
            &vf1,
            &mut fresh,
        )
        .unwrap();
        assert_eq!(dirty.tok.data, fresh.tok.data);
        assert_eq!(dirty.conf.data, fresh.conf.data);
        assert_eq!(dirty.k.data, fresh.k.data);
        assert_eq!(dirty.v.data, fresh.v.data);
    }

    #[test]
    fn student_confidence_sharper_than_teacher() {
        let b = backend();
        let clears = |student: bool| {
            (0..1000u64)
                .filter(|&i| b.dlm_propose(1, i, student).1 >= 0.9)
                .count()
        };
        let (cs, ct) = (clears(true), clears(false));
        assert!(cs > ct, "student {cs} must clear tau more often than {ct}");
    }

    #[test]
    fn tokens_stay_in_vocab() {
        let b = backend();
        let g = Manifest::reference(Path::new("ref")).geometry;
        for i in 0..500 {
            let (t, c) = b.dlm_propose(99, i, true);
            assert!(t == g.eos || (TOK_BASE..57).contains(&t));
            assert!((0.0..=1.0).contains(&c));
            let (t, _) = b.ar_next(99, i);
            assert!(t == g.eos || (TOK_BASE..57).contains(&t));
        }
    }
}
