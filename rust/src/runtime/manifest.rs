//! `artifacts/manifest.json` — the contract between the python build
//! path and the rust request path: model geometry, the canonical weight
//! argument order, and the AOT program table.
//!
//! When no artifacts directory exists the stack runs on the built-in
//! [`Manifest::reference`] manifest instead: the same schema, a toy
//! geometry matching the python fast-mode build, and a virtual program
//! table served by the deterministic reference backend.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct Geometry {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub block_size: usize,
    pub seq_len: usize,
    pub pad: i32,
    pub mask: i32,
    pub bos: i32,
    pub eos: i32,
}

impl Geometry {
    pub fn num_blocks(&self) -> usize {
        self.gen_len / self.block_size
    }
}

#[derive(Debug, Clone)]
pub struct ProgramEntry {
    pub name: String,
    pub bs: usize,
    pub block: Option<usize>,
    pub file: String,
    /// Input shapes (including the leading weight args).
    pub input_shapes: Vec<Vec<usize>>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub geometry: Geometry,
    pub weight_names: Vec<String>,
    pub buckets: Vec<usize>,
    pub sweep_blocks: Vec<usize>,
    pub programs: Vec<ProgramEntry>,
    pub models: Vec<(String, String)>,
    pub fast_mode: bool,
}

fn geti(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("{key} not a number"))
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = json::load(&dir.join("manifest.json"))?;
        let g = j.req("geometry")?;
        let geometry = Geometry {
            vocab_size: geti(g, "vocab_size")?,
            d_model: geti(g, "d_model")?,
            n_layers: geti(g, "n_layers")?,
            n_heads: geti(g, "n_heads")?,
            d_head: geti(g, "d_head")?,
            d_ff: geti(g, "d_ff")?,
            prompt_len: geti(g, "prompt_len")?,
            gen_len: geti(g, "gen_len")?,
            block_size: geti(g, "block_size")?,
            seq_len: geti(g, "seq_len")?,
            pad: geti(g, "pad")? as i32,
            mask: geti(g, "mask")? as i32,
            bos: geti(g, "bos")? as i32,
            eos: geti(g, "eos")? as i32,
        };
        let weight_names = j
            .req("weight_names")?
            .as_arr()
            .unwrap_or_default()
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect::<Vec<_>>();
        let buckets = j
            .req("buckets")?
            .as_arr()
            .unwrap_or_default()
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let sweep_blocks = j
            .get("sweep_blocks")
            .and_then(Json::as_arr)
            .unwrap_or_default()
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let mut programs = Vec::new();
        for p in j.req("programs")?.as_arr().unwrap_or_default() {
            programs.push(ProgramEntry {
                name: p.req("name")?.as_str().unwrap_or("").to_string(),
                bs: geti(p, "bs")?,
                block: p.get("block").and_then(Json::as_usize),
                file: p.req("file")?.as_str().unwrap_or("").to_string(),
                input_shapes: p
                    .req("inputs")?
                    .as_arr()
                    .unwrap_or_default()
                    .iter()
                    .map(|i| {
                        i.get("shape")
                            .and_then(Json::as_arr)
                            .unwrap_or_default()
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect()
                    })
                    .collect(),
            });
        }
        let models = j
            .req("models")?
            .as_obj()
            .map(|m| {
                m.iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                    .collect()
            })
            .unwrap_or_default();
        anyhow::ensure!(!programs.is_empty(), "manifest has no programs");
        anyhow::ensure!(!weight_names.is_empty(), "manifest has no weights");
        Ok(Manifest {
            dir: dir.to_path_buf(),
            geometry,
            weight_names,
            buckets,
            sweep_blocks,
            programs,
            models,
            fast_mode: j
                .get("fast_mode")
                .and_then(Json::as_bool)
                .unwrap_or(false),
        })
    }

    pub fn find_program(
        &self,
        name: &str,
        bs: usize,
        block: Option<usize>,
    ) -> Option<&ProgramEntry> {
        self.programs
            .iter()
            .find(|p| p.name == name && p.bs == bs && p.block == block)
    }

    /// Smallest exported batch bucket >= n.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().filter(|&b| b >= n).min()
    }

    pub fn model_weight_file(&self, model: &str) -> Option<&str> {
        self.models
            .iter()
            .find(|(k, _)| k == model)
            .map(|(_, v)| v.as_str())
    }

    /// Load `manifest.json` if present, else the built-in reference
    /// manifest (the artifact-free serving path).
    pub fn load_or_reference(dir: &Path) -> anyhow::Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::reference(dir))
        }
    }

    /// The built-in manifest backing the reference backend: the python
    /// fast-mode geometry, bucket/block grids matching the exported AOT
    /// set, and a virtual program table (no files behind the entries).
    pub fn reference(dir: &Path) -> Manifest {
        let geometry = Geometry {
            vocab_size: crate::tokenizer::VOCAB_SIZE,
            d_model: 96,
            n_layers: 3,
            n_heads: 4,
            d_head: 24,
            d_ff: 192,
            prompt_len: 64,
            gen_len: 32,
            block_size: 8,
            seq_len: 96,
            pad: crate::tokenizer::PAD,
            mask: crate::tokenizer::MASK,
            bos: crate::tokenizer::BOS,
            eos: crate::tokenizer::EOS,
        };
        let buckets = vec![1usize, 2, 4];
        let sweep_blocks = vec![2usize, 4, 16];
        let mut weight_names = vec![
            "embed".to_string(),
            "head".to_string(),
            "ln_f".to_string(),
        ];
        for l in 0..geometry.n_layers {
            // gated MLP (wg/wu/wd), matching the python param_shapes
            for part in [
                "attn_q", "attn_k", "attn_v", "attn_o", "mlp_wg", "mlp_wu",
                "mlp_wd", "ln1", "ln2",
            ] {
                weight_names.push(format!("layer{l}.{part}"));
            }
        }
        weight_names.sort();

        let mut programs = Vec::new();
        let mut push = |name: &str, bs: usize, block: Option<usize>| {
            let file = match block {
                Some(b) => format!("{name}_bs{bs}_b{b}.hlo.txt"),
                None => format!("{name}_bs{bs}.hlo.txt"),
            };
            programs.push(ProgramEntry {
                name: name.to_string(),
                bs,
                block,
                file,
                input_shapes: Vec::new(),
            });
        };
        for &bs in &buckets {
            push("teacher_denoise", bs, None);
            push("teacher_full_cache", bs, None);
            push("student_prefill", bs, None);
            push("ar_prefill", bs, None);
            push("ar_step", bs, None);
            push("student_block_step", bs, Some(geometry.block_size));
            push("teacher_block_approx", bs, Some(geometry.block_size));
            push("ar_verify", bs, Some(geometry.block_size));
        }
        // inference-time block-size sweep variants (Fig. 8) at bs=1
        for &b in &sweep_blocks {
            push("student_block_step", 1, Some(b));
        }

        let models = ["dream", "llada"]
            .iter()
            .flat_map(|backbone| {
                ["teacher", "cdlm", "ar"].iter().map(move |role| {
                    let name = format!("{role}_{backbone}");
                    let file = format!("weights_{name}.npz");
                    (name, file)
                })
            })
            .collect();

        Manifest {
            dir: dir.to_path_buf(),
            geometry,
            weight_names,
            buckets,
            sweep_blocks,
            programs,
            models,
            fast_mode: true,
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.geometry.seq_len, m.geometry.prompt_len + m.geometry.gen_len);
        assert!(m.geometry.gen_len % m.geometry.block_size == 0);
        assert!(m.find_program("student_block_step", 1,
                               Some(m.geometry.block_size)).is_some());
        assert!(m.find_program("teacher_denoise", 4, None).is_some());
        assert!(m.model_weight_file("cdlm_dream").is_some());
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(1), Some(1));
        assert_eq!(m.bucket_for(3), Some(4));
        assert_eq!(m.bucket_for(4), Some(4));
        assert_eq!(m.bucket_for(99), None);
    }

    #[test]
    fn reference_manifest_is_coherent() {
        let m = Manifest::reference(Path::new("/nonexistent"));
        let g = &m.geometry;
        assert_eq!(g.seq_len, g.prompt_len + g.gen_len);
        assert_eq!(g.d_model, g.n_heads * g.d_head);
        assert!(g.gen_len % g.block_size == 0);
        for &b in &m.sweep_blocks {
            assert!(g.gen_len % b == 0, "sweep block {b} must divide gen_len");
            assert!(
                m.find_program("student_block_step", 1, Some(b)).is_some(),
                "missing sweep variant B={b}"
            );
        }
        for &bs in &m.buckets {
            for name in ["teacher_denoise", "student_prefill", "ar_step"] {
                assert!(m.find_program(name, bs, None).is_some(), "{name}/{bs}");
            }
            for name in ["student_block_step", "teacher_block_approx", "ar_verify"] {
                assert!(
                    m.find_program(name, bs, Some(g.block_size)).is_some(),
                    "{name}/{bs}"
                );
            }
        }
        assert!(!m.weight_names.is_empty());
        for model in ["teacher_dream", "cdlm_dream", "ar_dream", "cdlm_llada"] {
            assert!(m.model_weight_file(model).is_some(), "{model}");
        }
    }

    #[test]
    fn reference_bucket_selection() {
        let m = Manifest::reference(Path::new("/nonexistent"));
        assert_eq!(m.bucket_for(1), Some(1));
        assert_eq!(m.bucket_for(3), Some(4));
        assert_eq!(m.bucket_for(4), Some(4));
        assert_eq!(m.bucket_for(99), None);
    }
}
