//! Host tensors: dense row-major f32/i32 buffers with explicit shapes.
//!
//! These are the interchange type across the [`crate::runtime::Backend`]
//! seam; the PJRT path (feature `pjrt`) adds `xla::Literal` conversions.

#[derive(Debug, Clone, Default, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Reshape in place for buffer reuse. When the shape is unchanged
    /// the contents are kept as-is (the writer overwrites every element
    /// it later exposes — the step-arena contract); on a shape change
    /// the buffer is zero-filled so no stale value from a differently
    /// shaped step can leak through. Never shrinks capacity, so a
    /// steady-state caller stops allocating after the first use of each
    /// shape's high-water mark: once capacity covers the new element
    /// count the zero-fill runs through the SIMD fill kernel with no
    /// allocator round trip.
    pub fn reuse(&mut self, shape: &[usize]) {
        if self.shape.as_slice() == shape {
            return;
        }
        let n: usize = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        if n <= self.data.len() {
            // shrink or same numel: keep the buffer, SIMD zero-fill
            self.data.truncate(n);
            crate::util::kernels::fill(&mut self.data, 0.0);
        } else {
            // grow: SIMD-zero the live prefix, extend the remainder
            // (allocates only past the high-water mark)
            crate::util::kernels::fill(&mut self.data, 0.0);
            self.data.resize(n, 0.0);
        }
    }
}

#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// In-place reshape-for-reuse; see [`TensorF32::reuse`].
    pub fn reuse(&mut self, shape: &[usize]) {
        if self.shape.as_slice() == shape {
            return;
        }
        let n: usize = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        if n <= self.data.len() {
            self.data.truncate(n);
            crate::util::kernels::fill_i32(&mut self.data, 0);
        } else {
            crate::util::kernels::fill_i32(&mut self.data, 0);
            self.data.resize(n, 0);
        }
    }
}

#[cfg(feature = "pjrt")]
mod literal {
    use super::{TensorF32, TensorI32};
    use anyhow::Result;

    impl TensorF32 {
        pub fn to_literal(&self) -> Result<xla::Literal> {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
        }

        /// Overwrite an existing literal's contents (shape must match).
        pub fn write_into(&self, lit: &mut xla::Literal) -> Result<()> {
            lit.copy_raw_from(&self.data)?;
            Ok(())
        }

        pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> =
                shape.dims().iter().map(|&d| d as usize).collect();
            Ok(Self { shape: dims, data: lit.to_vec::<f32>()? })
        }
    }

    impl TensorI32 {
        pub fn to_literal(&self) -> Result<xla::Literal> {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
        }

        pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
            let shape = lit.array_shape()?;
            let dims: Vec<usize> =
                shape.dims().iter().map(|&d| d as usize).collect();
            Ok(Self { shape: dims, data: lit.to_vec::<i32>()? })
        }
    }

    pub fn scalar_i32(v: i32) -> xla::Literal {
        xla::Literal::scalar(v)
    }
}

#[cfg(feature = "pjrt")]
pub use literal::scalar_i32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_numel() {
        let t = TensorF32::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert!(t.data.iter().all(|&x| x == 0.0));
        let i = TensorI32::zeros(&[5]);
        assert_eq!(i.numel(), 5);
    }

    #[test]
    fn from_vec_keeps_shape_and_data() {
        let t = TensorF32::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.data[4], 5.0);
        let i = TensorI32::from_vec(&[4], vec![1, -2, 3, 4]);
        assert_eq!(i.data, vec![1, -2, 3, 4]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        TensorF32::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    #[should_panic]
    fn i32_shape_mismatch_panics() {
        TensorI32::from_vec(&[3], vec![1, 2]);
    }

    #[test]
    fn reuse_keeps_same_shape_contents_and_zeroes_on_change() {
        let mut t = TensorF32::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        t.reuse(&[2, 2]);
        assert_eq!(t.data, vec![1., 2., 3., 4.], "same shape: kept");
        t.reuse(&[3, 2]);
        assert_eq!(t.shape, vec![3, 2]);
        assert!(t.data.iter().all(|&x| x == 0.0), "shape change: zeroed");
        assert_eq!(t.numel(), 6);
        let mut i = TensorI32::from_vec(&[2], vec![7, 8]);
        i.reuse(&[1]);
        assert_eq!(i.data, vec![0]);
        i.reuse(&[1]);
        assert_eq!(i.shape, vec![1]);
    }
}
