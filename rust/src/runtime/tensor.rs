//! Host tensors + conversion to/from `xla::Literal`.
//!
//! The hot path reuses `Literal`s in place (`copy_raw_from`) to avoid
//! per-step allocation; see `coordinator::methods` for usage.

use anyhow::Result;

#[derive(Debug, Clone)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    /// Overwrite an existing literal's contents (shape must match).
    pub fn write_into(&self, lit: &mut xla::Literal) -> Result<()> {
        lit.copy_raw_from(&self.data)?;
        Ok(())
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(Self { shape: dims, data: lit.to_vec::<f32>()? })
    }
}

#[derive(Debug, Clone)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(&self.data).reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(Self { shape: dims, data: lit.to_vec::<i32>()? })
    }
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_through_literal() {
        let t = TensorF32::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = t.to_literal().unwrap();
        let back = TensorF32::from_literal(&l).unwrap();
        assert_eq!(back.shape, vec![2, 3]);
        assert_eq!(back.data, t.data);
    }

    #[test]
    fn i32_roundtrip() {
        let t = TensorI32::from_vec(&[4], vec![1, -2, 3, 4]);
        let back = TensorI32::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.data, t.data);
    }

    #[test]
    fn write_into_reuses_literal() {
        let t = TensorF32::zeros(&[8]);
        let mut l = t.to_literal().unwrap();
        let t2 = TensorF32::from_vec(&[8], (0..8).map(|i| i as f32).collect());
        t2.write_into(&mut l).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), t2.data);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        TensorF32::from_vec(&[2, 2], vec![1.0]);
    }
}
