//! The execution-backend seam: everything above this trait (engines,
//! scheduler, router, server, benches) is backend-agnostic.
//!
//! Two implementations exist:
//! * [`crate::runtime::ReferenceBackend`] — deterministic pure-Rust
//!   stand-in model (seeded hash chains, real tensor shapes); the
//!   default whenever no AOT artifacts directory is present, so the
//!   full serving stack builds and runs hermetically on any machine;
//! * `PjrtBackend` (feature `pjrt`) — the original PJRT/XLA path that
//!   executes the AOT-compiled JAX/Pallas programs.
//!
//! Program inputs/outputs cross the trait as host
//! `TensorF32`/`TensorI32`; KV caches cross it as borrowed
//! zero-copy [`KvView`]s over the coordinator's lane-major slabs.
//! Backends that need the batch-major `[L, bs, H, S, dh]` device layout
//! materialize it internally (`KvView::to_batch_major`); host backends
//! read positions straight out of the slabs.
//!
//! Backends are `Send + Sync`: the scheduler's parallel chunk executor
//! and the router's concurrent group dispatch issue program calls from
//! multiple threads, bounded by [`Backend::max_concurrency`].
#![allow(clippy::too_many_arguments)]

use std::path::Path;

use anyhow::Result;

use super::kv::KvView;
use super::manifest::Manifest;
use super::pjrt::ProgramKey;
use super::programs::{
    ArPrefillOut, ArStepOut, BlockStepOut, DenoiseOut, FullCacheOut,
    PrefillOut,
};
use super::reference::{ReferenceBackend, DEFAULT_SEED};
use super::tensor::TensorI32;
use super::weights::ModelWeights;

/// One executable model surface: the eight AOT program entry points of
/// `python/compile/model.py`, plus backend lifecycle hooks.
pub trait Backend: Send + Sync {
    /// Device platform label (the reference backend reports "cpu", like
    /// the PJRT CPU client it stands in for).
    fn platform(&self) -> String;

    /// Short backend identity for logs/manifest summaries.
    fn name(&self) -> &'static str;

    /// Number of compiled executables held (0 for non-compiling backends).
    fn compiled_count(&self) -> usize {
        0
    }

    /// Upper bound on concurrent program executions the backend
    /// supports. 1 means "serialize every call on one thread" and
    /// disables the parallel chunk/group executors above the seam.
    fn max_concurrency(&self) -> usize {
        1
    }

    /// Pre-compile a program set (no-op where compilation is free).
    fn warmup(&self, _keys: &[ProgramKey]) -> Result<()> {
        Ok(())
    }

    /// Make a model's weights resident on the device (no-op where the
    /// distinction does not exist).
    fn upload(&self, _weights: &ModelWeights) -> Result<()> {
        Ok(())
    }

    /// One bidirectional refinement pass over the full padded sequence.
    ///
    /// All program methods are writer-style: the caller owns the output
    /// struct (see [`crate::runtime::StepArena`]) and the backend fills
    /// it in place, reusing its buffers. Steady-state calls with stable
    /// shapes must not allocate.
    fn teacher_denoise(
        &self,
        w: &ModelWeights,
        bs: usize,
        ids: &TensorI32,        // [bs, S]
        valid_from: &TensorI32, // [bs]
        out: &mut DenoiseOut,
    ) -> Result<()>;

    /// Full pass that also returns the KV stacks (approx-cache refresh).
    fn teacher_full_cache(
        &self,
        w: &ModelWeights,
        bs: usize,
        ids: &TensorI32,
        valid_from: &TensorI32,
        out: &mut FullCacheOut,
    ) -> Result<()>;

    /// Block-scoped teacher step against a stale full-sequence cache
    /// (the view's valid prefix spans the whole sequence).
    fn teacher_block_approx(
        &self,
        w: &ModelWeights,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32, // [bs, B]
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()>;

    /// Student prompt prefill: exact prompt KV.
    fn student_prefill(
        &self,
        w: &ModelWeights,
        bs: usize,
        prompt_ids: &TensorI32, // [bs, P]
        valid_from: &TensorI32,
        out: &mut PrefillOut,
    ) -> Result<()>;

    /// Student block refinement step under the exact cache; the view's
    /// `cache_len` is the committed-prefix length.
    fn student_block_step(
        &self,
        w: &ModelWeights,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()>;

    /// Parallel AR verification of a drafted block (Appendix C).
    fn ar_verify(
        &self,
        w: &ModelWeights,
        bs: usize,
        block: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        blk_ids: &TensorI32,
        pos0: i32,
        out: &mut BlockStepOut,
    ) -> Result<()>;

    /// Causal prompt prefill + first-token logits.
    fn ar_prefill(
        &self,
        w: &ModelWeights,
        bs: usize,
        prompt_ids: &TensorI32,
        valid_from: &TensorI32,
        out: &mut ArPrefillOut,
    ) -> Result<()>;

    /// One causal decode step with an exact token-level cache.
    fn ar_step(
        &self,
        w: &ModelWeights,
        bs: usize,
        kv: &KvView<'_>,
        valid_from: &TensorI32,
        tok_ids: &TensorI32, // [bs]
        out: &mut ArStepOut,
    ) -> Result<()>;
}

/// The runtime a `ServingCore` owns: a manifest plus the backend that
/// executes it.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
}

impl Runtime {
    /// Load from an artifacts directory. If `manifest.json` is present
    /// and the `pjrt` feature is compiled in, the PJRT path executes the
    /// AOT programs; otherwise the deterministic reference backend
    /// serves the (real or built-in) manifest.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load_or_reference(artifacts_dir)?;
        let backend = Self::pick_backend(&manifest, artifacts_dir)?;
        Ok(Runtime { manifest, backend })
    }

    #[cfg(feature = "pjrt")]
    fn pick_backend(
        manifest: &Manifest,
        artifacts_dir: &Path,
    ) -> Result<Box<dyn Backend>> {
        if artifacts_dir.join("manifest.json").exists() {
            Ok(Box::new(super::pjrt::PjrtBackend::load(manifest)?))
        } else {
            Ok(Box::new(ReferenceBackend::new(
                manifest.geometry.clone(),
                reference_seed(),
            )))
        }
    }

    #[cfg(not(feature = "pjrt"))]
    fn pick_backend(
        manifest: &Manifest,
        _artifacts_dir: &Path,
    ) -> Result<Box<dyn Backend>> {
        Ok(Box::new(ReferenceBackend::new(
            manifest.geometry.clone(),
            reference_seed(),
        )))
    }

    /// A reference-backend runtime with an explicit seed (tests pin
    /// decode traces through this constructor).
    pub fn reference(seed: u64) -> Runtime {
        let manifest = Manifest::reference(Path::new("reference"));
        let backend: Box<dyn Backend> = Box::new(ReferenceBackend::new(
            manifest.geometry.clone(),
            seed,
        ));
        Runtime { manifest, backend }
    }

    pub fn backend(&self) -> &dyn Backend {
        &*self.backend
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn compiled_count(&self) -> usize {
        self.backend.compiled_count()
    }

    /// Pre-compile the given programs (serving warm-up).
    pub fn warmup(&self, keys: &[ProgramKey]) -> Result<()> {
        self.backend.warmup(keys)
    }
}

/// Reference-backend seed: `CDLM_REF_SEED` override or the fixed
/// default (decode traces are reproducible across machines).
fn reference_seed() -> u64 {
    std::env::var("CDLM_REF_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_SEED)
}
