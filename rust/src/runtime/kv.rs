//! Zero-copy KV-cache views: the read side of the backend seam.
//!
//! A [`KvView`] is a borrowed, `cache_len`-bounded window over the
//! coordinator's lane-major KV slabs (`coordinator::kv_cache::KvPool`).
//! Each lane's slot is one contiguous `[L, H, S, dh]` region, so a view
//! is just the two slab borrows plus a per-lane base offset — creating
//! one copies no cache data. Engines hand views straight to the backend
//! every program call; backends that execute on the host (the reference
//! backend) read individual positions through the accessors, and
//! backends that need a device layout (PJRT) materialize the batch-major
//! `[L, bs, H, S, dh]` buffer behind the seam with
//! [`KvView::to_batch_major`] — the one place the old per-step
//! `gather_batch` cost still exists, and only for that backend.
//!
//! `cache_len` is the lockstep valid-prefix length: positions
//! `>= cache_len` are stale slab content (slots are not zeroed on free)
//! and reads there are a bug the debug assertions catch.

use super::tensor::TensorF32;

/// Per-slot layout dimensions: one lane's slot is `[L, H, S, dh]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvDims {
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub d_head: usize,
}

impl KvDims {
    pub fn of(geom: &super::manifest::Geometry) -> KvDims {
        KvDims {
            n_layers: geom.n_layers,
            n_heads: geom.n_heads,
            seq_len: geom.seq_len,
            d_head: geom.d_head,
        }
    }

    /// Elements in one lane's slot.
    pub fn slot_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.seq_len * self.d_head
    }
}

/// Borrowed view of a batch's KV caches: lane-major slabs, valid-prefix
/// bounded. See the module docs for the layout contract.
pub struct KvView<'a> {
    k: &'a [f32],
    v: &'a [f32],
    /// Per-lane base offset of the lane's `[L, H, S, dh]` slot within
    /// the slabs.
    bases: Vec<usize>,
    dims: KvDims,
    cache_len: usize,
}

impl<'a> KvView<'a> {
    /// Build a view over lane-major slabs. `bases[lane]` is the element
    /// offset of that lane's slot; every slot must fit inside both
    /// slabs.
    pub fn new(
        k: &'a [f32],
        v: &'a [f32],
        bases: Vec<usize>,
        dims: KvDims,
        cache_len: usize,
    ) -> KvView<'a> {
        debug_assert!(cache_len <= dims.seq_len, "cache_len beyond slot");
        debug_assert!(bases
            .iter()
            .all(|&b| b + dims.slot_elems() <= k.len()
                && b + dims.slot_elems() <= v.len()));
        KvView { k, v, bases, dims, cache_len }
    }

    /// Number of lanes in the view.
    pub fn bs(&self) -> usize {
        self.bases.len()
    }

    /// Valid-prefix length: positions `< cache_len` are committed.
    pub fn cache_len(&self) -> usize {
        self.cache_len
    }

    pub fn dims(&self) -> KvDims {
        self.dims
    }

    #[inline]
    fn idx(&self, lane: usize, l: usize, h: usize, pos: usize, d: usize) -> usize {
        debug_assert!(pos < self.cache_len, "read past valid prefix");
        let g = &self.dims;
        self.bases[lane]
            + ((l * g.n_heads + h) * g.seq_len + pos) * g.d_head
            + d
    }

    /// One K element at `(lane, layer, head, pos, feature)`.
    #[inline]
    pub fn k_at(&self, lane: usize, l: usize, h: usize, pos: usize, d: usize) -> f32 {
        self.k[self.idx(lane, l, h, pos, d)]
    }

    /// One V element at `(lane, layer, head, pos, feature)`.
    #[inline]
    pub fn v_at(&self, lane: usize, l: usize, h: usize, pos: usize, d: usize) -> f32 {
        self.v[self.idx(lane, l, h, pos, d)]
    }

    /// Materialize the batch-major `[L, bs, H, S, dh]` K/V pair the AOT
    /// programs consume. This is the full copy the engines no longer
    /// perform; only device backends (PJRT) pay it, behind the seam.
    pub fn to_batch_major(&self) -> (TensorF32, TensorF32) {
        let g = &self.dims;
        let (l_n, h_n, s_n, dh) = (g.n_layers, g.n_heads, g.seq_len, g.d_head);
        let bs = self.bases.len();
        let mut k = TensorF32::zeros(&[l_n, bs, h_n, s_n, dh]);
        let mut v = TensorF32::zeros(&[l_n, bs, h_n, s_n, dh]);
        let row = s_n * dh;
        for (lane, &base) in self.bases.iter().enumerate() {
            for l in 0..l_n {
                for h in 0..h_n {
                    let src = base + (l * h_n + h) * row;
                    let dst = ((l * bs + lane) * h_n + h) * row;
                    k.data[dst..dst + row]
                        .copy_from_slice(&self.k[src..src + row]);
                    v.data[dst..dst + row]
                        .copy_from_slice(&self.v[src..src + row]);
                }
            }
        }
        (k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> KvDims {
        KvDims { n_layers: 2, n_heads: 2, seq_len: 4, d_head: 3 }
    }

    #[test]
    fn view_reads_lane_major_slots() {
        let d = dims();
        let n = d.slot_elems();
        // two slots: slot 0 holds its flat index, slot 1 holds +1000
        let mut k: Vec<f32> = (0..n).map(|i| i as f32).collect();
        k.extend((0..n).map(|i| 1000.0 + i as f32));
        let v: Vec<f32> = k.iter().map(|x| x + 0.5).collect();
        // lanes swapped relative to slot order
        let view = KvView::new(&k, &v, vec![n, 0], d, 4);
        assert_eq!(view.bs(), 2);
        // lane 0 reads slot 1's content
        assert_eq!(view.k_at(0, 0, 0, 0, 0), 1000.0);
        // lane 1, layer 1, head 1, pos 3, feat 2 = last element of slot 0
        assert_eq!(view.k_at(1, 1, 1, 3, 2), (n - 1) as f32);
        assert_eq!(view.v_at(1, 0, 0, 0, 0), 0.5);
    }

    #[test]
    fn batch_major_materialization_matches_accessors() {
        let d = dims();
        let n = d.slot_elems();
        let k: Vec<f32> = (0..2 * n).map(|i| i as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        let view = KvView::new(&k, &v, vec![0, n], d, 4);
        let (bk, bv) = view.to_batch_major();
        assert_eq!(bk.shape, vec![2, 2, 2, 4, 3]);
        for lane in 0..2 {
            for l in 0..2 {
                for h in 0..2 {
                    for pos in 0..4 {
                        for f in 0..3 {
                            let idx = ((((l * 2 + lane) * 2 + h) * 4) + pos)
                                * 3
                                + f;
                            assert_eq!(
                                bk.data[idx],
                                view.k_at(lane, l, h, pos, f)
                            );
                            assert_eq!(
                                bv.data[idx],
                                view.v_at(lane, l, h, pos, f)
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "valid prefix")]
    fn reads_past_cache_len_are_caught() {
        let d = dims();
        let k = vec![0.0; d.slot_elems()];
        let v = vec![0.0; d.slot_elems()];
        let view = KvView::new(&k, &v, vec![0], d, 2);
        view.k_at(0, 0, 0, 2, 0);
    }
}
